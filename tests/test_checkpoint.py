"""Checkpoint roundtrip incl. bf16 leaves and stage-stacked trees."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore, save
from repro.configs import get_config, smoke_variant
from repro.models import model as modellib


def test_roundtrip_simple(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16) * 1.5,
                  "d": jnp.int32(7)}}
    p = str(tmp_path / "ckpt")
    save(p, tree)
    zero = jax.tree_util.tree_map(jnp.zeros_like, tree)
    back = restore(p, zero)
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(back)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_roundtrip_model_params(tmp_path):
    cfg = smoke_variant(get_config("zamba2-1.2b"))
    params = modellib.init_params(jax.random.PRNGKey(0), cfg)
    p = str(tmp_path / "model")
    save(p, params)
    back = restore(p, jax.tree_util.tree_map(jnp.zeros_like, params))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    l1, _ = modellib.loss_and_metrics(params, cfg, batch)
    l2, _ = modellib.loss_and_metrics(back, cfg, batch)
    assert float(jnp.abs(l1 - l2)) < 1e-6
