"""Unit tests for the trip-count-aware HLO analyzer on crafted modules."""
import textwrap

from repro.launch import hlo_cost, hlo_stats

MODULE = textwrap.dedent("""\
    HloModule test

    %body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
      %p = (s32[], f32[8,8]) parameter(0)
      %c = s32[] get-tuple-element(%p), index=0
      %x = f32[8,8] get-tuple-element(%p), index=1
      %w = f32[8,8] constant({...})
      %d = f32[8,8] dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %ar = f32[8,8] all-reduce(%d), replica_groups={{0,1,2,3}}, to_apply=%sum
      %one = s32[] constant(1)
      %nc = s32[] add(%c, %one)
      ROOT %t = (s32[], f32[8,8]) tuple(%nc, %ar)
    }

    %cond (p: (s32[], f32[8,8])) -> pred[] {
      %p = (s32[], f32[8,8]) parameter(0)
      %c = s32[] get-tuple-element(%p), index=0
      %n = s32[] constant(5)
      ROOT %lt = pred[] compare(%c, %n), direction=LT
    }

    ENTRY %main (a: f32[8,8]) -> f32[8,8] {
      %a = f32[8,8] parameter(0)
      %zero = s32[] constant(0)
      %init = (s32[], f32[8,8]) tuple(%zero, %a)
      %w = (s32[], f32[8,8]) while(%init), condition=%cond, body=%body
      ROOT %out = f32[8,8] get-tuple-element(%w), index=1
    }
""")


def test_while_trip_count_multiplies():
    c = hlo_cost.analyze(MODULE)
    # dot: 2*8*8*8 = 1024 flops, x5 trips
    assert c.flops == 5 * 1024, c.flops
    # all-reduce 8x8 f32 = 256B, ring factor 2*(4-1)/4 = 1.5 -> 384 x5
    assert abs(c.coll_bytes - 5 * 256 * 1.5) < 1e-6, c.coll_bytes
    assert c.coll_count == 5


def test_backend_config_trip_count_preferred():
    mod = MODULE.replace(
        "condition=%cond, body=%body",
        'condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"7"}}')
    c = hlo_cost.analyze(mod)
    assert c.flops == 7 * 1024


def test_groups_parsers():
    g = hlo_stats._parse_groups("{{0,1},{2,3}}")
    assert g == [[0, 1], [2, 3]]
    g = hlo_stats._parse_groups("[2,4]<=[8]")
    assert g == [[0, 1, 2, 3], [4, 5, 6, 7]]
    g = hlo_stats._parse_groups("[4,2]<=[2,4]T(1,0)")
    assert g == [[0, 4], [1, 5], [2, 6], [3, 7]]


def test_pod_crossing_detection():
    mod = MODULE.replace("replica_groups={{0,1,2,3}}",
                         "replica_groups={{0,1,256,257}}")
    c = hlo_cost.analyze(mod, pod_boundary=256)
    assert c.coll_pod_bytes > 0
    c2 = hlo_cost.analyze(MODULE, pod_boundary=256)
    assert c2.coll_pod_bytes == 0


def test_dynamic_slice_counts_slice_only():
    mod = textwrap.dedent("""\
        HloModule m
        ENTRY %main (a: f32[128,64]) -> f32[1,64] {
          %a = f32[128,64] parameter(0)
          %i = s32[] constant(3)
          ROOT %s = f32[1,64] dynamic-slice(%a, %i, %i), dynamic_slice_sizes={1,64}
        }
    """)
    c = hlo_cost.analyze(mod)
    assert c.hbm_bytes == 2 * 64 * 4      # slice rw, not the 128x64 operand
