"""The frontend / expert-server / transport seams (serving refactor).

The engine is now three layers: a router frontend, one self-contained
``ExpertServer`` per expert (its own tick clock, no router/frontend/
global-barrier references), and a serializable message transport between
them (in-process loopback or one spawned OS process per expert).  These
tests pin the seams:

* ``ExpertServer`` alone — enqueue/tick with no frontend, early-stop
  block recycling, the shared ``busy`` idle predicate;
* asynchrony — two servers driven wildly unequal tick counts must emit
  the same tokens as the lockstep engine (the paper's no-talk property
  applied to serving);
* a structural check that ``expert_server.py`` imports neither the
  router nor the frontend;
* the loopback frontend against the baseline oracle (same recipes as
  the main fuzz suites in ``tests/test_serving.py``);
* a spawn-based two-expert ``ProcessTransport`` identity smoke (slow:
  each worker re-imports jax and compiles its own programs).
"""
import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import router as routerlib
from repro.models import model as modellib
from repro.serving import (EngineConfig, ExpertServer, LoopbackTransport,
                           ServeFrontend, ProcessTransport, RequestMsg,
                           SamplingParams, StatsMsg, baseline)

ECFG = ModelConfig(name="tr-expert", n_layers=2, d_model=64, n_heads=4,
                   n_kv_heads=4, d_ff=128, vocab_size=128, ffn_type="gelu",
                   loss_chunk=32, compute_dtype="float32",
                   param_dtype="float32")
RCFG = ModelConfig(name="tr-router", n_layers=1, d_model=32, n_heads=2,
                   n_kv_heads=2, d_ff=64, vocab_size=128, ffn_type="gelu",
                   loss_chunk=32, compute_dtype="float32",
                   param_dtype="float32")
E, PREFIX, MAXLEN, BS = 2, 16, 48, 16
ENG = EngineConfig(lanes_per_expert=2, max_len=MAXLEN, prefix_len=PREFIX,
                   block_size=BS, route_batch=4)


@pytest.fixture(scope="module")
def mixture():
    key = jax.random.PRNGKey(0)
    router_params = routerlib.init_ensemble(key, RCFG, E)
    expert_params = [modellib.init_params(jax.random.fold_in(key, e), ECFG)
                     for e in range(E)]
    return expert_params, router_params


def _msg(uid, prompt, n_new, sampling=None, stops=(), tick=0):
    return RequestMsg(uid=uid, prompt=np.asarray(prompt, np.int32),
                      max_new_tokens=n_new,
                      sampling=sampling or SamplingParams(),
                      stop_tokens=frozenset(stops), enqueue_tick=tick)


def _drain(server):
    """Tick a lone server until idle; returns its deltas in order."""
    deltas = []
    while server.busy:
        deltas.append(server.tick())
    return [d for batch in deltas for d in batch]


def _oracle(params, prompt, n_new, sampling=None, uid=0, stops=()):
    return baseline.generate_request(ECFG, params, prompt, n_new,
                                     sampling=sampling, uid=uid,
                                     stop_tokens=stops, cache_len=MAXLEN)


# ---------------------------------------------------------------------------
# ExpertServer alone: no frontend, no transport, no router
# ---------------------------------------------------------------------------
def test_expert_server_enqueue_tick_matches_oracle(mixture):
    """A bare ExpertServer must serve greedy + sampled requests bitwise
    like the one-shot baseline, purely through enqueue()/tick()."""
    expert_params, _ = mixture
    rng = np.random.default_rng(50)
    srv = ExpertServer(ECFG, expert_params[0], ENG)
    prompts = [rng.integers(0, ECFG.vocab_size,
                            size=int(rng.integers(PREFIX, 30))).astype(np.int32)
               for _ in range(4)]
    sps = [None, SamplingParams(temperature=0.9, top_k=8, seed=9),
           None, SamplingParams(temperature=1.2, top_p=0.8, seed=10)]
    for i in range(4):
        srv.enqueue(_msg(i, prompts[i], 5, sampling=sps[i]))
    assert srv.busy
    deltas = _drain(srv)
    assert not srv.busy
    toks = {i: [] for i in range(4)}
    for d in deltas:
        assert d.index == len(toks[d.uid])
        toks[d.uid].append(d.token)
    for i in range(4):
        want = _oracle(expert_params[0], prompts[i], 5, sampling=sps[i],
                       uid=i)
        np.testing.assert_array_equal(np.asarray(toks[i]), want)
    st = srv.stats()
    assert isinstance(st, StatsMsg) and st.n_served == 4
    assert st.queue_wait_ticks >= 0


def test_expert_server_early_stop_returns_blocks_same_tick(mixture):
    """An early stop must free the lane and its pool blocks within the
    same tick() call — observable with no frontend attached."""
    expert_params, _ = mixture
    rng = np.random.default_rng(51)
    prompt = rng.integers(0, ECFG.vocab_size, size=PREFIX).astype(np.int32)
    want = _oracle(expert_params[0], prompt, 8)
    srv = ExpertServer(ECFG, expert_params[0], ENG)
    lanes = ENG.lanes_per_expert
    # stop on the very first (prefill-sampled) token: the request must
    # finish inside the admission tick and give everything back
    srv.enqueue(_msg(0, prompt, 8, stops={int(want[0])}))
    deltas = srv.tick()
    assert [d.done for d in deltas] == [True]
    assert deltas[0].finish_reason == "stop_token"
    assert deltas[0].admit_tick == deltas[0].tick
    assert srv.balloc.n_in_use == srv.cached_blocks
    assert srv.alloc.n_free == lanes
    assert not srv.busy


def test_expert_server_prefix_hit_then_evict_under_pressure(mixture):
    """Deterministic cache lifecycle on a bare 1-lane server with the
    minimum legal pool (3 blocks): a second request sharing the first's
    full 2-block prompt admits off the cache (prefilling only its novel
    suffix via decode replay), then an unrelated request under pool
    pressure forces LRU eviction of those cached blocks — tokens stay
    oracle-exact at every stage and the StatsMsg counters tell the
    story."""
    import dataclasses
    expert_params, _ = mixture
    rng = np.random.default_rng(53)
    eng1 = dataclasses.replace(ENG, lanes_per_expert=1,
                               pool_blocks=MAXLEN // BS)
    srv = ExpertServer(ECFG, expert_params[0], eng1)
    system = rng.integers(0, ECFG.vocab_size, size=2 * BS).astype(np.int32)

    def serve(uid, prompt, n_new=4):
        srv.enqueue(_msg(uid, prompt, n_new))
        toks = [d.token for d in _drain(srv)]
        np.testing.assert_array_equal(
            np.asarray(toks), _oracle(expert_params[0], prompt, n_new,
                                      uid=uid))

    serve(0, system)                          # cold: registers both blocks
    assert srv.prefix_hit_blocks == 0 and srv.cached_blocks == 2
    follow = np.concatenate(
        [system, rng.integers(0, ECFG.vocab_size, size=8).astype(np.int32)])
    assert srv.prefix.match_blocks(follow) == 2
    serve(1, follow)                          # warm: 2 of 3 blocks cached
    assert srv.prefix_hit_blocks == 2
    assert srv.prefill_tokens_saved == 2 * BS
    st = srv.stats()
    assert isinstance(st, StatsMsg)
    assert st.prefix_hit_blocks == 2 and st.prefill_tokens_saved == 2 * BS
    assert st.cached_blocks == 2
    # an unrelated max-size request needs all 3 blocks: only eviction of
    # the (now unreferenced) cached pair can free them
    other = rng.integers(0, ECFG.vocab_size, size=2 * BS).astype(np.int32)
    serve(2, other)
    assert srv.prefix.match_blocks(follow) == 0      # old chain evicted
    assert srv.prefix.match_blocks(
        np.concatenate([other, other[:1]])) == 2     # new chain cached
    assert srv.prefix_hit_blocks == 2                # eviction != a hit
    assert srv.balloc.n_in_use == srv.cached_blocks == 2
    assert srv.alloc.n_free == 1 and not srv.busy


def test_expert_server_clock_syncs_forward_only(mixture):
    """enqueue() pulls the clock to the sender's tick, never backward,
    and admit stamps land on the synced timeline."""
    expert_params, _ = mixture
    rng = np.random.default_rng(52)
    prompt = rng.integers(0, ECFG.vocab_size, size=PREFIX).astype(np.int32)
    srv = ExpertServer(ECFG, expert_params[0], ENG)
    srv.enqueue(_msg(0, prompt, 2, tick=500))
    assert srv.clock == 500
    deltas = _drain(srv)
    assert deltas[0].admit_tick == 500
    srv.enqueue(_msg(1, prompt, 2, tick=3))      # stale sender tick
    assert srv.clock > 500                        # no time travel
    _drain(srv)
    assert srv.stats().n_served == 2


def test_unequal_tick_counts_leave_tokens_unchanged(mixture):
    """Acceptance: no global barrier.  Expert 0 is driven to completion
    before expert 1 is ticked at all (plus extra no-op ticks), and every
    request's tokens still match the lockstep engine facade bit for bit."""
    expert_params, router_params = mixture
    rng = np.random.default_rng(53)
    prompts = [rng.integers(0, ECFG.vocab_size, size=PREFIX).astype(np.int32)
               for _ in range(6)]
    sps = [None if i % 2 else SamplingParams(temperature=0.8, seed=20 + i)
           for i in range(6)]
    # reference: the ordinary lockstep facade
    eng = ServeFrontend(ECFG, RCFG, expert_params, router_params, ENG)
    ref = [eng.submit(prompts[i], 4, sampling=sps[i]) for i in range(6)]
    eng.run()
    by_expert = {0: [], 1: []}
    for r in ref:
        by_expert[r.expert].append(r)
    # async: two standalone servers, wildly unequal tick schedules —
    # uids/prompts identical to the facade run, so tokens must be too
    srvs = [ExpertServer(ECFG, expert_params[e], ENG) for e in range(E)]
    toks = {r.uid: [] for r in ref}
    for e in range(E):
        for r in by_expert[e]:
            srvs[e].enqueue(_msg(r.uid, prompts[r.uid], 4,
                                 sampling=sps[r.uid]))
    for d in _drain(srvs[0]):                 # expert 0 runs to the end...
        toks[d.uid].append(d.token)
    for _ in range(7):
        srvs[0].tick()                        # ...then spins empty ticks
    for d in _drain(srvs[1]):                 # expert 1 only starts now
        toks[d.uid].append(d.token)
    assert srvs[0].clock != srvs[1].clock     # genuinely different clocks
    for r in ref:
        assert toks[r.uid] == r.tokens, r.uid


def test_expert_server_imports_no_router_no_frontend():
    """Structural: the expert layer must not know about routing or the
    frontend — the transport messages are its whole world."""
    import inspect

    from repro.serving import expert_server
    src = inspect.getsource(expert_server)
    imports = [ln for ln in src.splitlines()
               if ln.lstrip().startswith(("import ", "from "))]
    assert imports, "no imports found — test is broken"
    for ln in imports:
        assert "router" not in ln, ln
        assert "frontend" not in ln, ln
        assert "assignment" not in ln, ln


# ---------------------------------------------------------------------------
# Loopback frontend vs the baseline oracle (same recipes as test_serving)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed", range(4))
def test_loopback_frontend_fuzz_matches_baseline(mixture, seed):
    """Random prompts/budgets/recipes/stop sets through the layered stack
    on LoopbackTransport: tokens bitwise vs the serial oracle."""
    expert_params, router_params = mixture
    rng = np.random.default_rng(7000 + seed)
    R = int(rng.integers(3, 6))
    prompts = [rng.integers(0, ECFG.vocab_size,
                            size=int(rng.integers(PREFIX, 33))).astype(np.int32)
               for _ in range(R)]
    n_new = [int(rng.integers(2, 8)) for _ in range(R)]
    sps = [None if rng.random() < 0.4 else
           SamplingParams(temperature=float(rng.uniform(0.3, 1.3)),
                          top_k=int(rng.choice([0, 2, 8])),
                          seed=int(rng.integers(0, 1 << 16)))
           for _ in range(R)]
    stops = [frozenset(int(t) for t in
                       rng.integers(0, ECFG.vocab_size, size=8))
             if rng.random() < 0.5 else frozenset() for _ in range(R)]
    eng = ServeFrontend(ECFG, RCFG, expert_params, router_params, ENG)
    assert isinstance(eng._transport, LoopbackTransport)
    reqs = [eng.submit(prompts[i], n_new[i], sampling=sps[i],
                       stop_tokens=stops[i],
                       arrival_tick=int(rng.integers(0, 5)))
            for i in range(R)]
    res = eng.run()
    assert len(res["requests"]) == R
    for r in res["requests"]:
        want = _oracle(expert_params[r.expert], prompts[r.uid], n_new[r.uid],
                       sampling=sps[r.uid], uid=r.uid, stops=stops[r.uid])
        np.testing.assert_array_equal(np.asarray(r.tokens), want,
                                      err_msg=f"seed {seed} uid {r.uid}")
    assert sum(s["served"] for s in res["per_expert"].values()) == R


def test_run_report_per_expert_stats(mixture):
    """Satellite: run() must report per-expert queue_wait_ticks and
    occupancy next to the global aggregates."""
    expert_params, router_params = mixture
    rng = np.random.default_rng(60)
    eng = ServeFrontend(ECFG, RCFG, expert_params, router_params, ENG)
    for i in range(6):                        # > lanes: someone must queue
        eng.submit(rng.integers(0, ECFG.vocab_size,
                                size=PREFIX).astype(np.int32), 4,
                   arrival_tick=0)
    res = eng.run()
    assert set(res["per_expert"]) == set(range(E))
    for st in res["per_expert"].values():
        assert st["queue_wait_ticks"] >= 0
        assert 0.0 <= st["occupancy"] <= 1.0
    assert res["transport"] == "loopback"
    # per-expert occupancies aggregate to the global one
    tot_lane = sum(s["occupancy"] * s["decode_calls"]
                   for s in res["per_expert"].values())
    tot_calls = sum(s["decode_calls"] for s in res["per_expert"].values())
    assert res["occupancy"] == pytest.approx(tot_lane / max(tot_calls, 1))


def test_engine_config_rejects_unknown_transport(mixture):
    expert_params, router_params = mixture
    with pytest.raises(ValueError, match="transport"):
        ServeFrontend(ECFG, RCFG, expert_params, router_params,
                           EngineConfig(max_len=MAXLEN, block_size=BS,
                                        prefix_len=PREFIX, transport="grpc"))


# ---------------------------------------------------------------------------
# ProcessTransport: one spawned process per expert (slow: jax per worker)
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_process_transport_identity_smoke(mixture):
    """Two experts in two spawned processes, router scores the only
    cross-process traffic: tokens must stay bitwise identical to the
    baseline oracle (greedy + sampled + early stops), with per-expert
    stats flowing back as StatsMsg."""
    expert_params, router_params = mixture
    rng = np.random.default_rng(80)
    R = 6
    prompts = [rng.integers(0, ECFG.vocab_size,
                            size=int(rng.integers(PREFIX, 30))).astype(np.int32)
               for _ in range(R)]
    n_new = [int(rng.integers(2, 7)) for _ in range(R)]
    sps = [None if i % 2 == 0 else
           SamplingParams(temperature=0.9, top_k=8, seed=70 + i)
           for i in range(R)]
    stops = [frozenset() if i % 3 else
             frozenset(int(t) for t in
                       rng.integers(0, ECFG.vocab_size, size=12))
             for i in range(R)]
    eng = ServeFrontend(
        ECFG, RCFG, expert_params, router_params,
        EngineConfig(lanes_per_expert=2, max_len=MAXLEN, prefix_len=PREFIX,
                     block_size=BS, route_batch=4, transport="process"))
    with eng:
        assert isinstance(eng._transport, ProcessTransport)
        reqs = [eng.submit(prompts[i], n_new[i], sampling=sps[i],
                           stop_tokens=stops[i], arrival_tick=i // 3)
                for i in range(R)]
        res = eng.run()
    assert len(res["requests"]) == R
    assert res["transport"] == "process"
    want_routes = baseline.route(RCFG, router_params,
                                 np.stack([p[:PREFIX] for p in prompts]),
                                 PREFIX)
    for r in res["requests"]:
        assert r.expert == want_routes[r.uid]
        want = _oracle(expert_params[r.expert], prompts[r.uid],
                       n_new[r.uid], sampling=sps[r.uid], uid=r.uid,
                       stops=stops[r.uid])
        np.testing.assert_array_equal(np.asarray(r.tokens), want,
                                      err_msg=f"uid {r.uid}")
    assert sum(s["served"] for s in res["per_expert"].values()) == R
    # the facade exposes no local expert state on this transport
    with pytest.raises(AttributeError):
        eng._experts
