"""Property tests for balanced assignment (paper §2.2, Fig. 1)."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.assignment import (argmax_assignment, balanced_assignment,
                                   balanced_assignment_np, default_capacity,
                                   sequential_assignment_np)


def _scores(n, e, seed):
    rng = np.random.default_rng(seed)
    return rng.normal(size=(n, e)).astype(np.float32) * 10


@settings(max_examples=50, deadline=None)
@given(n=st.integers(1, 64), e=st.integers(1, 8), seed=st.integers(0, 999),
       cf=st.floats(1.0, 2.0))
def test_capacity_respected_and_total(n, e, seed, cf):
    cap = default_capacity(n, e, cf)
    out = balanced_assignment_np(_scores(n, e, seed), cap)
    assert out.min() >= 0 and out.max() < e
    counts = np.bincount(out, minlength=e)
    assert counts.max() <= cap
    assert counts.sum() == n


@settings(max_examples=30, deadline=None)
@given(n=st.integers(1, 48), e=st.integers(1, 6), seed=st.integers(0, 999))
def test_jax_matches_numpy(n, e, seed):
    s = _scores(n, e, seed)
    cap = default_capacity(n, e)
    got = np.asarray(balanced_assignment(s, cap))
    want = balanced_assignment_np(s, cap)
    np.testing.assert_array_equal(got, want)


def test_unconstrained_equals_argmax():
    s = _scores(100, 4, 0)
    out = balanced_assignment_np(s, capacity=100)
    np.testing.assert_array_equal(out, s.argmax(1))
    np.testing.assert_array_equal(np.asarray(argmax_assignment(s)), s.argmax(1))


def test_figure1_example():
    """Paper Fig. 1: sorted-by-confidence beats sequential assignment."""
    # 3 sequences, 3 experts, capacity 1.  Sequential assigns row0->e0,
    # row1 wants e0 (full) -> e1; row2 wants e0/e1 (full) -> e2 at a big
    # loss.  Balanced assigns the confident rows first.
    scores = np.array([
        [-1.0, -9.0, -9.5],    # weak preference for e0
        [-0.5, -0.6, -9.5],    # nearly indifferent e0/e1
        [-0.1, -8.0, -9.9],    # STRONG preference for e0
    ])
    seq = sequential_assignment_np(scores, capacity=1)
    bal = balanced_assignment_np(scores, capacity=1)

    def total(assign):
        return sum(scores[i, a] for i, a in enumerate(assign))

    assert total(bal) > total(seq)
    assert bal[2] == 0                       # the confident row got e0


@settings(max_examples=30, deadline=None)
@given(n=st.integers(2, 32), e=st.integers(2, 4), seed=st.integers(0, 99))
def test_most_confident_sequence_gets_its_argmax(n, e, seed):
    """The guarantee balanced assignment actually provides (Fig. 1b): the
    highest-likelihood sequence is assigned first, so it always receives
    its argmax expert."""
    s = _scores(n, e, seed)
    cap = default_capacity(n, e)
    bal = balanced_assignment_np(s, cap)
    top = int(s.max(1).argmax())
    assert bal[top] == s[top].argmax()


def test_capacity_too_small_raises():
    with pytest.raises(ValueError):
        balanced_assignment_np(_scores(10, 2, 0), capacity=3)
