"""Paper App. A.3 cost accounting: our formulas must reproduce Table 3's
printed training/inference costs at paper scale."""
import numpy as np

from benchmarks.flops_accounting import (EXPERT_1P3B, EXPERT_335M, ROUTER_4M,
                                         comm_table, inference_flops, table3,
                                         train_flops)


def test_dense_training_cost_matches_table3():
    # Table 3: 335M dense, 256k steps, batch 512 -> 31.02e19 FLOPs
    got = train_flops(EXPERT_335M, 512, 1024, 256_000)
    assert abs(got / 1e19 - 31.02) < 0.5, got / 1e19
    # 1.3B dense, 512k steps, batch 512 -> 221.33e19
    got = train_flops(EXPERT_1P3B, 512, 1024, 512_000)
    assert abs(got / 1e19 - 221.33) < 3.0, got / 1e19


def test_dense_inference_cost_matches_table3():
    # Table 3: 335M -> 0.79e12, 1.3B -> 2.81e12
    assert abs(inference_flops(EXPERT_335M, 1024) / 1e12 - 0.79) < 0.03
    assert abs(inference_flops(EXPERT_1P3B, 1024) / 1e12 - 2.81) < 0.1


def test_mixture_overheads_match_table3():
    rows = {(r["model"], r["experts"]): r for r in table3()}
    # paper: 1.3B/32e: ~1.07% train, <3% inference
    r = rows[("1.3B", 32)]
    assert r["mix_overhead_train_pct"] < 2.0, r
    assert r["mix_overhead_inf_pct"] < 3.5, r
    # 335M/32e: ~4.1% train, ~10% inference
    r = rows[("335M", 4)]
    assert r["mix_overhead_train_pct"] < 1.0, r
    # overheads grow with E at fixed size
    t = [rows[("335M", e)]["mix_overhead_train_pct"] for e in (4, 8, 16, 32)]
    assert all(a < b for a, b in zip(t, t[1:])), t


def test_router_is_tiny_fraction():
    # paper: router < 1.5% of expert params; check via FLOPs proxy at S=1
    r = inference_flops(ROUTER_4M, 256)
    e = inference_flops(EXPERT_335M, 1024)
    assert r / e < 0.05


def test_comm_overhead_appendix_a4():
    c = comm_table(E=32, W=1.3e9)
    # App A.4: <= 5.625 MB per router per comm; ~94 comms; DDP step = 10.4 GB
    assert c["router_bytes_per_comm"] <= 5.7e6
    assert 80 <= c["router_n_comms"] <= 100
    assert abs(c["ddp_bytes_per_step"] - 10.4e9) / 10.4e9 < 0.01
    # one DDP step moves more than the routers' ENTIRE training comm
    assert c["ratio_one_ddp_step_vs_entire_router_training"] > 15
