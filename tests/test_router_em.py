"""Router ensemble + EM: Bayes-rule scoring, vmap==loop equivalence,
and the paper's core property — EM routing discovers latent domains."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import em, router as routerlib
from repro.data import DataConfig, SyntheticCorpus

RCFG = ModelConfig(name="test-router", n_layers=2, d_model=64, n_heads=4,
                   n_kv_heads=4, d_ff=256, vocab_size=256, ffn_type="gelu",
                   loss_chunk=64)


def test_scores_are_prefix_loglik():
    """score[b,e] == -sum NLL over the prefix under router e (Eq. 7)."""
    E, B, M = 3, 4, 16
    stacked = routerlib.init_ensemble(jax.random.PRNGKey(0), RCFG, E)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, M), 0, 256)
    scores = routerlib.ensemble_scores(stacked, RCFG, toks)
    assert scores.shape == (B, E)
    # loop equivalence
    for e in range(E):
        pe = routerlib.unstack(stacked, e)
        want = routerlib.sequence_loglik(pe, RCFG, toks)
        np.testing.assert_allclose(np.asarray(scores[:, e]),
                                   np.asarray(want), rtol=2e-3, atol=2e-3)
    assert (np.asarray(scores) < 0).all()     # log-probs


def test_independent_inits():
    stacked = routerlib.init_ensemble(jax.random.PRNGKey(0), RCFG, 2)
    a = jax.tree_util.tree_leaves(stacked)[3]
    assert a.shape[0] == 2
    assert float(jnp.abs(a[0] - a[1]).max()) > 0


@pytest.mark.slow
def test_em_discovers_domains():
    """Paper Algorithm 1 at toy scale: purity -> ~1, load balanced."""
    corpus = SyntheticCorpus(DataConfig(vocab_size=256, seq_len=64,
                                        n_domains=4))
    emcfg = em.EMConfig(n_experts=4, prefix_len=32, em_iters=3,
                        chunk_size=2048, steps_per_iter=40, batch_size=32,
                        lr=3e-3)
    state = em.train_routers(corpus, RCFG, emcfg, jax.random.PRNGKey(0))
    hist = state.history
    assert hist[-1]["purity"] > 0.9, hist
    assert hist[-1]["router_ce"] < hist[0]["router_ce"]
    load = np.array(hist[-1]["load"])
    assert load.max() - load.min() <= 1            # balanced by construction
    # communication: 2 bytes per (sequence, router) per E-step
    assert state.comm_bytes == 2 * emcfg.chunk_size * 4 * emcfg.em_iters
