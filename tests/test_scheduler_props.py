"""Property/fuzz suite for the host-side serving schedulers.

Allocator invariants under random alloc/free interleavings (never
double-allocate, never leak, unowned frees raise), the refcounting
lifecycle prefix sharing leans on (``ref_n``/``free_n`` interleavings
against a reference model: refcount 0 iff the block is on the free
list, no double-free, no leak), RequestQueue arrival-ordering (a
late-submitted early arrival pops first), and the prompt-length
bucketing function (power-of-two ladder, monotone, capped).  Each
property runs twice: a hypothesis-driven version (skipped on minimal
environments via ``_hypothesis_compat``) and a seeded-rng version that
always runs, so the invariants stay covered even without hypothesis.
"""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.serving import bucket_len
from repro.serving.scheduler import (BlockAllocator, PrefixCache, Request,
                                     RequestQueue, SlotAllocator)


# ---------------------------------------------------------------------------
# Reference-model interleavings (shared by hypothesis and seeded drivers)
# ---------------------------------------------------------------------------
def _drive_slot_allocator(n, choices):
    """choices: iterable of floats in [0,1) steering alloc-vs-free."""
    a = SlotAllocator(n)
    held = set()
    for c in choices:
        if c < 0.5:
            if a.n_free == 0:
                assert a.alloc() is None, "exhausted pool must hand out None"
                continue
            s = a.alloc()
            assert s is not None and 0 <= s < n
            assert s not in held, "double allocation"
            held.add(s)
        elif held:
            s = sorted(held)[int(c * 100) % len(held)]
            a.free(s)
            held.remove(s)
    assert a.n_free == n - len(held), "leaked or fabricated slots"
    for s in sorted(held):
        a.free(s)
    assert a.n_free == n


def _drive_block_allocator(n, choices):
    a = BlockAllocator(n)
    held: list[list[int]] = []
    held_flat: set[int] = set()
    for c in choices:
        if c < 0.5:
            k = int(c * 100) % (n + 2)            # may exceed what's free
            got = a.alloc_n(k)
            if len(held_flat) + k > n:
                assert got is None, "allocated past capacity"
            if got is None:
                assert a.n_free == n - len(held_flat), \
                    "failed alloc_n mutated the free list"
                continue
            assert len(got) == k and len(set(got)) == k
            assert not (set(got) & held_flat), "double allocation"
            held.append(got)
            held_flat.update(got)
        elif held:
            grp = held.pop(int(c * 100) % len(held))
            a.free_n(grp)
            held_flat.difference_update(grp)
    assert a.n_free == n - len(held_flat), "leaked or fabricated blocks"
    assert a.n_in_use == len(held_flat)
    assert a.peak_in_use <= n
    for grp in held:
        a.free_n(grp)
    assert a.n_free == n and a.n_in_use == 0


def _drive_refcounts(n, choices):
    """Refcounting lifecycle against a dict reference model: alloc_n
    births at refcount 1, ref_n increments (sharing), free_n decrements
    — a block returns to the free list exactly when its count hits 0."""
    a = BlockAllocator(n)
    model: dict[int, int] = {}            # block -> expected refcount
    for c in choices:
        live = sorted(model)
        if c < 0.4:
            k = int(c * 1000) % (n + 2)
            got = a.alloc_n(k)
            if len(model) + k > n:
                assert got is None, "allocated past capacity"
                continue
            assert got is not None and not (set(got) & set(model))
            for b in got:
                assert a.refcount(b) == 1, "fresh block not at refcount 1"
                model[b] = 1
        elif c < 0.7 and live:
            b = live[int(c * 1000) % len(live)]
            reps = 1 + int(c * 10000) % 2         # duplicates count twice
            a.ref_n([b] * reps)
            model[b] += reps
        elif live:
            b = live[int(c * 1000) % len(live)]
            reps = 1 + int(c * 10000) % 2
            if reps > model[b]:
                reps = model[b]
            a.free_n([b] * reps)
            model[b] -= reps
            if model[b] == 0:
                del model[b]
        # refcount 0 <=> on the free list, counts match the model exactly
        assert a.n_in_use == len(model), "leaked or fabricated blocks"
        assert a.n_free == n - len(model)
        for b in range(n):
            assert a.refcount(b) == model.get(b, 0)
            assert (a.refcount(b) == 0) == (b in a._free)
    for b, k in list(model.items()):
        a.free_n([b] * k)
    assert a.n_free == n and a.n_in_use == 0


def _drive_queue(arrivals):
    """arrivals: submission-ordered list of arrival ticks (arbitrary order)."""
    q = RequestQueue()
    reqs = [Request(uid=i, prompt=np.zeros(4, np.int32), max_new_tokens=1,
                    arrival_tick=t) for i, t in enumerate(arrivals)]
    for r in reqs:
        q.push(r)
    assert len(q) == len(reqs)
    if reqs:
        assert q.next_arrival() == min(arrivals)
    popped = []
    tick = -1
    while len(q):
        tick = q.next_arrival() if q.next_arrival() > tick else tick + 1
        got = q.pop_arrived(tick)
        assert all(r.arrival_tick <= tick for r in got)
        assert q.next_arrival() is None or q.next_arrival() > tick
        popped.extend(got)
    # arrival-ordered overall, submission-ordered (stable) within a tick
    want = [uid for uid, _ in sorted(enumerate(arrivals),
                                     key=lambda p: (p[1], p[0]))]
    assert [r.uid for r in popped] == want, "queue broke arrival ordering"


# ---------------------------------------------------------------------------
# Hypothesis-driven properties (skip cleanly without hypothesis)
# ---------------------------------------------------------------------------
@settings(max_examples=50, deadline=None)
@given(st.integers(1, 8), st.lists(st.floats(0, 0.999), max_size=120))
def test_prop_slot_allocator(n, choices):
    _drive_slot_allocator(n, choices)


@settings(max_examples=50, deadline=None)
@given(st.integers(1, 32), st.lists(st.floats(0, 0.999), max_size=120))
def test_prop_block_allocator(n, choices):
    _drive_block_allocator(n, choices)


@settings(max_examples=50, deadline=None)
@given(st.integers(1, 16), st.lists(st.floats(0, 0.999), max_size=120))
def test_prop_block_refcounts(n, choices):
    _drive_refcounts(n, choices)


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(0, 20), max_size=40))
def test_prop_request_queue_ordering(arrivals):
    _drive_queue(arrivals)


# ---------------------------------------------------------------------------
# Prompt-length bucketing: pow2 ladder, monotone, bounded
# ---------------------------------------------------------------------------
def _check_bucket(n, min_bucket, max_len):
    b = bucket_len(n, min_bucket, max_len)
    assert b <= max_len, "bucket exceeds the lane budget"
    if n <= max_len:
        assert b >= n, "bucket cannot hold the prompt"
    # the result is min_bucket * 2^j for some j, or the max_len cap
    if b != max_len:
        q = b
        while q > min_bucket and q % 2 == 0:
            q //= 2
        assert q == min_bucket, (n, min_bucket, max_len, b)
    # monotone: one more token never lands in a smaller bucket
    assert bucket_len(n + 1, min_bucket, max_len) >= b
    # idempotent: a bucket-sized prompt keeps its bucket
    assert bucket_len(b, min_bucket, max_len) == b


@settings(max_examples=100, deadline=None)
@given(st.integers(1, 512), st.integers(1, 64), st.integers(1, 600))
def test_prop_bucket_len(n, min_bucket, max_len):
    _check_bucket(n, min_bucket, max_len)


# ---------------------------------------------------------------------------
# Seeded-rng versions: always run, same invariants
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed", range(8))
def test_fuzz_slot_allocator(seed):
    rng = np.random.default_rng(seed)
    _drive_slot_allocator(int(rng.integers(1, 9)), rng.random(200))


@pytest.mark.parametrize("seed", range(8))
def test_fuzz_block_allocator(seed):
    rng = np.random.default_rng(100 + seed)
    _drive_block_allocator(int(rng.integers(1, 33)), rng.random(200))


@pytest.mark.parametrize("seed", range(8))
def test_fuzz_block_refcounts(seed):
    rng = np.random.default_rng(400 + seed)
    _drive_refcounts(int(rng.integers(1, 17)), rng.random(200))


@pytest.mark.parametrize("seed", range(8))
def test_fuzz_request_queue(seed):
    rng = np.random.default_rng(200 + seed)
    _drive_queue([int(t) for t in rng.integers(0, 15,
                                               size=rng.integers(0, 40))])


@pytest.mark.parametrize("seed", range(8))
def test_fuzz_bucket_len(seed):
    rng = np.random.default_rng(300 + seed)
    for _ in range(50):
        _check_bucket(int(rng.integers(1, 513)), int(rng.integers(1, 65)),
                      int(rng.integers(1, 601)))


def test_bucket_len_rejects_degenerate_min_bucket():
    for mb in (0, -4):
        with pytest.raises(ValueError):
            bucket_len(5, mb, 64)       # would loop forever otherwise


# ---------------------------------------------------------------------------
# Unowned / double frees must raise, not corrupt
# ---------------------------------------------------------------------------
def test_slot_allocator_bad_free_raises():
    a = SlotAllocator(3)
    s = a.alloc()
    with pytest.raises(ValueError):
        a.free(3)                       # out of range
    with pytest.raises(ValueError):
        a.free((s + 1) % 3)             # never allocated
    a.free(s)
    with pytest.raises(ValueError):
        a.free(s)                       # double free


def test_block_allocator_bad_free_raises():
    a = BlockAllocator(4)
    got = a.alloc_n(2)
    with pytest.raises(ValueError):
        a.free(99)                      # out of range / never allocated
    other = ({0, 1, 2, 3} - set(got)).pop()
    with pytest.raises(ValueError):
        a.free(other)                   # not currently owned
    a.free_n(got)
    with pytest.raises(ValueError):
        a.free(got[0])                  # double free
    with pytest.raises(ValueError):
        a.alloc_n(-1)
    assert a.alloc_n(0) == []
    assert a.alloc_n(5) is None and a.n_free == 4


def test_block_allocator_atomic_under_shortage():
    a = BlockAllocator(3)
    first = a.alloc_n(2)
    assert a.alloc_n(2) is None         # only 1 free: all-or-nothing
    assert a.n_free == 1
    assert a.alloc_n(1) is not None and a.n_free == 0
    a.free_n(first)
    assert a.n_free == 2


def test_alloc_n_failed_allocation_rolls_back_fully():
    """A failed alloc_n must leave NO trace: identical free-list content
    and order (a partial grab that leaked even one block would shrink the
    pool until the engine deadlocks), untouched ownership, and the next
    exact-fit allocation must still succeed."""
    a = BlockAllocator(8)
    held = a.alloc_n(3)
    free_before = list(a._free)
    owned_before = set(a._owned)
    peak_before = a.peak_in_use
    for ask in (6, 7, 100):             # all exceed the 5 free blocks
        assert a.alloc_n(ask) is None
        assert a._free == free_before, "failed alloc_n mutated the free list"
        assert a._owned == owned_before
        assert a.n_in_use == 3 and a.peak_in_use == peak_before
    got = a.alloc_n(5)                  # exact fit still available
    assert got is not None and len(got) == 5
    assert a.n_free == 0
    a.free_n(got)
    a.free_n(held)
    assert a.n_free == 8 and a.n_in_use == 0


def test_block_allocator_free_n_atomic():
    """Satellite regression: a ``free_n`` batch naming ANY bad block —
    never-allocated, out-of-range, or more drops than the block has
    references — must raise and leave the allocator exactly as it was
    (the old code freed list-order prefixes before noticing, leaking
    partially-freed state that desynced ``n_free`` from the engine's
    block tables)."""
    a = BlockAllocator(6)
    held = a.alloc_n(3)
    a.ref_n([held[0]])                    # held[0] shared at refcount 2
    never = ({0, 1, 2, 3, 4, 5} - set(held)).pop()
    before = (list(a._free), {b: a.refcount(b) for b in range(6)})
    for bad in ([held[1], never],         # valid then never-allocated
                [never, held[1]],         # bad id first
                [held[1], held[1]],       # drops exceed refcount 1
                [held[0]] * 3,            # drops exceed refcount 2
                [held[2], 99]):           # out of range
        with pytest.raises(ValueError):
            a.free_n(bad)
        assert a._free == before[0], f"free_n({bad}) mutated the free list"
        assert {b: a.refcount(b) for b in range(6)} == before[1]
        assert a.n_free == 3 and a.n_in_use == 3
    a.free_n([held[0], held[0]])          # both refs in one batch is fine
    a.free_n([held[1], held[2]])
    assert a.n_free == 6 and a.n_in_use == 0


def test_slot_allocator_distinguishes_double_free():
    """Satellite: freeing a previously-owned slot twice and freeing a
    slot that was never handed out are different bugs — the error must
    say which one happened."""
    a = SlotAllocator(4)
    s = a.alloc()
    a.free(s)
    with pytest.raises(ValueError, match="double free"):
        a.free(s)
    fresh = next(x for x in range(4) if x != s)
    with pytest.raises(ValueError, match="never-allocated"):
        a.free(fresh)
    with pytest.raises(ValueError, match="never-allocated"):
        a.free(99)                        # out of range is never-allocated


def test_prefix_cache_refcount_lifecycle():
    """register takes a cache-owned ref; acquire adds a per-lane ref;
    eviction only touches refcount-1 (cache-only) leaves, LRU-first."""
    balloc = BlockAllocator(8)
    pc = PrefixCache(balloc, block_size=4)
    prompt = np.arange(9, dtype=np.int32)          # 2 full blocks + 1 tail
    lane = balloc.alloc_n(3)
    pc.register(prompt, lane)
    assert pc.n_blocks == 2                        # tail block not cached
    assert [balloc.refcount(b) for b in lane] == [2, 2, 1]
    assert pc.match_blocks(prompt) == 2
    # a sharer: acquire bumps the cached blocks, caller owns those refs
    got = pc.acquire(prompt)
    assert got == lane[:2]
    assert [balloc.refcount(b) for b in lane] == [3, 3, 1]
    # a one-block prompt can never hit: its only full block holds the
    # last prompt position, which must be computed to emit token 0
    assert pc.match_blocks(prompt[:4]) == 0
    # nothing evictable while the cache's blocks are shared with lanes
    balloc.free_n(got)                             # sharer retires
    pc2_prompt = np.arange(100, 106, dtype=np.int32)   # 1 full block + tail
    lane2 = balloc.alloc_n(2)
    pc.register(pc2_prompt, lane2)                 # younger single-block entry
    balloc.free_n(lane)                            # first lane retires too
    balloc.free_n(lane2)
    # pool: 3 cached blocks all at refcount 1, 5 free; ask for 7 free —
    # LRU evicts the older chain (deep leaf first), keeps the young one
    assert pc.evict(7) is True
    assert balloc.n_free == 7 and pc.n_blocks == 1
    assert pc.match_blocks(prompt) == 0
    assert pc.match_blocks(pc2_prompt) == 1
    # asking beyond what eviction can reach reports failure, not a hang
    held = balloc.alloc_n(1)
    pc3 = np.arange(200, 205, dtype=np.int32)
    pc.register(pc3, held)
    assert pc.evict(8) is False                    # held still referenced
    balloc.free_n(held)
    assert pc.evict(8) is True and balloc.n_free == 8


def test_request_queue_ticks_guard():
    """Satellite: queue_ticks must read 0 (not negative garbage) before a
    lane is acquired — admit_tick still holds the -1 sentinel then."""
    req = Request(uid=0, prompt=np.zeros(4, np.int32), max_new_tokens=1,
                  arrival_tick=7)
    assert req.admit_tick == -1
    assert req.queue_ticks == 0         # pre-admission: no -8 garbage
    req.admit_tick = 9
    assert req.queue_ticks == 2         # post-admission unchanged
