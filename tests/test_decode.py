"""Decode-vs-full-forward consistency: prefill + N decode steps must match
teacher-forced full forwards exactly (f32).  Exercises KV caches (full +
rotating sliding-window), Mamba2/mLSTM/sLSTM recurrent states and MoE."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, smoke_variant
from repro.models import model as modellib

ARCHS = ["gemma2-27b", "chatglm3-6b", "zamba2-1.2b", "xlstm-1.3b",
         "grok-1-314b", "qwen2-1.5b"]
B, S, STEPS = 2, 32, 3


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_full_forward(arch):
    cfg = smoke_variant(get_config(arch)).replace(
        compute_dtype="float32", param_dtype="float32")
    if cfg.moe is not None:    # remove capacity drops for exactness
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                  capacity_factor=8.0))
    params = modellib.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    _, caches = modellib.prefill(params, cfg, {"tokens": toks},
                                 cache_len=S + STEPS)
    cur = toks
    for t in range(STEPS):
        nxt = jnp.full((B, 1), (7 * t + 3) % cfg.vocab_size, jnp.int32)
        lg, caches = modellib.decode_step(params, cfg, {
            "tokens": nxt,
            "positions": jnp.full((B, 1), S + t, jnp.int32),
            "cache_index": jnp.int32(S + t)}, caches)
        cur = jnp.concatenate([cur, nxt], 1)
        want, _ = modellib.prefill(params, cfg, {"tokens": cur})
        err = float(jnp.abs(lg[:, 0] - want).max())
        assert err < 1e-4, (arch, t, err)


def test_sliding_window_cache_rotation():
    """Decode far past the window: rotating cache must stay correct."""
    cfg = smoke_variant(get_config("gemma2-27b")).replace(
        compute_dtype="float32", param_dtype="float32", sliding_window=16)
    params = modellib.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, 24), 0,
                              cfg.vocab_size)
    n_steps = 20                                  # > window
    _, caches = modellib.prefill(params, cfg, {"tokens": toks},
                                 cache_len=24 + n_steps)
    cur = toks
    for t in range(n_steps):
        nxt = jnp.full((B, 1), (5 * t + 1) % cfg.vocab_size, jnp.int32)
        lg, caches = modellib.decode_step(params, cfg, {
            "tokens": nxt,
            "positions": jnp.full((B, 1), 24 + t, jnp.int32),
            "cache_index": jnp.int32(24 + t)}, caches)
        cur = jnp.concatenate([cur, nxt], 1)
    want, _ = modellib.prefill(params, cfg, {"tokens": cur})
    err = float(jnp.abs(lg[:, 0] - want).max())
    assert err < 1e-4, err
