"""Pallas lm_loss kernel vs pure-jnp oracle: shape/dtype sweep + grads +
hypothesis property tests (interpret=True executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.lm_loss import ops
from repro.kernels.lm_loss.lm_loss import lm_loss_pallas
from repro.kernels.lm_loss.ref import lm_loss_chunked, lm_loss_naive

SHAPES = [(2, 64, 32, 128), (1, 100, 48, 300), (3, 33, 16, 77),
          (1, 256, 64, 512), (2, 17, 24, 1000)]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("softcap", [0.0, 30.0])
def test_forward_matches_oracle(shape, dtype, softcap):
    B, S, D, V = shape
    h = (jax.random.normal(jax.random.PRNGKey(0), (B, S, D)) * 0.5).astype(dtype)
    emb = (jax.random.normal(jax.random.PRNGKey(1), (V, D)) * 0.1).astype(dtype)
    lab = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, V)
    want = lm_loss_naive(h, emb, lab, softcap=softcap)
    got = lm_loss_pallas(h, emb, lab, softcap=softcap)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("softcap", [0.0, 20.0])
def test_grads_match_oracle(softcap):
    B, S, D, V = 2, 40, 24, 160
    h = jax.random.normal(jax.random.PRNGKey(0), (B, S, D))
    emb = jax.random.normal(jax.random.PRNGKey(1), (V, D)) * 0.1
    lab = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, V)
    w = jax.random.normal(jax.random.PRNGKey(3), (B, S))     # nonuniform cotangent

    def f(fn):
        return lambda h, e: (fn(h, e, lab, softcap=softcap) * w).sum()

    g_ref = jax.grad(f(lambda h, e, labels, softcap: lm_loss_naive(
        h, e, labels, softcap=softcap)), (0, 1))(h, emb)
    g_pl = jax.grad(f(lambda h, e, labels, softcap: lm_loss_pallas(
        h, e, labels, softcap=softcap)), (0, 1))(h, emb)
    for a, b in zip(g_ref, g_pl):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=2e-4, atol=2e-5)


def test_chunked_equals_naive():
    B, S, D, V = 2, 96, 32, 200
    h = jax.random.normal(jax.random.PRNGKey(0), (B, S, D))
    emb = jax.random.normal(jax.random.PRNGKey(1), (V, D)) * 0.1
    lab = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, V)
    np.testing.assert_allclose(
        np.asarray(lm_loss_chunked(h, emb, lab, chunk=32)),
        np.asarray(lm_loss_naive(h, emb, lab)), rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(B=st.integers(1, 3), S=st.integers(1, 50), D=st.sampled_from([8, 16]),
       V=st.integers(2, 200), seed=st.integers(0, 99))
def test_property_nll_is_valid_distribution(B, S, D, V, seed):
    """NLL must be >= 0 and equal to -log softmax[label]."""
    k = jax.random.PRNGKey(seed)
    h = jax.random.normal(k, (B, S, D))
    emb = jax.random.normal(jax.random.PRNGKey(seed + 1), (V, D)) * 0.2
    lab = jax.random.randint(jax.random.PRNGKey(seed + 2), (B, S), 0, V)
    nll = np.asarray(lm_loss_pallas(h, emb, lab))
    assert (nll >= -1e-5).all()
    logp = jax.nn.log_softmax(h @ emb.T, axis=-1)
    want = -np.asarray(jnp.take_along_axis(logp, lab[..., None], -1))[..., 0]
    np.testing.assert_allclose(nll, want, rtol=1e-4, atol=1e-4)


def test_ops_dispatch():
    B, S, D, V = 1, 16, 8, 32
    h = jax.random.normal(jax.random.PRNGKey(0), (B, S, D))
    emb = jax.random.normal(jax.random.PRNGKey(1), (V, D))
    lab = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, V)
    outs = [ops.lm_loss(h, emb, lab, impl=i) for i in ("naive", "jnp", "pallas")]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(o), np.asarray(outs[0]),
                                   rtol=1e-5, atol=1e-5)
