"""AdamW vs an independent numpy reference; schedules; clipping."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import AdamWConfig, adamw


def _np_adamw(params, grads, m, v, step, cfg, clip=True):
    gflat = np.concatenate([g.ravel() for g in grads])
    gnorm = np.sqrt((gflat ** 2).sum())
    scale = min(1.0, cfg.clip_norm / max(gnorm, 1e-12)) if clip else 1.0
    lr = cfg.peak_lr * step / cfg.warmup_steps if step < cfg.warmup_steps \
        else cfg.peak_lr
    out_p, out_m, out_v = [], [], []
    for p, g in zip(params, grads):
        g = g * scale
        m_n = cfg.b1 * m[len(out_m)] + (1 - cfg.b1) * g
        v_n = cfg.b2 * v[len(out_v)] + (1 - cfg.b2) * g ** 2
        mh = m_n / (1 - cfg.b1 ** step)
        vh = v_n / (1 - cfg.b2 ** step)
        upd = mh / (np.sqrt(vh) + cfg.eps) + cfg.weight_decay * p
        out_p.append(p - lr * upd)
        out_m.append(m_n)
        out_v.append(v_n)
    return out_p, out_m, out_v


def test_adamw_matches_numpy_reference():
    cfg = AdamWConfig(peak_lr=1e-2, warmup_steps=2, total_steps=100,
                      schedule="constant", clip_norm=0.5, weight_decay=0.1)
    rng = np.random.default_rng(0)
    params = {"a": jnp.asarray(rng.normal(size=(4, 3)), jnp.float32),
              "b": jnp.asarray(rng.normal(size=(7,)), jnp.float32)}
    state = adamw.init_state(params, cfg)
    np_p = [np.asarray(params["a"]), np.asarray(params["b"])]
    np_m = [np.zeros_like(x) for x in np_p]
    np_v = [np.zeros_like(x) for x in np_p]
    for step in range(1, 5):
        grads = {"a": jnp.asarray(rng.normal(size=(4, 3)), jnp.float32),
                 "b": jnp.asarray(rng.normal(size=(7,)), jnp.float32)}
        params, state, info = adamw.apply_updates(params, grads, state, cfg)
        np_p, np_m, np_v = _np_adamw(
            np_p, [np.asarray(grads["a"]), np.asarray(grads["b"])],
            np_m, np_v, step, cfg)
        np.testing.assert_allclose(np.asarray(params["a"]), np_p[0],
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(params["b"]), np_p[1],
                                   rtol=1e-5, atol=1e-6)


def test_schedules():
    cos = AdamWConfig(peak_lr=1.0, warmup_steps=10, total_steps=110,
                      schedule="cosine", min_lr_ratio=0.1)
    assert float(adamw.lr_at(cos, jnp.int32(0))) == 0.0
    assert abs(float(adamw.lr_at(cos, jnp.int32(10))) - 1.0) < 1e-6
    assert abs(float(adamw.lr_at(cos, jnp.int32(110))) - 0.1) < 1e-6
    mid = float(adamw.lr_at(cos, jnp.int32(60)))
    assert 0.5 < mid < 0.6
    const = AdamWConfig(peak_lr=0.5, warmup_steps=4, schedule="constant")
    assert abs(float(adamw.lr_at(const, jnp.int32(1000))) - 0.5) < 1e-7


def test_clip_by_global_norm():
    g = {"x": jnp.full((10,), 3.0)}
    clipped, norm = adamw.clip_by_global_norm(g, 0.1)
    assert abs(float(norm) - 3.0 * np.sqrt(10)) < 1e-4
    cn = float(jnp.sqrt((clipped["x"] ** 2).sum()))
    assert abs(cn - 0.1) < 1e-5
    small = {"x": jnp.full((4,), 0.001)}
    out, _ = adamw.clip_by_global_norm(small, 0.1)
    np.testing.assert_allclose(np.asarray(out["x"]), 0.001, rtol=1e-6)


def test_paper_hyperparameters_default():
    cfg = AdamWConfig()
    assert cfg.b1 == 0.9 and cfg.b2 == 0.99
    assert cfg.weight_decay == 0.1 and cfg.clip_norm == 0.1
