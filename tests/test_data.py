"""Synthetic corpus + pipeline: determinism, domain separability, batches."""
import numpy as np

from repro.data import (AssignedStream, DataConfig, Stream, SyntheticCorpus,
                        chunk_indices, make_lm_batch)


def test_deterministic():
    c1 = SyntheticCorpus(DataConfig(seed=7))
    c2 = SyntheticCorpus(DataConfig(seed=7))
    idx = np.array([0, 5, 123456789])
    t1, d1 = c1.sequences(idx)
    t2, d2 = c2.sequences(idx)
    np.testing.assert_array_equal(t1, t2)
    np.testing.assert_array_equal(d1, d2)
    t3, _ = SyntheticCorpus(DataConfig(seed=8)).sequences(idx)
    assert (t1 != t3).any()


def test_domains_are_statistically_distinct():
    """A domain's bigram successor statistics must not transfer: the
    fraction of 'chain-consistent' transitions is high within-domain and
    ~uniform across domains."""
    cfg = DataConfig(vocab_size=256, seq_len=128, n_domains=4, signal=0.9)
    corpus = SyntheticCorpus(cfg)
    toks, doms = corpus.sequences(np.arange(64))
    for d in range(4):
        sel = toks[doms == d]
        a, b = corpus.a[d], corpus.b[d]
        pred = (a * sel[:, :-1] + b) % cfg.vocab_size
        hit = np.abs((sel[:, 1:] - pred) % cfg.vocab_size) < cfg.jitter
        assert hit.mean() > 0.7, d
        # other domains' rule must not explain it
        a2, b2 = corpus.a[(d + 1) % 4], corpus.b[(d + 1) % 4]
        pred2 = (a2 * sel[:, :-1] + b2) % cfg.vocab_size
        hit2 = np.abs((sel[:, 1:] - pred2) % cfg.vocab_size) < cfg.jitter
        assert hit2.mean() < 0.2, d


def test_lm_batch_shift():
    toks = np.arange(12).reshape(2, 6)
    b = make_lm_batch(toks)
    np.testing.assert_array_equal(b["labels"][:, :-1], toks[:, 1:])
    assert b["loss_mask"][:, -1].sum() == 0
    assert b["loss_mask"][:, :-1].all()


def test_streams_disjoint_and_assigned():
    corpus = SyntheticCorpus(DataConfig())
    s = Stream(corpus, batch_size=4)
    b0, b1 = s.next(), s.next()
    assert (b0["tokens"] != b1["tokens"]).any()
    idx = np.array([3, 7, 11, 15, 19])
    a = AssignedStream(corpus, idx, batch_size=4, seed=0)
    batch = a.next()
    # every sequence in the batch must come from the assigned set
    allowed, _ = corpus.sequences(idx)
    for row in batch["tokens"]:
        assert any((row == ar).all() for ar in allowed)


def test_chunk_indices_disjoint():
    a = chunk_indices(0, 100)
    b = chunk_indices(1, 100)
    assert len(np.intersect1d(a, b)) == 0
