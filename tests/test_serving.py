"""Continuous-batching serving engine: exactness + scheduling.

The engine must be a pure throughput optimization — greedy tokens
bit-identical to the one-shot ``baseline.generate`` path and routing
decisions identical to ``baseline.serve_batch`` — while admitting and
evicting requests mid-decode over fixed lane shapes."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import router as routerlib
from repro.models import model as modellib
from repro.serving import EngineConfig, MixtureServeEngine, SlotAllocator
from repro.serving import baseline
from repro.serving import cache as cachelib

ECFG = ModelConfig(name="srv-expert", n_layers=2, d_model=64, n_heads=4,
                   n_kv_heads=4, d_ff=128, vocab_size=128, ffn_type="gelu",
                   loss_chunk=32, compute_dtype="float32",
                   param_dtype="float32")
RCFG = ModelConfig(name="srv-router", n_layers=1, d_model=32, n_heads=2,
                   n_kv_heads=2, d_ff=64, vocab_size=128, ffn_type="gelu",
                   loss_chunk=32, compute_dtype="float32",
                   param_dtype="float32")
E, PREFIX, MAXLEN = 2, 16, 48


@pytest.fixture(scope="module")
def mixture():
    key = jax.random.PRNGKey(0)
    router_params = routerlib.init_ensemble(key, RCFG, E)
    expert_params = [modellib.init_params(jax.random.fold_in(key, e), ECFG)
                     for e in range(E)]
    return expert_params, router_params


def _engine(mixture, lanes=3, **kw):
    expert_params, router_params = mixture
    return MixtureServeEngine(
        ECFG, RCFG, expert_params, router_params,
        EngineConfig(lanes_per_expert=lanes, max_len=MAXLEN,
                     prefix_len=PREFIX, route_batch=4, **kw))


def _oracle(mixture, prompt, expert, n_new):
    """One-shot greedy reference with KV budget matched to the lanes."""
    expert_params, _ = mixture
    return baseline.generate(ECFG, expert_params[expert],
                             jnp.asarray(prompt[None]), n_new,
                             cache_len=MAXLEN)[0]


def test_engine_bitwise_matches_generate_and_serve_batch(mixture):
    """Equal-length prompts: tokens == generate, routes == serve_batch."""
    expert_params, router_params = mixture
    rng = np.random.default_rng(0)
    R, n_new = 9, 6
    prompts = rng.integers(0, ECFG.vocab_size, size=(R, PREFIX)).astype(np.int32)
    ref = baseline.serve_batch(ECFG, RCFG, expert_params, router_params,
                               prompts, prefix_len=PREFIX, n_new=n_new,
                               cache_len=MAXLEN)
    eng = _engine(mixture)
    for i in range(R):
        eng.submit(prompts[i], n_new)
    res = eng.run()
    assert len(res["requests"]) == R
    for r in res["requests"]:
        assert r.expert == ref["routes"][r.uid], r.uid
        np.testing.assert_array_equal(np.asarray(r.tokens),
                                      ref["tokens"][r.uid])


def test_mixed_prompt_lengths_use_padded_prefill(mixture):
    """Bucketed (right-padded) prefill must not change any token."""
    rng = np.random.default_rng(1)
    lens = rng.integers(PREFIX, 30, size=6)          # mostly non-bucket sizes
    prompts = [rng.integers(0, ECFG.vocab_size, size=l).astype(np.int32)
               for l in lens]
    n_new = rng.integers(2, 8, size=6)
    eng = _engine(mixture, lanes=2)
    assert eng.pad_safe                               # pure-attention config
    for i in range(6):
        eng.submit(prompts[i], int(n_new[i]))
    res = eng.run()
    for r in res["requests"]:
        want = _oracle(mixture, prompts[r.uid], r.expert, int(n_new[r.uid]))
        np.testing.assert_array_equal(np.asarray(r.tokens), want)


def test_staggered_arrival_slot_reuse_and_eviction(mixture):
    """More requests than lanes, arriving over time: slots must be
    reused mid-decode and every request still decodes exactly."""
    rng = np.random.default_rng(2)
    R, lanes = 8, 2
    prompts = rng.integers(0, ECFG.vocab_size, size=(R, PREFIX)).astype(np.int32)
    n_new = rng.integers(1, 10, size=R)               # includes 1-token runs
    eng = _engine(mixture, lanes=lanes)
    for i in range(R):
        eng.submit(prompts[i], int(n_new[i]), arrival_tick=i // 3)
    res = eng.run()
    assert len(res["requests"]) == R
    # every lane drained and returned to the free list
    for st in eng._experts:
        assert not st.active.any() and not st.pending
        assert st.alloc.n_free == lanes
    # with R > total lanes somebody had to wait for an eviction
    assert any(r.queue_ticks > 0 for r in res["requests"])
    served = sum(st.n_served for st in eng._experts)
    assert served == R                                # slots were reused
    for r in res["requests"]:
        assert len(r.tokens) == int(n_new[r.uid])
        want = _oracle(mixture, prompts[r.uid], r.expert, int(n_new[r.uid]))
        np.testing.assert_array_equal(np.asarray(r.tokens), want)


def test_decode_step_vector_cache_index_matches_scalar():
    """Per-slot (B,) cache_index must reproduce the scalar path exactly."""
    cfg = dataclasses.replace(ECFG, sliding_window=8)
    cfg2 = dataclasses.replace(cfg, stages=((("attn_local",), 2),))
    for c in (cfg, cfg2):                             # full + rotating caches
        params = modellib.init_params(jax.random.PRNGKey(3), c)
        toks = jax.random.randint(jax.random.PRNGKey(4), (2, 12), 0,
                                  c.vocab_size)
        _, c_s = modellib.prefill(params, c, {"tokens": toks}, cache_len=16)
        _, c_v = modellib.prefill(params, c, {"tokens": toks}, cache_len=16)
        nxt = jnp.array([[3], [5]], jnp.int32)
        pos = jnp.full((2, 1), 12, jnp.int32)
        lg_s, c_s = modellib.decode_step(params, c, {
            "tokens": nxt, "positions": pos,
            "cache_index": jnp.int32(12)}, c_s)
        lg_v, c_v = modellib.decode_step(params, c, {
            "tokens": nxt, "positions": pos,
            "cache_index": jnp.full((2,), 12, jnp.int32)}, c_v)
        np.testing.assert_array_equal(np.asarray(lg_s), np.asarray(lg_v))
        jax.tree_util.tree_map(
            lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                       np.asarray(b)),
            c_s, c_v)


def test_lane_cache_insert_and_release():
    """pos bookkeeping: empty lanes are -1, padded slots masked, release
    evicts exactly the freed lanes."""
    lanes, max_len, true_len = 3, 16, 5
    caches = cachelib.init_lane_caches(ECFG, lanes, max_len)
    pos_leaves = [l for p, l in jax.tree_util.tree_leaves_with_path(caches)
                  if cachelib._is_pos_leaf(p)]
    assert pos_leaves and all((np.asarray(l) == -1).all() for l in pos_leaves)

    params = modellib.init_params(jax.random.PRNGKey(5), ECFG)
    padded = jnp.zeros((1, 8), jnp.int32)             # 5 real + 3 pad tokens
    _, rcache = modellib.prefill(params, ECFG, {"tokens": padded},
                                 cache_len=max_len)
    caches = cachelib.insert_request(caches, rcache, 1, true_len)
    for pl in [l for p, l in jax.tree_util.tree_leaves_with_path(caches)
               if cachelib._is_pos_leaf(p)]:
        pl = np.asarray(pl)
        want = np.concatenate([np.arange(true_len),
                               np.full(max_len - true_len, -1)])
        assert (pl[:, 1] == want).all()               # pad slots masked
        assert (pl[:, [0, 2]] == -1).all()            # other lanes untouched

    freed = np.array([False, True, False])
    caches = cachelib.release_slots(caches, jnp.asarray(freed))
    for pl in [l for p, l in jax.tree_util.tree_leaves_with_path(caches)
               if cachelib._is_pos_leaf(p)]:
        assert (np.asarray(pl) == -1).all()


def test_slot_allocator():
    a = SlotAllocator(2)
    s0, s1 = a.alloc(), a.alloc()
    assert {s0, s1} == {0, 1} and a.alloc() is None and a.n_free == 0
    a.free(s0)
    assert a.n_free == 1 and a.alloc() == s0
    with pytest.raises(ValueError):
        a.free(7)


def test_out_of_order_arrival_ticks(mixture):
    """A late-submitted early arrival must not head-of-line-block, and
    idle gaps before a far-future arrival are fast-forwarded."""
    rng = np.random.default_rng(3)
    prompts = rng.integers(0, ECFG.vocab_size, size=(2, PREFIX)).astype(np.int32)
    eng = _engine(mixture, lanes=2)
    late = eng.submit(prompts[0], 2, arrival_tick=500)
    early = eng.submit(prompts[1], 2, arrival_tick=0)
    res = eng.run()
    assert len(res["requests"]) == 2
    assert early.admit_tick == 0                      # not blocked behind uid 0
    assert late.admit_tick >= 500
    assert res["steps"] < 50                          # idle gap skipped


def test_submit_validation(mixture):
    eng = _engine(mixture)
    with pytest.raises(ValueError):                   # prompt < routing prefix
        eng.submit(np.zeros(PREFIX - 1, np.int32), 4)
    with pytest.raises(ValueError):                   # exceeds lane budget
        eng.submit(np.zeros(PREFIX, np.int32), MAXLEN)
