"""Continuous-batching serving engine: exactness + scheduling.

The engine must be a pure throughput optimization — tokens bit-identical
to the one-shot ``baseline.generate`` path (greedy AND sampled: the
shared counter-based sampler keyed on ``(seed, uid, step)`` makes tokens
lane-placement-invariant) and routing decisions identical to
``baseline.serve_batch`` — while admitting and evicting requests
mid-decode over fixed lane shapes, with full-attention KV living in the
paged block pool (``serving/cache.py``).  Two fuzz sections run seeded
random workloads against the baseline oracle: ~50 greedy trials (prompt
lengths, token budgets, arrival ticks, pool pressure) and ~24 sampled
trials (random temperature / top-k / top-p / seeds / stop-token sets,
early-stop block reuse under pressure).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import router as routerlib
from repro.models import model as modellib
from repro.serving import (EngineConfig, ServeFrontend, SamplingParams,
                           SlotAllocator)
from repro.serving import baseline
from repro.serving import cache as cachelib

ECFG = ModelConfig(name="srv-expert", n_layers=2, d_model=64, n_heads=4,
                   n_kv_heads=4, d_ff=128, vocab_size=128, ffn_type="gelu",
                   loss_chunk=32, compute_dtype="float32",
                   param_dtype="float32")
RCFG = ModelConfig(name="srv-router", n_layers=1, d_model=32, n_heads=2,
                   n_kv_heads=2, d_ff=64, vocab_size=128, ffn_type="gelu",
                   loss_chunk=32, compute_dtype="float32",
                   param_dtype="float32")
E, PREFIX, MAXLEN, BS = 2, 16, 48, 16
FULL_POOL = 0          # EngineConfig: 0 -> lanes * max_len / block_size


@pytest.fixture(scope="module")
def mixture():
    key = jax.random.PRNGKey(0)
    router_params = routerlib.init_ensemble(key, RCFG, E)
    expert_params = [modellib.init_params(jax.random.fold_in(key, e), ECFG)
                     for e in range(E)]
    return expert_params, router_params


def _engine(mixture, lanes=3, ecfg=ECFG, **kw):
    expert_params, router_params = mixture
    kw.setdefault("route_batch", 4)
    return ServeFrontend(
        ecfg, RCFG, expert_params, router_params,
        EngineConfig(lanes_per_expert=lanes, max_len=MAXLEN,
                     prefix_len=PREFIX, block_size=BS, **kw))


def _oracle(mixture, prompt, expert, n_new, ecfg=ECFG, sampling=None,
            uid=0, stop_tokens=()):
    """One-shot reference with KV budget matched to the lanes."""
    expert_params, _ = mixture
    return baseline.generate_request(ecfg, expert_params[expert], prompt,
                                     n_new, sampling=sampling, uid=uid,
                                     stop_tokens=stop_tokens,
                                     cache_len=MAXLEN)


def test_engine_bitwise_matches_generate_and_serve_batch(mixture):
    """Equal-length prompts: tokens == generate, routes == serve_batch."""
    expert_params, router_params = mixture
    rng = np.random.default_rng(0)
    R, n_new = 9, 6
    prompts = rng.integers(0, ECFG.vocab_size, size=(R, PREFIX)).astype(np.int32)
    ref = baseline.serve_batch(ECFG, RCFG, expert_params, router_params,
                               prompts, prefix_len=PREFIX, n_new=n_new,
                               cache_len=MAXLEN)
    eng = _engine(mixture)
    for i in range(R):
        eng.submit(prompts[i], n_new)
    res = eng.run()
    assert len(res["requests"]) == R
    for r in res["requests"]:
        assert r.expert == ref["routes"][r.uid], r.uid
        np.testing.assert_array_equal(np.asarray(r.tokens),
                                      ref["tokens"][r.uid])


def test_mixed_prompt_lengths_use_padded_prefill(mixture):
    """Bucketed (right-padded) prefill must not change any token."""
    rng = np.random.default_rng(1)
    lens = rng.integers(PREFIX, 30, size=6)          # mostly non-bucket sizes
    prompts = [rng.integers(0, ECFG.vocab_size, size=l).astype(np.int32)
               for l in lens]
    n_new = rng.integers(2, 8, size=6)
    eng = _engine(mixture, lanes=2)
    assert eng.pad_safe                               # pure-attention config
    for i in range(6):
        eng.submit(prompts[i], int(n_new[i]))
    res = eng.run()
    for r in res["requests"]:
        want = _oracle(mixture, prompts[r.uid], r.expert, int(n_new[r.uid]))
        np.testing.assert_array_equal(np.asarray(r.tokens), want)


def test_staggered_arrival_slot_reuse_and_eviction(mixture):
    """More requests than lanes, arriving over time: slots and pool blocks
    must be reused mid-decode and every request still decodes exactly."""
    rng = np.random.default_rng(2)
    R, lanes = 8, 2
    prompts = rng.integers(0, ECFG.vocab_size, size=(R, PREFIX)).astype(np.int32)
    n_new = rng.integers(1, 10, size=R)               # includes 1-token runs
    eng = _engine(mixture, lanes=lanes)
    for i in range(R):
        eng.submit(prompts[i], int(n_new[i]), arrival_tick=i // 3)
    res = eng.run()
    assert len(res["requests"]) == R
    # every lane drained, block tables cleared, free lists whole again
    # (the prefix cache may retain blocks — each accounted by one ref)
    for st in eng._experts:
        assert not st.active.any() and not st.pending
        assert st.alloc.n_free == lanes
        assert st.balloc.n_in_use == st.cached_blocks
        assert (st.block_tables == -1).all()
    # with R > total lanes somebody had to wait for an eviction
    assert any(r.queue_ticks > 0 for r in res["requests"])
    served = sum(st.n_served for st in eng._experts)
    assert served == R                                # slots were reused
    for r in res["requests"]:
        assert len(r.tokens) == int(n_new[r.uid])
        want = _oracle(mixture, prompts[r.uid], r.expert, int(n_new[r.uid]))
        np.testing.assert_array_equal(np.asarray(r.tokens), want)


def test_decode_step_vector_cache_index_matches_scalar():
    """Per-slot (B,) cache_index must reproduce the scalar path exactly."""
    cfg = dataclasses.replace(ECFG, sliding_window=8)
    cfg2 = dataclasses.replace(cfg, stages=((("attn_local",), 2),))
    for c in (cfg, cfg2):                             # full + rotating caches
        params = modellib.init_params(jax.random.PRNGKey(3), c)
        toks = jax.random.randint(jax.random.PRNGKey(4), (2, 12), 0,
                                  c.vocab_size)
        _, c_s = modellib.prefill(params, c, {"tokens": toks}, cache_len=16)
        _, c_v = modellib.prefill(params, c, {"tokens": toks}, cache_len=16)
        nxt = jnp.array([[3], [5]], jnp.int32)
        pos = jnp.full((2, 1), 12, jnp.int32)
        lg_s, c_s = modellib.decode_step(params, c, {
            "tokens": nxt, "positions": pos,
            "cache_index": jnp.int32(12)}, c_s)
        lg_v, c_v = modellib.decode_step(params, c, {
            "tokens": nxt, "positions": pos,
            "cache_index": jnp.full((2,), 12, jnp.int32)}, c_v)
        np.testing.assert_array_equal(np.asarray(lg_s), np.asarray(lg_v))
        jax.tree_util.tree_map(
            lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                       np.asarray(b)),
            c_s, c_v)


def test_paged_decode_matches_dense_decode():
    """Block-table decode must reproduce the dense-slab path bit-for-bit.

    Two requests prefilled into (a) a dense per-lane cache driven with
    vector cache_index and (b) the paged pool via insert_requests +
    block_tables; one decode step must give identical logits, and the
    token written through the block table must land in the mapped block.
    """
    lanes, n_blocks = 2, 7
    params = modellib.init_params(jax.random.PRNGKey(5), ECFG)
    toks = jax.random.randint(jax.random.PRNGKey(6), (lanes, 12), 0,
                              ECFG.vocab_size)
    _, dense = modellib.prefill(params, ECFG, {"tokens": toks},
                                cache_len=MAXLEN)
    _, reqc = modellib.prefill(params, ECFG, {"tokens": toks},
                               cache_len=MAXLEN)
    paged = cachelib.init_paged_caches(ECFG, lanes, n_blocks, BS, MAXLEN)
    # non-contiguous, per-lane-disjoint block reservations
    rows = np.array([[2, 5, 0], [4, 1, 6]], np.int32)
    paged = cachelib.insert_requests(
        ECFG, paged, reqc, rows, np.arange(lanes, dtype=np.int32),
        np.full(lanes, 12, np.int32))
    nxt = jnp.array([[3], [5]], jnp.int32)
    pos = jnp.full((lanes, 1), 12, jnp.int32)
    ci = jnp.full((lanes,), 12, jnp.int32)
    lg_d, _ = modellib.decode_step(params, ECFG, {
        "tokens": nxt, "positions": pos, "cache_index": ci}, dense)
    lg_p, newp = modellib.decode_step(params, ECFG, {
        "tokens": nxt, "positions": pos, "cache_index": ci,
        "block_tables": jnp.asarray(rows)}, paged)
    np.testing.assert_array_equal(np.asarray(lg_d), np.asarray(lg_p))
    # position 12 of lane 0 lives in block rows[0][12 // BS] at offset 12
    for path, leaf in jax.tree_util.tree_leaves_with_path(newp):
        if cachelib._is_pos_leaf(path):
            leaf = np.asarray(leaf)
            assert (leaf[:, rows[0][0], 12] == 12).all()
            assert (leaf[:, rows[1][0], 12] == 12).all()


def test_insert_requests_masks_padding_and_isolates_blocks():
    """Pool pos bookkeeping: prompt-pad slots masked to -1, reserved growth
    blocks cleared, unreserved rows land in scratch, other blocks kept."""
    lanes, n_blocks, true_len = 2, 5, 5
    caches = cachelib.init_paged_caches(ECFG, lanes, n_blocks, BS, MAXLEN)
    params = modellib.init_params(jax.random.PRNGKey(7), ECFG)
    padded = jnp.zeros((1, 16), jnp.int32)            # 5 real + 11 pad tokens
    _, rcache = modellib.prefill(params, ECFG, {"tokens": padded},
                                 cache_len=MAXLEN)
    # poison block 3 so we can verify untouched blocks stay untouched and
    # a reused block is fully overwritten by the next insert
    caches = jax.tree_util.tree_map_with_path(
        lambda p, l: l.at[:, 3].set(7) if cachelib._is_pos_leaf(p) else l,
        caches)
    rows = np.array([[1, 4, -1]], np.int32)           # 2 reserved of 3 rows
    caches = cachelib.insert_requests(ECFG, caches, rcache, rows,
                                      np.zeros(1, np.int32),
                                      np.full(1, true_len, np.int32))
    for path, leaf in jax.tree_util.tree_leaves_with_path(caches):
        if not cachelib._is_pos_leaf(path):
            continue
        leaf = np.asarray(leaf)
        want = np.concatenate([np.arange(true_len),
                               np.full(BS - true_len, -1)])
        assert (leaf[:, 1] == want).all()             # data block, pads masked
        assert (leaf[:, 4] == -1).all()               # growth block cleared
        assert (leaf[:, 3] == 7).all()                # unrelated block kept
        assert (leaf[:, [0, 2]] == -1).all()


def test_slot_allocator():
    a = SlotAllocator(2)
    s0, s1 = a.alloc(), a.alloc()
    assert {s0, s1} == {0, 1} and a.alloc() is None and a.n_free == 0
    a.free(s0)
    assert a.n_free == 1 and a.alloc() == s0
    with pytest.raises(ValueError):
        a.free(7)


def test_out_of_order_arrival_ticks(mixture):
    """A late-submitted early arrival must not head-of-line-block, and
    idle gaps before a far-future arrival are fast-forwarded."""
    rng = np.random.default_rng(3)
    prompts = rng.integers(0, ECFG.vocab_size, size=(2, PREFIX)).astype(np.int32)
    eng = _engine(mixture, lanes=2)
    late = eng.submit(prompts[0], 2, arrival_tick=500)
    early = eng.submit(prompts[1], 2, arrival_tick=0)
    res = eng.run()
    assert len(res["requests"]) == 2
    assert early.admit_tick == 0                      # not blocked behind uid 0
    assert late.admit_tick >= 500
    assert res["steps"] < 50                          # idle gap skipped


def test_submit_validation(mixture):
    eng = _engine(mixture)
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit(np.zeros((0,), np.int32), 4)
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit([], 4)
    with pytest.raises(ValueError):                   # prompt < routing prefix
        eng.submit(np.zeros(PREFIX - 1, np.int32), 4)
    with pytest.raises(ValueError):                   # exceeds lane budget
        eng.submit(np.zeros(PREFIX, np.int32), MAXLEN)


def test_engine_config_validation(mixture):
    expert_params, router_params = mixture
    with pytest.raises(ValueError, match="multiple"):
        ServeFrontend(ECFG, RCFG, expert_params, router_params,
                           EngineConfig(max_len=MAXLEN + 1, block_size=BS,
                                        prefix_len=PREFIX))
    with pytest.raises(ValueError, match="deadlock"):
        # pool cannot hold even one max-size request
        ServeFrontend(ECFG, RCFG, expert_params, router_params,
                           EngineConfig(max_len=MAXLEN, block_size=BS,
                                        prefix_len=PREFIX,
                                        pool_blocks=MAXLEN // BS - 1))
    with pytest.raises(ValueError, match="min_prefill_bucket"):
        # a 0 bucket would loop forever in bucket_len at admission time
        ServeFrontend(ECFG, RCFG, expert_params, router_params,
                           EngineConfig(max_len=MAXLEN, block_size=BS,
                                        prefix_len=PREFIX,
                                        min_prefill_bucket=0))
    with pytest.raises(ValueError, match="decode_impl"):
        ServeFrontend(ECFG, RCFG, expert_params, router_params,
                           EngineConfig(max_len=MAXLEN, block_size=BS,
                                        prefix_len=PREFIX,
                                        decode_impl="triton"))
    # archs with no full-attention KV have no pool: block alignment is
    # irrelevant and must not be enforced
    key = jax.random.PRNGKey(13)
    ssm_params = [modellib.init_params(jax.random.fold_in(key, e), SSM_CFG)
                  for e in range(E)]
    eng = ServeFrontend(SSM_CFG, RCFG, ssm_params, router_params,
                             EngineConfig(max_len=MAXLEN + 1, block_size=BS,
                                          prefix_len=PREFIX))
    assert not eng.has_pool


def test_route_batch_one_skips_padding(mixture):
    """route_batch=1 must route identically without the padded-copies path."""
    expert_params, router_params = mixture
    rng = np.random.default_rng(4)
    prompts = rng.integers(0, ECFG.vocab_size, size=(3, PREFIX)).astype(np.int32)
    want = baseline.route(RCFG, router_params, prompts, PREFIX)
    eng = _engine(mixture, lanes=2, route_batch=1)
    reqs = [eng.submit(p, 2) for p in prompts]
    eng.run()
    assert [r.expert for r in reqs] == want.tolist()


def test_batched_admission_prefill_call_budget(mixture):
    """k simultaneous arrivals must cost <= ceil(k_e / lanes) prefill calls
    per expert — batched admission, not one prefill per request."""
    lanes, R, n_new = 2, 8, 4
    rng = np.random.default_rng(5)
    prompts = rng.integers(0, ECFG.vocab_size, size=(R, PREFIX)).astype(np.int32)
    eng = _engine(mixture, lanes=lanes)
    for i in range(R):
        eng.submit(prompts[i], n_new, arrival_tick=0)  # all arrive at once
    res = eng.run()
    assert len(res["requests"]) == R
    total = 0
    for e, st in enumerate(eng._experts):
        k_e = sum(1 for r in res["requests"] if r.expert == e)
        assert st.prefill_calls <= -(-k_e // lanes), (e, k_e, st.prefill_calls)
        total += st.prefill_calls
    assert res["prefill_calls"] == total
    for r in res["requests"]:
        want = _oracle(mixture, prompts[r.uid], r.expert, n_new)
        np.testing.assert_array_equal(np.asarray(r.tokens), want)


def test_paged_pool_uses_less_memory_than_dense_slab(mixture):
    """At pool utilization < 1 the paged cache must hold strictly less KV
    than the dense (lanes, max_len) slab layout."""
    lanes = 3
    dense_bytes = cachelib.kv_cache_bytes(
        modellib.cache_specs(ECFG, lanes, MAXLEN))
    full = lanes * MAXLEN // BS
    eng = _engine(mixture, lanes=lanes, pool_blocks=full - 2)
    assert eng.kv_bytes_per_expert() < dense_bytes
    # and the pool still serves a full workload exactly
    rng = np.random.default_rng(6)
    prompts = rng.integers(0, ECFG.vocab_size, size=(5, PREFIX)).astype(np.int32)
    for i in range(5):
        eng.submit(prompts[i], 4)
    res = eng.run()
    assert len(res["requests"]) == 5
    for r in res["requests"]:
        want = _oracle(mixture, prompts[r.uid], r.expert, 4)
        np.testing.assert_array_equal(np.asarray(r.tokens), want)
    peak = max(st.balloc.peak_in_use for st in eng._experts)
    assert peak <= full - 2


# ---------------------------------------------------------------------------
# Randomized fuzz oracle: ~50 seeded trials vs the one-shot baseline
# ---------------------------------------------------------------------------
N_FUZZ_TRIALS = 50


@pytest.mark.parametrize("seed", range(N_FUZZ_TRIALS))
def test_fuzz_engine_matches_baseline(mixture, seed):
    """Random prompt lengths, token budgets, and arrival ticks: engine
    tokens, routing, and per-request expert assignment must be
    bit-identical to the serial baseline — including under deliberate
    block-pool pressure (pool < lanes * max_len / block_size)."""
    rng = np.random.default_rng(1000 + seed)
    lanes = 2
    full = lanes * MAXLEN // BS
    # half the trials squeeze the pool to force admission to wait on blocks
    pool = FULL_POOL if seed % 2 == 0 else MAXLEN // BS + 1
    R = int(rng.integers(3, 6))
    prompts = [rng.integers(0, ECFG.vocab_size,
                            size=int(rng.integers(PREFIX, 33))).astype(np.int32)
               for _ in range(R)]
    n_new = [int(rng.integers(1, 7)) for _ in range(R)]
    arrivals = [int(rng.integers(0, 7)) for _ in range(R)]
    eng = _engine(mixture, lanes=lanes, pool_blocks=pool)
    for i in range(R):
        eng.submit(prompts[i], n_new[i], arrival_tick=arrivals[i])
    res = eng.run()
    assert len(res["requests"]) == R
    if pool != FULL_POOL:
        assert max(st.balloc.peak_in_use
                   for st in eng._experts) <= pool < full
    expert_params, router_params = mixture
    want_routes = baseline.route(
        RCFG, router_params,
        np.stack([p[:PREFIX] for p in prompts]), PREFIX)
    for r in res["requests"]:
        assert r.expert == want_routes[r.uid], (seed, r.uid)
        want = _oracle(mixture, prompts[r.uid], r.expert, n_new[r.uid])
        np.testing.assert_array_equal(
            np.asarray(r.tokens), want,
            err_msg=f"seed {seed} uid {r.uid} pool {pool}")
    for st in eng._experts:                   # no leaks, trial after trial
        assert st.balloc.n_in_use == st.cached_blocks
        assert st.alloc.n_free == lanes


# ---------------------------------------------------------------------------
# SamplingParams / stop conditions / streaming (the generation API)
# ---------------------------------------------------------------------------
def test_sampling_params_validation():
    assert SamplingParams().greedy
    assert not SamplingParams(temperature=0.5).greedy
    for bad in (dict(temperature=-0.1), dict(top_k=-1), dict(top_p=0.0),
                dict(top_p=1.5), dict(seed=-1)):
        with pytest.raises(ValueError):
            SamplingParams(**bad)


def test_submit_rejects_bad_sampling_and_stops(mixture):
    eng = _engine(mixture)
    p = np.zeros(PREFIX, np.int32)
    with pytest.raises(TypeError, match="SamplingParams"):
        eng.submit(p, 4, sampling=0.7)
    with pytest.raises(ValueError, match="outside vocab"):
        eng.submit(p, 4, stop_tokens={ECFG.vocab_size})
    with pytest.raises(ValueError, match="outside vocab"):
        eng.submit(p, 4, stop_tokens={-1})


def _fresh_index(tokens) -> int | None:
    """First MID-sequence position whose token value never occurred
    earlier — a stop token on it makes the request end exactly there,
    strictly before the budget (None if the rollout is a constant loop,
    which tiny random models do produce)."""
    tokens = np.asarray(tokens)
    for j in range(1, len(tokens) - 1):
        if tokens[j] not in tokens[:j]:
            return j
    return None


def _prompt_with_fresh_token(mixture, rng, n_new, route_to=None):
    """A (prompt, greedy rollout, fresh index) triple, scanning random
    prompts until the rollout has a mid-sequence stop candidate."""
    _, router_params = mixture
    for _ in range(40):
        prompt = rng.integers(0, ECFG.vocab_size, size=PREFIX).astype(np.int32)
        e = int(baseline.route(RCFG, router_params, prompt[None], PREFIX)[0])
        if route_to is not None and e != route_to:
            continue
        want = _oracle(mixture, prompt, e, n_new)
        j = _fresh_index(want)
        if j is not None:
            return prompt, e, want, j
    pytest.skip("no prompt with a mid-sequence fresh token found")


def test_stop_token_ends_request_early(mixture):
    """A stop token finishes the request the tick it is emitted, keeps it
    as the final token, and records the finish reason."""
    rng = np.random.default_rng(21)
    prompt, _, want, j = _prompt_with_fresh_token(mixture, rng, 8)
    eng = _engine(mixture, lanes=2)
    req = eng.submit(prompt, 8, stop_tokens={int(want[j])})
    eng.run()
    assert req.finish_reason == "stop_token"
    assert len(req.tokens) == j + 1 < 8
    np.testing.assert_array_equal(np.asarray(req.tokens), want[:j + 1])
    # a stop token sampled from the PREFILL logits finishes at admission
    eng2 = _engine(mixture, lanes=2)
    req2 = eng2.submit(prompt, 8, stop_tokens={int(want[0])})
    eng2.run()
    assert req2.tokens == [int(want[0])]
    assert req2.finish_reason == "stop_token"
    assert req2.finish_tick == req2.admit_tick


def test_early_stop_frees_blocks_same_tick_under_pool_pressure(mixture):
    """Satellite: a request that stops early must release its KV blocks
    the same tick, and a request queued on those blocks must be admitted
    at the very next admission pass."""
    _, router_params = mixture
    rng = np.random.default_rng(22)
    n_new = 8                       # needs ceil((16+8-1)/16) = 2 blocks
    pA, e, want, j = _prompt_with_fresh_token(mixture, rng, n_new)
    pB = None
    for _ in range(40):             # co-locate B on A's expert
        cand = rng.integers(0, ECFG.vocab_size, size=PREFIX).astype(np.int32)
        if int(baseline.route(RCFG, router_params, cand[None], PREFIX)[0]) == e:
            pB = cand
            break
    assert pB is not None
    # pool of 3 blocks (the config minimum for max_len 48): A's 2-block
    # reservation starves B until A ends, even though a lane is free
    eng = _engine(mixture, lanes=2, pool_blocks=MAXLEN // BS)
    A = eng.submit(pA, n_new, stop_tokens={int(want[j])})
    B = eng.submit(pB, n_new)
    st = eng._experts[e]
    done: list = []
    while not A.done:
        done = eng.step()
    assert A in done
    # the tick A stopped, its blocks are already back in the pool (B has
    # not been admitted yet, so only the prefix cache may retain any)
    assert not B.done and B.admit_tick < 0
    assert st.balloc.n_in_use == st.cached_blocks
    assert A.finish_reason == "stop_token" and len(A.tokens) == j + 1
    eng.run()
    assert B.admit_tick == A.finish_tick + 1      # admitted with A's blocks
    np.testing.assert_array_equal(np.asarray(B.tokens),
                                  _oracle(mixture, pB, e, n_new))


def test_stream_yields_every_token_in_order(mixture):
    """stream() must deliver one delta per emitted token, in tick order,
    with done exactly on each request's final token."""
    rng = np.random.default_rng(23)
    prompts = [rng.integers(0, ECFG.vocab_size,
                            size=int(rng.integers(PREFIX, 30))).astype(np.int32)
               for _ in range(5)]
    eng = _engine(mixture, lanes=2)
    reqs = [eng.submit(prompts[i], int(rng.integers(1, 7)),
                       sampling=SamplingParams(temperature=0.9, seed=i)
                       if i % 2 else None,
                       arrival_tick=i // 2)
            for i in range(5)]
    got = {r.uid: [] for r in reqs}
    done_seen = set()
    last_tick = -1
    for d in eng.stream():
        assert d.tick >= last_tick
        last_tick = d.tick
        assert d.request.uid not in done_seen, "token after done"
        assert d.index == len(got[d.request.uid])
        got[d.request.uid].append(d.token)
        if d.done:
            done_seen.add(d.request.uid)
    assert not eng.busy
    assert eng._t0 is None       # clock origin reset for a later run()
    for r in reqs:
        assert r.uid in done_seen
        assert got[r.uid] == r.tokens
        want = _oracle(mixture, prompts[r.uid], r.expert, r.max_new_tokens,
                       sampling=r.sampling, uid=r.uid)
        np.testing.assert_array_equal(np.asarray(got[r.uid]), want)


# ---------------------------------------------------------------------------
# Sampled-mode fuzz oracle: engine == baseline under random SamplingParams,
# stop sets, arrival ticks, and pool pressure
# ---------------------------------------------------------------------------
N_SAMPLED_TRIALS = 24


def _random_sampling(rng) -> SamplingParams:
    if rng.random() < 0.25:
        return SamplingParams()                       # greedy rides along
    return SamplingParams(
        temperature=float(np.round(rng.uniform(0.2, 1.5), 3)),
        top_k=int(rng.choice([0, 1, 2, 5, 16])),
        top_p=float(np.round(rng.choice([1.0, rng.uniform(0.3, 0.99)]), 3)),
        seed=int(rng.integers(0, 2 ** 20)))


@pytest.mark.parametrize("seed", range(N_SAMPLED_TRIALS))
def test_fuzz_sampled_engine_matches_baseline(mixture, seed):
    """Per-request random sampling recipes + stop sets: engine tokens must
    be bit-identical to the baseline run with the same (seed, uid) RNG
    stream, stop truncation included — also under block-pool pressure,
    where early stops put blocks back for waiting requests."""
    rng = np.random.default_rng(5000 + seed)
    lanes = 2
    pool = FULL_POOL if seed % 2 == 0 else MAXLEN // BS + 1
    R = int(rng.integers(3, 6))
    prompts = [rng.integers(0, ECFG.vocab_size,
                            size=int(rng.integers(PREFIX, 33))).astype(np.int32)
               for _ in range(R)]
    n_new = [int(rng.integers(2, 8)) for _ in range(R)]
    arrivals = [int(rng.integers(0, 7)) for _ in range(R)]
    sps = [_random_sampling(rng) for _ in range(R)]
    stops = [frozenset(int(t) for t in
                       rng.integers(0, ECFG.vocab_size,
                                    size=int(rng.integers(4, 40))))
             if rng.random() < 0.5 else frozenset() for _ in range(R)]
    eng = _engine(mixture, lanes=lanes, pool_blocks=pool)
    reqs = [eng.submit(prompts[i], n_new[i], sampling=sps[i],
                       stop_tokens=stops[i], arrival_tick=arrivals[i])
            for i in range(R)]
    res = eng.run()
    assert len(res["requests"]) == R
    for r in res["requests"]:
        want = _oracle(mixture, prompts[r.uid], r.expert, n_new[r.uid],
                       sampling=sps[r.uid], uid=r.uid,
                       stop_tokens=stops[r.uid])
        np.testing.assert_array_equal(
            np.asarray(r.tokens), want,
            err_msg=f"seed {seed} uid {r.uid} {sps[r.uid]} pool {pool}")
        stopped = len(r.tokens) < n_new[r.uid]
        assert r.finish_reason == ("stop_token" if stopped or
                                   (r.tokens and r.tokens[-1] in stops[r.uid])
                                   else "length")
        if stopped:
            assert r.tokens[-1] in stops[r.uid]
    assert res["early_stops"] == sum(r.finish_reason == "stop_token"
                                     for r in reqs)
    for st in eng._experts:                   # no leaks, trial after trial
        assert st.balloc.n_in_use == st.cached_blocks
        assert st.alloc.n_free == lanes


def test_engine_decode_impl_pallas_matches_baseline(mixture):
    """Satellite: decode_impl='pallas' swaps the paged decode read for
    the block-table Pallas kernel (interpret-mode on CPU) — tokens must
    still match the baseline oracle exactly, greedy and sampled mixed,
    and the read-traffic stats must show the paged win."""
    rng = np.random.default_rng(41)
    R = 4
    prompts = [rng.integers(0, ECFG.vocab_size,
                            size=int(rng.integers(PREFIX, 30))).astype(np.int32)
               for _ in range(R)]
    n_new = [int(rng.integers(2, 7)) for _ in range(R)]
    sps = [None if i % 2 == 0 else
           SamplingParams(temperature=0.9, top_k=8, seed=60 + i)
           for i in range(R)]
    eng = _engine(mixture, lanes=2, decode_impl="pallas")
    assert eng.decode_impl == "pallas"
    for i in range(R):
        eng.submit(prompts[i], n_new[i], sampling=sps[i])
    res = eng.run()
    assert len(res["requests"]) == R
    assert res["decode_impl"] == "pallas"
    for r in res["requests"]:
        want = _oracle(mixture, prompts[r.uid], r.expert, n_new[r.uid],
                       sampling=sps[r.uid], uid=r.uid)
        np.testing.assert_array_equal(np.asarray(r.tokens), want)
    rb = res["decode_read_bytes"]
    assert 0 < rb["paged"] < rb["gathered"]


def test_lane_placement_invariance(mixture):
    """The RNG stream is a pure function of (seed, uid, step): the same
    request samples identical tokens decoding alone on a fresh engine or
    squeezed between other active sampled lanes — uid 0 both times, so
    the two engine runs must agree with each other (and the oracle)."""
    rng = np.random.default_rng(31)
    prompt = rng.integers(0, ECFG.vocab_size, size=PREFIX).astype(np.int32)
    sp = SamplingParams(temperature=1.1, top_k=12, seed=77)
    eng = _engine(mixture, lanes=3)
    solo = eng.submit(prompt, 6, sampling=sp)             # uid 0, empty engine
    eng.run()
    eng2 = _engine(mixture, lanes=3)
    crowd = eng2.submit(prompt, 6, sampling=sp)           # uid 0, crowded
    for _ in range(2):
        eng2.submit(rng.integers(0, ECFG.vocab_size, size=PREFIX)
                    .astype(np.int32), 6,
                    sampling=SamplingParams(temperature=0.9, seed=5))
    eng2.run()
    assert crowd.tokens == solo.tokens
    want = _oracle(mixture, prompt, solo.expert, 6, sampling=sp, uid=solo.uid)
    np.testing.assert_array_equal(np.asarray(solo.tokens), want)


# ---------------------------------------------------------------------------
# Prefix-sharing fuzz: shared system prompts, chunked suffix replay, and
# cache pressure — tokens must stay bitwise identical to the oracle
# ---------------------------------------------------------------------------
N_PREFIX_TRIALS = 12


@pytest.mark.parametrize("seed", range(N_PREFIX_TRIALS))
def test_fuzz_shared_prefix_matches_baseline(mixture, seed):
    """Every request opens with the same "system prompt": admissions
    after the first per expert take the cached leading blocks and replay
    only the novel suffix through the decode path (chunked when
    ``prefill_chunk_tokens`` is small — odd trials use 1- and 3-token
    chunks, so one admission spans many ticks).  Tokens must stay
    bitwise identical to the one-shot oracle — greedy and sampled mixed,
    stop sets included — under full pools AND block pressure (where the
    cache itself must be evicted to admit), and the run must report real
    cache traffic (saved prefill tokens > 0: the shared head routes
    every request to one expert, whose lanes are outnumbered)."""
    rng = np.random.default_rng(7000 + seed)
    lanes = 2
    pool = FULL_POOL if seed % 2 == 0 else MAXLEN // BS + 2
    chunk = int(rng.choice([0, 1, 3, BS]))    # 0 = whole suffix in one tick
    sys_len = int(rng.choice([BS, BS + 5, 2 * BS]))
    system = rng.integers(0, ECFG.vocab_size, size=sys_len).astype(np.int32)
    R = int(rng.integers(4, 7))
    prompts, n_new, sps, stops = [], [], [], []
    for _ in range(R):
        tail = rng.integers(0, ECFG.vocab_size,
                            size=int(rng.integers(1, 13))).astype(np.int32)
        prompts.append(np.concatenate([system, tail]))
        n_new.append(int(min(rng.integers(1, 7),
                             MAXLEN - len(prompts[-1]))))
        sps.append(_random_sampling(rng))
        stops.append(frozenset(
            int(t) for t in rng.integers(0, ECFG.vocab_size, size=8))
            if rng.random() < 0.4 else frozenset())
    eng = _engine(mixture, lanes=lanes, pool_blocks=pool,
                  prefill_chunk_tokens=chunk)
    reqs = [eng.submit(prompts[i], n_new[i], sampling=sps[i],
                       stop_tokens=stops[i],
                       arrival_tick=int(rng.integers(0, 4)))
            for i in range(R)]
    res = eng.run()
    assert len(res["requests"]) == R
    for r in res["requests"]:
        want = _oracle(mixture, prompts[r.uid], r.expert, n_new[r.uid],
                       sampling=sps[r.uid], uid=r.uid,
                       stop_tokens=stops[r.uid])
        np.testing.assert_array_equal(
            np.asarray(r.tokens), want,
            err_msg=f"seed {seed} uid {r.uid} chunk {chunk} pool {pool}")
    ps = res["prefix_sharing"]
    assert ps["enabled"]
    # the identical PREFIX-token head routes all R requests to ONE
    # expert with 2 lanes, so at least one admission found the system
    # prompt's leading block(s) cached
    assert len({r.expert for r in reqs}) == 1
    assert ps["hit_blocks"] > 0 and ps["prefill_tokens_saved"] > 0
    assert ps["prefill_tokens_saved"] == BS * ps["hit_blocks"]
    assert res["n_unadmitted"] == 0           # run() drains everything
    for st in eng._experts:                   # no leaks, trial after trial
        assert st.balloc.n_in_use == st.cached_blocks
        assert st.alloc.n_free == lanes


def test_prefix_cache_off_still_matches_baseline(mixture):
    """``prefix_cache=False`` is the paranoia escape hatch: same shared-
    prompt workload, zero cache traffic, tokens still oracle-exact."""
    rng = np.random.default_rng(7777)
    system = rng.integers(0, ECFG.vocab_size, size=2 * BS).astype(np.int32)
    prompts = [np.concatenate([system, rng.integers(
        0, ECFG.vocab_size, size=4 + i).astype(np.int32)]) for i in range(4)]
    eng = _engine(mixture, lanes=2, prefix_cache=False)
    for i, p in enumerate(prompts):
        eng.submit(p, 4)
    res = eng.run()
    ps = res["prefix_sharing"]
    assert not ps["enabled"]
    assert ps["hit_blocks"] == 0 == ps["prefill_tokens_saved"]
    assert all(st.cached_blocks == 0 for st in eng._experts)
    for r in res["requests"]:
        want = _oracle(mixture, prompts[r.uid], r.expert, 4)
        np.testing.assert_array_equal(np.asarray(r.tokens), want)


def test_n_unadmitted_counts_requests_without_a_lane(mixture):
    """Satellite: requests still waiting for a lane (queued on arrival
    tick or on pool blocks) show up in ``n_unadmitted`` mid-run, keeping
    them out of the queue-wait aggregates, and drop to 0 once drained."""
    rng = np.random.default_rng(88)
    system = rng.integers(0, ECFG.vocab_size, size=PREFIX).astype(np.int32)
    mk = lambda n: np.concatenate(
        [system, rng.integers(0, ECFG.vocab_size, size=n).astype(np.int32)])
    # one lane, minimal legal pool: B cannot be admitted while A decodes
    eng = _engine(mixture, lanes=1, pool_blocks=MAXLEN // BS)
    a = eng.submit(mk(8), 6, arrival_tick=0)
    b = eng.submit(mk(4), 2, arrival_tick=0)
    late = eng.submit(mk(2), 1, arrival_tick=10 ** 6)   # far-future arrival
    assert eng.n_unadmitted == 3              # nothing routed yet
    eng.step()
    assert a.admit_tick >= 0 and b.admit_tick < 0
    assert eng.n_unadmitted == 2              # b (pool), late (arrival)
    while b.admit_tick < 0:
        eng.step()
    assert eng.n_unadmitted == 1              # only the far-future one
    res = eng.run()
    assert res["n_unadmitted"] == 0
    assert [len(r.tokens) for r in (a, b, late)] == [6, 2, 1]


# ---------------------------------------------------------------------------
# Non-pad-safe archs: exact-length prefill fallback (SSM / xLSTM)
# ---------------------------------------------------------------------------
_NPS_BASE = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
                 vocab_size=128, ffn_type="gelu", loss_chunk=32,
                 compute_dtype="float32", param_dtype="float32")
SSM_CFG = ModelConfig(name="srv-ssm", stages=((("mamba2",), 2),),
                      ssm_headdim=32, ssm_state=16, **_NPS_BASE)
XLSTM_CFG = ModelConfig(name="srv-xlstm", stages=((("slstm",), 2),),
                        **_NPS_BASE)
HYBRID_CFG = ModelConfig(name="srv-hybrid", stages=((("attn", "mamba2"), 1),),
                         ssm_headdim=32, ssm_state=16, **_NPS_BASE)


@pytest.mark.parametrize("ecfg", [SSM_CFG, XLSTM_CFG, HYBRID_CFG],
                         ids=["mamba2", "slstm", "hybrid"])
def test_non_pad_safe_archs_match_baseline(mixture, ecfg):
    """SSM and xLSTM lane state cannot absorb right-padding: the engine
    must fall back to exact-length prefill and still match the one-shot
    baseline token-for-token — greedy and sampled requests mixed, so the
    per-request fallback samples first tokens with per-row params (the
    hybrid case also exercises paged full-attention KV next to recurrent
    lane state in one cache tree)."""
    _, router_params = mixture
    key = jax.random.PRNGKey(11)
    expert_params = [modellib.init_params(jax.random.fold_in(key, e), ecfg)
                     for e in range(E)]
    mix = (expert_params, router_params)
    rng = np.random.default_rng(12)
    lens = rng.integers(PREFIX, 30, size=5)           # ragged: forces fallback
    prompts = [rng.integers(0, ecfg.vocab_size, size=l).astype(np.int32)
               for l in lens]
    n_new = rng.integers(1, 6, size=5)
    sps = [None if i % 2 == 0 else
           SamplingParams(temperature=0.8, top_k=8, seed=40 + i)
           for i in range(5)]
    eng = _engine(mix, lanes=2, ecfg=ecfg)
    assert not eng.pad_safe
    for i in range(5):
        eng.submit(prompts[i], int(n_new[i]), sampling=sps[i],
                   arrival_tick=i // 2)
    res = eng.run()
    assert len(res["requests"]) == 5
    for r in res["requests"]:
        want = _oracle(mix, prompts[r.uid], r.expert, int(n_new[r.uid]),
                       ecfg=ecfg, sampling=sps[r.uid], uid=r.uid)
        np.testing.assert_array_equal(np.asarray(r.tokens), want)
