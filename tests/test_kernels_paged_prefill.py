"""Fused paged prefill kernel: slab+scatter identity + CoW non-clobber.

The fused op replaces the legacy admission pair — dense ``(K, max_len)``
slab prefill followed by ``cache.insert_requests`` — so its oracle is a
verbatim re-enactment of that pair over random pool recipes (bucket
widths, block sizes, GQA ratios, head dims, ragged true lengths, padded
lanes, softcap on/off):

  1. the jnp impl's attention output must match the **exact** blockwise
     flash call the slab path made (``impl="jnp"``, ``q_chunk=1024``)
     bit for bit — engine first tokens, and hence the token-identity
     contract vs ``serving/baseline.py``, ride on it;
  2. both impls' ``pos`` pool must equal the slab+scatter result bit for
     bit over every row (full-span rewrite clears a previous tenant's
     stale positions, unreserved spans land on scratch, scratch pos
     stays -1), and the *readable* K/V state (``pos >= 0``) must be
     identical — beyond a lane's prompt the two paths store different
     padding, all of it masked dead;
  3. rows not addressed by any table entry — other lanes' blocks and
     shared copy-on-write prefix blocks — must come back untouched;
  4. ``ops.paged_prefill_attention`` must reject bad ``impl`` values and
     malformed shapes loudly instead of silently falling back.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import ops as fa
from repro.kernels.paged_prefill import ops


def _slab_scatter(k, v, tables, true_lens, kp0, vp0, pp0):
    """The deleted admission pair (single replication slice): pad the
    bucket to the reserved span, write every block-sized piece through
    the table (unreserved pieces to scratch), mask pos beyond true_len —
    ``cache.insert_requests`` semantics, kept test-only as the bitwise
    anchor."""
    K, S = k.shape[:2]
    R, bs = tables.shape[1], pp0.shape[1]
    scratch = pp0.shape[0] - 1
    pad = R * bs - S
    k_slab = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    v_slab = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    span = jnp.broadcast_to(jnp.arange(R * bs, dtype=jnp.int32), (K, R * bs))
    ids = jnp.where(tables >= 0, tables, scratch).reshape(-1)
    kp = kp0.at[ids].set(k_slab.reshape(K * R, bs, *k.shape[2:]))
    vp = vp0.at[ids].set(v_slab.reshape(K * R, bs, *v.shape[2:]))
    pos = jnp.where((span >= 0) & (span < true_lens[:, None]), span, -1)
    pp = pp0.at[ids].set(pos.reshape(K * R, bs))
    return kp, vp, pp


def _random_problem(rng):
    """An engine-shaped fused-prefill problem: disjoint per-lane tables
    covering each prompt plus random reserved growth, occasionally a
    padding lane (all -1 table, true_len 0), pools pre-filled with
    garbage K/V and stale position markers from a previous tenant."""
    K = int(rng.integers(1, 4))
    bs = int(rng.choice([4, 8, 16]))
    R = int(rng.integers(2, 6))
    S = int(rng.choice([b for b in (4, 8, 16, 32, 64) if b <= R * bs]))
    Hkv = int(rng.choice([1, 2]))
    g = int(rng.choice([1, 2, 4]))
    hd = int(rng.choice([8, 16]))
    softcap = float(rng.choice([0.0, 30.0]))
    n_rows = int(rng.integers(K * R + 2, K * R + 6))
    scratch = n_rows - 1

    q = jnp.asarray(rng.standard_normal((K, S, Hkv * g, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((K, S, Hkv, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((K, S, Hkv, hd)), jnp.float32)
    true_lens = jnp.asarray(rng.integers(1, S + 1, K), jnp.int32)
    perm = rng.permutation(scratch)[:K * R]
    tables = np.full((K, R), -1, np.int32)
    for i in range(K):
        need = -(-int(true_lens[i]) // bs)
        n = need + int(rng.integers(0, R - need + 1))
        tables[i, :n] = perm[i * R:i * R + n]
    if K > 1 and rng.random() < 0.5:          # padding lane
        tables[K - 1] = -1
        true_lens = true_lens.at[K - 1].set(0)
    tables = jnp.asarray(tables)

    kp0 = jnp.asarray(rng.standard_normal((n_rows, bs, Hkv, hd)), jnp.float32)
    vp0 = jnp.asarray(rng.standard_normal((n_rows, bs, Hkv, hd)), jnp.float32)
    pp0 = jnp.asarray(rng.integers(-1, 50, (n_rows, bs)), jnp.int32)
    pp0 = pp0.at[scratch].set(-1)   # engine invariant: scratch pos is -1
    return q, k, v, tables, true_lens, kp0, vp0, pp0, softcap


N_FUZZ = 25


@pytest.mark.parametrize("seed", range(N_FUZZ))
def test_fuzz_fused_matches_slab_scatter(seed):
    rng = np.random.default_rng(7000 + seed)
    q, k, v, tables, true_lens, kp0, vp0, pp0, softcap = _random_problem(rng)
    scratch = pp0.shape[0] - 1
    kp_s, vp_s, pp_s = _slab_scatter(k, v, tables, true_lens, kp0, vp0, pp0)
    untouched = sorted(set(range(pp0.shape[0])) - {scratch}
                       - set(np.asarray(jnp.where(tables >= 0, tables,
                                                  scratch)).ravel().tolist()))
    out_jnp = None
    for impl in ("jnp", "pallas"):
        out, kp1, vp1, pp1 = ops.paged_prefill_attention(
            q, k, v, block_tables=tables, true_lens=true_lens,
            k_pool=kp0, v_pool=vp0, pos_pool=pp0, softcap=softcap, impl=impl)
        # pos pool == slab+scatter bit for bit (every row, scratch incl.)
        np.testing.assert_array_equal(np.asarray(pp1), np.asarray(pp_s),
                                      err_msg=f"seed {seed} {impl} pos")
        # readable K/V state (pos >= 0) identical; beyond the prompt the
        # two paths store different dead padding
        m = (np.asarray(pp_s) >= 0)[:, :, None, None]
        np.testing.assert_array_equal(
            np.where(m, np.asarray(kp1), 0), np.where(m, np.asarray(kp_s), 0),
            err_msg=f"seed {seed} {impl} k")
        np.testing.assert_array_equal(
            np.where(m, np.asarray(vp1), 0), np.where(m, np.asarray(vp_s), 0),
            err_msg=f"seed {seed} {impl} v")
        # scratch pos never leaves -1
        assert (np.asarray(pp1)[scratch] == -1).all(), f"seed {seed} {impl}"
        # unaddressed rows (other tenants' blocks) bitwise untouched
        for r in untouched:
            assert (np.asarray(kp1[r]) == np.asarray(kp0[r])).all() \
                and (np.asarray(vp1[r]) == np.asarray(vp0[r])).all() \
                and (np.asarray(pp1[r]) == np.asarray(pp0[r])).all(), \
                f"seed {seed} {impl} clobbered row {r}"
        if impl == "jnp":
            # attention == the exact flash call the slab prefill made
            want = fa.flash_attention(q, k, v, causal=True, window=0,
                                      softcap=softcap, impl="jnp",
                                      q_chunk=1024)
            np.testing.assert_array_equal(np.asarray(out), np.asarray(want),
                                          err_msg=f"seed {seed} attn")
            out_jnp = np.asarray(out)
        else:
            np.testing.assert_allclose(np.asarray(out), out_jnp,
                                       rtol=2e-5, atol=2e-5,
                                       err_msg=f"seed {seed} pallas attn")


@pytest.mark.parametrize("impl", ["jnp", "pallas"])
def test_cow_shared_prefix_block_not_clobbered(impl):
    """A shared copy-on-write prefix block (held by the radix cache, in
    no admitted lane's table) survives a fused prefill bitwise — the
    writer only chases rows the tables name."""
    rng = np.random.default_rng(42)
    bs, R, Hkv, hd = 8, 3, 2, 16
    n_rows, scratch, shared = 8, 7, 1
    q = jnp.asarray(rng.standard_normal((1, 16, 4, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 16, Hkv, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 16, Hkv, hd)), jnp.float32)
    tables = jnp.asarray([[3, 4, -1]], jnp.int32)   # novel suffix rows only
    true_lens = jnp.asarray([13], jnp.int32)
    kp0 = jnp.asarray(rng.standard_normal((n_rows, bs, Hkv, hd)), jnp.float32)
    vp0 = jnp.asarray(rng.standard_normal((n_rows, bs, Hkv, hd)), jnp.float32)
    pp0 = jnp.full((n_rows, bs), -1, jnp.int32)
    pp0 = pp0.at[shared].set(jnp.arange(bs, dtype=jnp.int32))  # live prefix
    _, kp1, vp1, pp1 = ops.paged_prefill_attention(
        q, k, v, block_tables=tables, true_lens=true_lens,
        k_pool=kp0, v_pool=vp0, pos_pool=pp0, impl=impl)
    np.testing.assert_array_equal(np.asarray(kp1[shared]),
                                  np.asarray(kp0[shared]))
    np.testing.assert_array_equal(np.asarray(vp1[shared]),
                                  np.asarray(vp0[shared]))
    np.testing.assert_array_equal(np.asarray(pp1[shared]),
                                  np.asarray(pp0[shared]))
    # while the addressed rows did get the prompt
    assert (np.asarray(pp1[3]) == np.arange(bs)).all()


def test_ops_dispatch_validates():
    rng = np.random.default_rng(5)
    q, k, v, tables, true_lens, kp0, vp0, pp0, _ = _random_problem(rng)
    kw = dict(block_tables=tables, true_lens=true_lens,
              k_pool=kp0, v_pool=vp0, pos_pool=pp0)
    with pytest.raises(ValueError, match="impl must be one of"):
        ops.paged_prefill_attention(q, k, v, impl="triton", **kw)
    with pytest.raises(ValueError, match="GQA"):
        ops.paged_prefill_attention(q[:, :, :, :4], k, v, impl="jnp", **kw)
    with pytest.raises(ValueError, match="block_tables"):
        ops.paged_prefill_attention(q, k, v, block_tables=tables[0],
                                    true_lens=true_lens, k_pool=kp0,
                                    v_pool=vp0, pos_pool=pp0, impl="jnp")
    # a bucket wider than the reserved span is an admission bug, not a
    # silent truncation
    S_over = tables.shape[1] * pp0.shape[1] + pp0.shape[1]
    qq = jnp.zeros((q.shape[0], S_over) + q.shape[2:], q.dtype)
    kk = jnp.zeros((k.shape[0], S_over) + k.shape[2:], k.dtype)
    with pytest.raises(ValueError, match="exceeds the reserved span"):
        ops.paged_prefill_attention(qq, kk, kk, impl="jnp", **kw)
