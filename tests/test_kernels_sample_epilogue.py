"""Fused decode epilogue: token identity vs the unfused sampler chain.

The fused op replaces the legacy decode tail — ``model._logits``
materializing ``(lanes, vocab)`` logits in HBM, then a separate
``sample_tokens_jit`` call — so the oracle is that exact sequence,
re-enacted per trial and compared **bitwise**:

  1. both impls of ``ops.decode_and_sample`` must return the same
     tokens as ``sample_tokens_jit`` on ``softcap((h @ U.T).astype(f32))``
     across the full recipe grid: temperature 0 (exact greedy lanes)
     through > 1, top-k off/1/partial/full, top-p tight/loose/off,
     mixed per-lane, with real ``request_key`` roots and varying step
     counters — the sampler sees exactly ``(V,)`` logits in-kernel, so
     vocab padding must never leak into the categorical draw;
  2. ``ops.decode_greedy`` must equal the raw argmax on both impls;
  3. vocab sizes around the kernel's 512-lane chunk (non-divisible,
     smaller-than-one-chunk, multi-chunk) all hold;
  4. the dispatch rejects bad ``impl`` values and malformed shapes.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.sample_epilogue import ops
from repro.models import common
from repro.serving import sampling as samplib

N_FUZZ = 12


def _problem(rng, *, V=None):
    B = int(rng.integers(1, 6))
    D = int(rng.choice([16, 32]))
    V = V if V is not None else int(rng.choice([50, 500, 512, 700, 1024]))
    cap = float(rng.choice([0.0, 30.0]))
    h = jnp.asarray(rng.standard_normal((B, 1, D)), jnp.float32)
    unemb = jnp.asarray(rng.standard_normal((V, D)), jnp.float32)
    keys = jnp.asarray(np.stack([samplib.request_key(3, u)
                                 for u in range(B)]))
    steps = jnp.asarray(rng.integers(0, 9, B), jnp.int32)
    temps = jnp.asarray(rng.choice([0.0, 0.5, 1.0, 1.7], B), jnp.float32)
    top_ks = jnp.asarray(rng.choice([0, 1, 5, V], B), jnp.int32)
    top_ps = jnp.asarray(rng.choice([0.1, 0.7, 1.0], B), jnp.float32)
    return h, unemb, keys, steps, temps, top_ks, top_ps, cap


def _unfused(h, unemb, keys, steps, temps, top_ks, top_ps, cap):
    """The legacy sequence the fusion replaced, bit for bit: logits to
    HBM (same matmul/astype/softcap order as ``model._logits``), then
    the shared jitted sampler."""
    logits = common.softcap((h @ unemb.T).astype(jnp.float32), cap)
    toks = samplib.sample_tokens_jit(logits[:, 0], keys, steps, temps,
                                     top_ks, top_ps)
    return toks, jnp.argmax(logits[:, 0], -1).astype(jnp.int32)


@pytest.mark.parametrize("seed", range(N_FUZZ))
def test_fuzz_fused_tokens_bitwise(seed):
    rng = np.random.default_rng(8000 + seed)
    h, unemb, keys, steps, temps, top_ks, top_ps, cap = _problem(rng)
    want, want_g = _unfused(h, unemb, keys, steps, temps, top_ks, top_ps,
                            cap)
    for impl in ("jnp", "pallas"):
        got = ops.decode_and_sample(h, unemb, keys=keys, steps=steps,
                                    temps=temps, top_ks=top_ks,
                                    top_ps=top_ps, final_softcap=cap,
                                    logit_dtype=jnp.float32, impl=impl)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want),
                                      err_msg=f"seed {seed} {impl}")
        got_g = ops.decode_greedy(h, unemb, final_softcap=cap,
                                  logit_dtype=jnp.float32, impl=impl)
        np.testing.assert_array_equal(np.asarray(got_g), np.asarray(want_g),
                                      err_msg=f"seed {seed} {impl} greedy")


@pytest.mark.parametrize("impl", ["jnp", "pallas"])
def test_temperature_zero_lanes_are_exact_greedy(impl):
    """An all-greedy sampled batch (temperature 0 everywhere) must equal
    the raw argmax — the engine mixes greedy and sampled lanes through
    one program, so temp-0 rows cannot pick up sampler noise."""
    rng = np.random.default_rng(9)
    h, unemb, keys, steps, _, top_ks, top_ps, cap = _problem(rng, V=300)
    B = h.shape[0]
    zeros = jnp.zeros(B, jnp.float32)
    got = ops.decode_and_sample(h, unemb, keys=keys, steps=steps,
                                temps=zeros, top_ks=top_ks, top_ps=top_ps,
                                final_softcap=cap,
                                logit_dtype=jnp.float32, impl=impl)
    want = ops.decode_greedy(h, unemb, final_softcap=cap,
                             logit_dtype=jnp.float32, impl="jnp")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("V", [8, 511, 512, 513, 1536])
def test_vocab_chunk_boundaries(V):
    """Vocabs below / at / just past / at multiples of the Pallas vocab
    chunk: padded matmul lanes must never reach the sampler."""
    rng = np.random.default_rng(100 + V)
    h, unemb, keys, steps, temps, top_ks, top_ps, cap = _problem(rng, V=V)
    want, want_g = _unfused(h, unemb, keys, steps, temps, top_ks, top_ps,
                            cap)
    got = ops.decode_and_sample(h, unemb, keys=keys, steps=steps,
                                temps=temps, top_ks=top_ks, top_ps=top_ps,
                                final_softcap=cap, logit_dtype=jnp.float32,
                                impl="pallas")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    got_g = ops.decode_greedy(h, unemb, final_softcap=cap,
                              logit_dtype=jnp.float32, impl="pallas")
    np.testing.assert_array_equal(np.asarray(got_g), np.asarray(want_g))


def test_ops_dispatch_validates():
    rng = np.random.default_rng(5)
    h, unemb, keys, steps, temps, top_ks, top_ps, cap = _problem(rng, V=64)
    kw = dict(keys=keys, steps=steps, temps=temps, top_ks=top_ks,
              top_ps=top_ps)
    with pytest.raises(ValueError, match="impl must be one of"):
        ops.decode_and_sample(h, unemb, impl="triton", **kw)
    with pytest.raises(ValueError, match="impl must be one of"):
        ops.decode_greedy(h, unemb, impl="triton")
    with pytest.raises(ValueError, match=r"\(B, 1, D\)"):
        ops.decode_and_sample(h[:, 0], unemb, **kw)
    with pytest.raises(ValueError, match=r"\(V, D\)"):
        ops.decode_and_sample(h, unemb.T, **kw)
    with pytest.raises(ValueError, match="keys"):
        ops.decode_and_sample(h, unemb, keys=keys[:, :1], steps=steps,
                              temps=temps, top_ks=top_ks, top_ps=top_ps)
    with pytest.raises(ValueError, match="temps"):
        ops.decode_and_sample(h, unemb, keys=keys, steps=steps,
                              temps=temps[:-1], top_ks=top_ks,
                              top_ps=top_ps)
