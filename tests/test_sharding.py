"""Sharding rules: every param/cache leaf gets a spec whose axes divide the
leaf dims — for ALL 10 full-size architectures on the production meshes
(pure spec computation; no devices needed)."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import INPUT_SHAPES, get_config
from repro.configs.archs import ASSIGNED_NAMES, FSDP_ARCHS
from repro.launch import specs as speclib
from repro.models import model as modellib
from repro.parallel import sharding as shlib


class FakeMesh:
    """Duck-typed mesh: the spec builders only read axis_names/devices.shape."""
    def __init__(self, shape, names):
        self.axis_names = names
        self.devices = np.empty(shape, dtype=object)


MESH_SP = FakeMesh((16, 16), ("data", "model"))
MESH_MP = FakeMesh((2, 16, 16), ("pod", "data", "model"))


def _check(tree_struct, spec_tree, ms):
    leaves = jax.tree_util.tree_leaves(tree_struct)
    specs = jax.tree_util.tree_leaves(spec_tree,
                                      is_leaf=lambda x: isinstance(x, P))
    assert len(leaves) == len(specs)
    for leaf, sp in zip(leaves, specs):
        assert len(sp) <= len(leaf.shape), (leaf.shape, sp)
        for dim, ax in zip(leaf.shape, tuple(sp)):
            if ax is None:
                continue
            n = 1
            for a in ((ax,) if isinstance(ax, str) else ax):
                n *= ms[a]
            assert dim % n == 0, (leaf.shape, sp)


@pytest.mark.parametrize("arch", ASSIGNED_NAMES)
def test_param_specs_divide(arch):
    cfg = get_config(arch)
    ps = speclib.param_struct(cfg)
    for mesh in (MESH_SP, MESH_MP):
        ms = dict(zip(mesh.axis_names, mesh.devices.shape))
        specs = shlib.param_specs(ps, mesh, fsdp=arch in FSDP_ARCHS)
        _check(ps, specs, ms)


@pytest.mark.parametrize("arch", ["gemma2-27b", "zamba2-1.2b", "xlstm-1.3b",
                                  "chatglm3-6b"])
@pytest.mark.parametrize("shape", ["decode_32k", "long_500k"])
def test_cache_specs_divide(arch, shape):
    cfg = get_config(arch)
    s = INPUT_SHAPES[shape]
    caches = modellib.cache_specs(cfg, s.global_batch, s.seq_len)
    ms = dict(zip(MESH_SP.axis_names, MESH_SP.devices.shape))
    specs = shlib.cache_tree_specs(caches, MESH_SP)
    _check(caches, specs, ms)


def test_model_parallel_actually_shards_big_leaves():
    """The big matrices must not be replicated on the model axis."""
    cfg = get_config("qwen2-1.5b")
    ps = speclib.param_struct(cfg)
    specs = shlib.param_specs(ps, MESH_SP, fsdp=False)
    flat = jax.tree_util.tree_flatten_with_path(specs)[0]
    sharded = {shlib._pname(p[-1]) for p, s in flat if "model" in str(s)}
    for need in ("embed", "wq", "wk", "wv", "wo", "wi", "wg"):
        assert need in sharded, need


def test_zero_extends_over_data():
    cfg = get_config("gemma2-27b")
    ps = speclib.param_struct(cfg)
    specs = shlib.param_specs(ps, MESH_SP, fsdp=True)
    text = str(jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P)))
    assert "'data'" in text    # at least some leaves ZeRO-sharded


def test_moe_expert_sharding_rule():
    """arctic (128e): expert dim on model; grok (8e): d_ff on model."""
    ms = dict(zip(MESH_SP.axis_names, MESH_SP.devices.shape))
    for arch, expect_axis0 in (("arctic-480b", True), ("grok-1-314b", False)):
        cfg = get_config(arch)
        ps = speclib.param_struct(cfg)
        specs = shlib.param_specs(ps, MESH_SP, fsdp=False)
        flat = jax.tree_util.tree_flatten_with_path(specs)[0]
        for path, sp in flat:
            names = [shlib._pname(p) for p in path]
            if "moe" in names and names[-1] == "wi" and "dense" not in names:
                body = tuple(sp)[1:]   # skip stacked stage axis
                if expect_axis0:
                    assert body[0] == "model", (arch, sp)
                else:
                    assert body[0] is None and "model" in body, (arch, sp)
