"""Per-architecture smoke tests (deliverable f): a REDUCED variant of each
assigned family runs one forward/train step on CPU with shape + finiteness
assertions."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_variant
from repro.configs.archs import ASSIGNED_NAMES
from repro.models import model as modellib
from repro.optim import AdamWConfig, adamw

B, S = 2, 64


def make_batch(cfg, key):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    if cfg.input_mode == "embeddings":
        batch = {"embeds": jax.random.normal(key, (B, S, cfg.input_embed_dim)),
                 "frame_mask": jax.random.bernoulli(key, 0.08, (B, S)),
                 "labels": toks,
                 "loss_mask": jax.random.bernoulli(key, 0.08, (B, S))}
    elif cfg.input_mode == "multimodal":
        n = cfg.n_image_tokens
        batch["image_embeds"] = jax.random.normal(
            key, (B, n, cfg.input_embed_dim))
        batch["image_positions"] = jnp.tile(jnp.arange(n)[None], (B, 1))
        batch["positions"] = jnp.tile(jnp.arange(S)[None, :, None], (B, 1, 3))
    return batch


@pytest.mark.parametrize("arch", ASSIGNED_NAMES)
def test_smoke_forward_and_train_step(arch):
    cfg = smoke_variant(get_config(arch))
    assert cfg.n_layers <= 2 and cfg.d_model <= 512
    assert cfg.moe is None or cfg.moe.n_experts <= 4
    key = jax.random.PRNGKey(0)
    params = modellib.init_params(key, cfg)
    batch = make_batch(cfg, jax.random.PRNGKey(1))

    loss, metrics = modellib.loss_and_metrics(params, cfg, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), arch
    nll, _ = modellib.per_token_nll(params, cfg, batch)
    assert nll.shape == (B, S)
    assert bool(jnp.isfinite(nll).all()), arch

    opt_cfg = AdamWConfig(peak_lr=1e-3, warmup_steps=1, total_steps=10,
                          clip_norm=1.0, opt_dtype=cfg.opt_dtype)
    step = adamw.make_train_step(
        lambda p, b: modellib.loss_and_metrics(p, cfg, b), opt_cfg)
    state = adamw.init_state(params, opt_cfg)
    new_params, state, m = step(params, state, batch)
    assert bool(jnp.isfinite(m["loss"]))
    # params actually moved
    moved = any(
        float(jnp.abs(a - b).max()) > 0
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(new_params)))
    assert moved, arch
    # one more step with the SAME batch must reduce loss (sanity descent)
    _, _, m2 = step(new_params, state, batch)
    assert float(m2["ce"]) < float(m["ce"]) + 0.2, arch


@pytest.mark.parametrize("arch", ASSIGNED_NAMES)
def test_smoke_prefill_shapes(arch):
    cfg = smoke_variant(get_config(arch))
    if not cfg.has_decode:
        pytest.skip("encoder-only: no serve path")
    params = modellib.init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    batch.pop("labels", None)
    batch.pop("loss_mask", None)
    logits, caches = modellib.prefill(params, cfg, batch, cache_len=S + 4)
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert caches is not None


def test_full_configs_match_assignment():
    """The FULL configs carry the exact assigned hyperparameters."""
    spec = {
        "gemma2-27b": (46, 4608, 32, 16, 36864, 256000),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "qwen2-vl-7b": (28, 3584, 28, 4, 18944, 152064),
        "chatglm3-6b": (28, 4096, 32, 2, 13696, 65024),
        "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
        "qwen2-1.5b": (28, 1536, 12, 2, 8960, 151936),
        "qwen1.5-4b": (40, 2560, 20, 20, 6912, 151936),
        "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
    }
    for name, (L, d, h, kv, ff, v) in spec.items():
        cfg = get_config(name)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.d_ff, cfg.vocab_size) == (L, d, h, kv, ff, v), name
        assert len(cfg.layer_pattern) == L, name
    assert get_config("grok-1-314b").moe.n_experts == 8
    assert get_config("arctic-480b").moe.n_experts == 128
    assert get_config("arctic-480b").moe.dense_residual
    assert not get_config("hubert-xlarge").causal
