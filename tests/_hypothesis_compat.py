"""Optional-hypothesis shim: property tests skip on minimal environments.

``from _hypothesis_compat import given, settings, st`` behaves exactly
like the real hypothesis import when the package is installed.  When it
is not (e.g. a CPU box with only the runtime deps), the suite must still
COLLECT — so ``given`` turns each property test into a zero-argument stub
that skips, ``settings`` is a no-op, and ``st`` hands out dummy strategy
builders.  The stub takes no parameters on purpose: pytest would otherwise
try to resolve the property-test arguments as fixtures.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _Strategies:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _Strategies()

    def settings(*a, **k):
        return lambda f: f

    def given(*a, **k):
        def deco(f):
            def stub():
                pytest.skip("hypothesis not installed")
            stub.__name__ = f.__name__
            stub.__doc__ = f.__doc__
            return stub
        return deco
