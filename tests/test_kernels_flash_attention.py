"""Pallas flash-attention kernel vs full-materialization oracle:
shape/dtype sweep over causal/window/softcap/GQA + grads."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.flash_attention.flash_attention import flash_attention_pallas
from repro.kernels.flash_attention.ref import (blockwise_attention,
                                               decode_attention_ref,
                                               mha_reference)

CASES = [
    dict(B=2, S=128, Hq=4, Hkv=2, d=32, causal=True, window=0, cap=0.0),
    dict(B=1, S=256, Hq=4, Hkv=4, d=64, causal=True, window=64, cap=0.0),
    dict(B=2, S=64, Hq=8, Hkv=1, d=16, causal=True, window=0, cap=30.0),
    dict(B=1, S=96, Hq=2, Hkv=2, d=32, causal=False, window=0, cap=0.0),
    dict(B=1, S=80, Hq=4, Hkv=2, d=24, causal=True, window=16, cap=50.0),
]


def _qkv(c, dtype):
    q = jax.random.normal(jax.random.PRNGKey(0), (c["B"], c["S"], c["Hq"], c["d"]))
    k = jax.random.normal(jax.random.PRNGKey(1), (c["B"], c["S"], c["Hkv"], c["d"]))
    v = jax.random.normal(jax.random.PRNGKey(2), (c["B"], c["S"], c["Hkv"], c["d"]))
    return q.astype(dtype), k.astype(dtype), v.astype(dtype)


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pallas_matches_oracle(case, dtype):
    q, k, v = _qkv(case, dtype)
    kw = dict(causal=case["causal"], window=case["window"], softcap=case["cap"])
    want = mha_reference(q, k, v, **kw).astype(jnp.float32)
    got = flash_attention_pallas(q, k, v, **kw).astype(jnp.float32)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("case", CASES[:3])
def test_blockwise_matches_oracle(case):
    q, k, v = _qkv(case, jnp.float32)
    kw = dict(causal=case["causal"], window=case["window"], softcap=case["cap"])
    want = mha_reference(q, k, v, **kw)
    got = blockwise_attention(q, k, v, q_chunk=32, kv_chunk=32, **kw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_grads_flow_through_pallas():
    c = CASES[0]
    q, k, v = _qkv(c, jnp.float32)
    kw = dict(causal=True, window=0, softcap=0.0)

    def loss(fn, q, k, v):
        return (fn(q, k, v, **kw) ** 2).sum()

    g_ref = jax.grad(lambda q, k, v: loss(mha_reference, q, k, v),
                     (0, 1, 2))(q, k, v)
    g_pl = jax.grad(lambda q, k, v: loss(flash_attention_pallas, q, k, v),
                    (0, 1, 2))(q, k, v)
    for a, b in zip(g_ref, g_pl):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=1e-3, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(S=st.integers(2, 64), Hkv=st.sampled_from([1, 2]),
       g=st.sampled_from([1, 2, 4]), window=st.sampled_from([0, 8]),
       seed=st.integers(0, 99))
def test_property_rows_are_convex_combinations(S, Hkv, g, window, seed):
    """Each output is a convex combination of V rows => within [min,max]."""
    B, d = 1, 8
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(k1, (B, S, Hkv * g, d))
    k = jax.random.normal(k2, (B, S, Hkv, d))
    v = jax.random.normal(k3, (B, S, Hkv, d))
    out = np.asarray(blockwise_attention(q, k, v, causal=True, window=window,
                                         q_chunk=16, kv_chunk=16))
    assert np.isfinite(out).all()
    assert out.max() <= float(v.max()) + 1e-4
    assert out.min() >= float(v.min()) - 1e-4


def test_decode_matches_full_attention_row():
    """Single-token decode == last row of full causal attention."""
    B, S, Hq, Hkv, d = 2, 33, 4, 2, 16
    q = jax.random.normal(jax.random.PRNGKey(0), (B, S, Hq, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, Hkv, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, Hkv, d))
    full = mha_reference(q, k, v, causal=True)
    kv_pos = jnp.tile(jnp.arange(S)[None], (B, 1))
    dec = decode_attention_ref(q[:, -1:], k, v,
                               q_pos=jnp.full((B, 1), S - 1), kv_pos=kv_pos)
    np.testing.assert_allclose(np.asarray(dec[:, 0]), np.asarray(full[:, -1]),
                               rtol=1e-5, atol=1e-5)
