"""Live replica autoscaling: policy units, quiesce safety, token identity.

The autoscaler (``repro.serving.autoscale``) grows and shrinks the
frontend's replica map mid-serve.  The invariants pinned here:

* :class:`ScalePolicy` validation and the deterministic
  :class:`Autoscaler` decision logic — pressure hysteresis, idle
  streaks, per-expert cooldown, min/max clamps, warming accounting,
  and the cooldown re-stamp at adoption (a slot that spent its own
  cooldown warming must not be idle-retired on arrival);
* the ``recall`` load-leak regression — a retired replica's queued
  requests leave the sender-side ``Transport.load`` tracker, or
  least-loaded admission would be skewed forever;
* quiesce safety under fire — a seeded fuzz retires a *busy* replica
  mid-stream (queued requests recalled and re-routed, active lanes
  draining in place) on the loopback and process transports, and every
  token stays bitwise identical to the one-shot oracle: tokens are a
  pure function of ``(seed, uid, step)``, so time-varying placement
  cannot touch them;
* an end-to-end loopback run with a :class:`ScalePolicy` installed —
  the hot expert gains a replica under pressure, the idle one retires,
  ``run()`` reports a typed ``autoscale`` section, and the stream
  equals the serial oracle.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import router as routerlib
from repro.models import model as modellib
from repro.serving import (Autoscaler, AutoscaleStats, EngineConfig,
                           ExpertServer, LoopbackTransport, RequestMsg,
                           SamplingParams, ScaleEvent, ScalePolicy,
                           ServeFrontend, baseline)
from repro.serving.autoscale import SlotLoad

ECFG = ModelConfig(name="as-expert", n_layers=2, d_model=64, n_heads=4,
                   n_kv_heads=4, d_ff=128, vocab_size=128, ffn_type="gelu",
                   loss_chunk=32, compute_dtype="float32",
                   param_dtype="float32")
RCFG = ModelConfig(name="as-router", n_layers=1, d_model=32, n_heads=2,
                   n_kv_heads=2, d_ff=64, vocab_size=128, ffn_type="gelu",
                   loss_chunk=32, compute_dtype="float32",
                   param_dtype="float32")
E, PREFIX, MAXLEN, BS = 2, 16, 48, 16
ENG = EngineConfig(lanes_per_expert=2, max_len=MAXLEN, prefix_len=PREFIX,
                   block_size=BS, route_batch=4)


@pytest.fixture(scope="module")
def mixture():
    key = jax.random.PRNGKey(0)
    router_params = routerlib.init_ensemble(key, RCFG, E)
    expert_params = [modellib.init_params(jax.random.fold_in(key, e), ECFG)
                     for e in range(E)]
    return expert_params, router_params


def _oracle(params, prompt, n_new, sampling=None, uid=0, stops=()):
    return baseline.generate_request(ECFG, params, prompt, n_new,
                                     sampling=sampling, uid=uid,
                                     stop_tokens=stops, cache_len=MAXLEN)


# ---------------------------------------------------------------------------
# policy validation
# ---------------------------------------------------------------------------
def test_scale_policy_validation():
    ScalePolicy().validate()                        # defaults are legal
    with pytest.raises(ValueError, match="up_pressure"):
        ScalePolicy(up_pressure=0).validate()
    with pytest.raises(ValueError, match="up_ticks"):
        ScalePolicy(up_ticks=0).validate()
    with pytest.raises(ValueError, match="cooldown"):
        ScalePolicy(cooldown_ticks=-1).validate()
    with pytest.raises(ValueError, match="min_replicas"):
        ScalePolicy(min_replicas=0).validate()
    with pytest.raises(ValueError, match="max_replicas"):
        ScalePolicy(max_replicas=1, min_replicas=2).validate()
    with pytest.raises(ValueError, match="every"):
        ScalePolicy(every=0).validate()


# ---------------------------------------------------------------------------
# decision logic (no jax, no transport)
# ---------------------------------------------------------------------------
def _policy(**kw):
    base = dict(up_pressure=1, up_ticks=2, down_idle_ticks=3,
                cooldown_ticks=4, min_replicas=1, max_replicas=3)
    base.update(kw)
    return ScalePolicy(**base)


def test_autoscaler_pressure_hysteresis_and_max():
    a = Autoscaler(_policy(), n_experts=1, lanes_per_replica=2)
    hot = {0: [SlotLoad(0, 5)]}              # pressure 3 over one replica
    assert a.observe(0, hot, {}) == []       # 1 pressured eval < up_ticks
    assert a.observe(1, hot, {}) == [("up", 0)]
    # the spawn is warming: capacity doubles, pressure gone, and the
    # in-flight spawn counts toward max_replicas
    calm = {0: [SlotLoad(0, 3)]}
    assert a.observe(2, calm, {0: 1}) == []
    # a single calm eval resets the streak — no flapping on bursts
    a2 = Autoscaler(_policy(), 1, 2)
    a2.observe(0, hot, {})
    a2.observe(1, calm, {})
    assert a2.observe(2, hot, {}) == []
    # max_replicas clamps even under sustained pressure
    a3 = Autoscaler(_policy(max_replicas=1), 1, 2)
    a3.observe(0, hot, {})
    assert a3.observe(1, hot, {}) == []


def test_autoscaler_idle_retire_min_and_victim():
    a = Autoscaler(_policy(), n_experts=1, lanes_per_replica=2)
    loads = {0: [SlotLoad(0, 1), SlotLoad(1, 0), SlotLoad(2, 0)]}
    assert a.observe(0, loads, {}) == []
    assert a.observe(1, loads, {}) == []
    # third consecutive idle eval: exactly one action, highest slot first
    assert a.observe(2, loads, {}) == [("down", 0, 2)]
    # cooldown blocks the next retire until tick 2 + cooldown_ticks
    two = {0: [SlotLoad(0, 1), SlotLoad(1, 0)]}
    for t in (3, 4, 5):
        assert a.observe(t, two, {}) == []
    assert a.observe(6, two, {}) == [("down", 0, 1)]
    # min_replicas: the last replica never retires, however idle
    one = {0: [SlotLoad(0, 0)]}
    for t in range(10, 30):
        assert a.observe(t, one, {}) == []


def test_autoscaler_adoption_restamps_cooldown():
    """A replica that warmed for longer than the cooldown must not be
    ripe for retirement the moment it is adopted."""
    a = Autoscaler(_policy(), n_experts=1, lanes_per_replica=2)
    a.observe(0, {0: [SlotLoad(0, 5)]}, {})
    assert a.observe(1, {0: [SlotLoad(0, 5)]}, {}) == [("up", 0)]
    # ...slot 1 spawns and warms for 10 ticks (cooldown long expired)...
    for t in range(2, 12):
        a.observe(t, {0: [SlotLoad(0, 2)]}, {0: 1})
    a.note_adopted(0, slot=1, tick=12)
    both = {0: [SlotLoad(0, 2), SlotLoad(1, 0)]}
    # idle streak (3) ripens before cooldown (12+4) clears; nothing may
    # fire until tick 16
    for t in range(12, 16):
        assert a.observe(t, both, {}) == []
    assert a.observe(16, both, {}) == [("down", 0, 1)]


# ---------------------------------------------------------------------------
# recall: the sender-side load tracker must shed recalled requests
# ---------------------------------------------------------------------------
def _req(uid, prompt, n_new=3, tick=0):
    return RequestMsg(uid=uid, prompt=prompt, max_new_tokens=n_new,
                      sampling=SamplingParams(), stop_tokens=frozenset(),
                      enqueue_tick=tick)


def test_recall_decrements_sender_side_load(mixture):
    """Regression: retiring a replica with queued requests used to leak
    their load in ``Transport.load`` forever, skewing least-loaded
    admission toward the survivors."""
    expert_params, _ = mixture
    rng = np.random.default_rng(7)
    lt = LoopbackTransport([ExpertServer(ECFG, expert_params[0], ENG)])
    prompts = [rng.integers(0, ECFG.vocab_size, size=PREFIX).astype(np.int32)
               for _ in range(5)]
    for u, p in enumerate(prompts):
        lt.enqueue(0, _req(u, p))
    assert lt.load(0) == 5
    lt.tick(0)                       # admit up to lanes=2, rest queued
    uids = lt.recall(0)
    assert sorted(uids) == [2, 3, 4]           # the queued, unadmitted tail
    assert lt.load(0) == 2                     # active lanes only: no leak
    while lt.busy(0):
        lt.tick(0)
    assert lt.load(0) == 0


# ---------------------------------------------------------------------------
# quiesce safety: retire a BUSY replica mid-stream, tokens identical
# ---------------------------------------------------------------------------
def _fuzz_retire_mid_stream(mixture, seed, transport):
    expert_params, router_params = mixture
    rng = np.random.default_rng(seed)
    n = 10
    prompts = [rng.integers(0, ECFG.vocab_size,
                            size=int(rng.integers(PREFIX, 30))
                            ).astype(np.int32) for _ in range(n)]
    n_new = [int(rng.integers(3, 8)) for _ in range(n)]
    sps = [None if rng.random() < 0.5 else
           SamplingParams(temperature=float(rng.uniform(0.3, 1.2)),
                          top_k=int(rng.choice([0, 4])),
                          seed=int(rng.integers(0, 1 << 16)))
           for _ in range(n)]
    eng_cfg = dataclasses.replace(ENG, transport=transport)
    with ServeFrontend(ECFG, RCFG, expert_params, router_params, eng_cfg,
                       replicas={e: 2 for e in range(E)}) as eng:
        reqs = [eng.submit(prompts[i], n_new[i], sampling=sps[i],
                           arrival_tick=0) for i in range(n)]
        # let lanes fill and some tokens stream, then yank one replica
        # of the busiest expert out from under the engine (stats reset at
        # run(), so completions during these warm steps won't be counted)
        done0 = 0
        for _ in range(int(rng.integers(1, 4))):
            done0 += len(eng.step())
        victim = max(range(E),
                     key=lambda e: sum(r.expert == e for r in reqs))
        assert any(eng._transport.busy(s)
                   for s in eng.placements.slots_of(victim)), \
            "fuzz setup: the victim expert must be mid-stream"
        eng.retire_replica(victim, 1)
        res = eng.run()
    # the retire completed: replica 1 released, its counters folded in
    assert [(ev.action, ev.expert, ev.replica)
            for ev in eng.scale_events] == [("down", victim, 1)]
    assert eng.placements.n_replicas(victim) == 1
    assert res["per_expert"][victim]["replicas"] == 1
    served = sum(st["served"] for st in res["per_expert"].values())
    assert served == n - done0         # retired counters are not dropped
    for r in sorted(reqs, key=lambda r: r.uid):
        want = _oracle(expert_params[r.expert], prompts[r.uid],
                       n_new[r.uid], sampling=sps[r.uid], uid=r.uid)
        np.testing.assert_array_equal(np.asarray(r.tokens), want,
                                      err_msg=f"uid {r.uid} (seed {seed})")


@pytest.mark.parametrize("seed", range(3))
def test_retire_busy_replica_mid_stream_loopback(mixture, seed):
    _fuzz_retire_mid_stream(mixture, 8800 + seed, "loopback")


@pytest.mark.slow
def test_retire_busy_replica_mid_stream_process(mixture):
    _fuzz_retire_mid_stream(mixture, 8810, "process")


def test_retire_guards(mixture):
    expert_params, router_params = mixture
    with ServeFrontend(ECFG, RCFG, expert_params, router_params, ENG,
                       replicas={0: 2}) as eng:
        with pytest.raises(ValueError, match="not a live replica"):
            eng.retire_replica(0, 5)
        with pytest.raises(ValueError, match="last live replica"):
            eng.retire_replica(1, 0)
        eng.retire_replica(0, 1)           # idle: finalized next step
        eng.step()
        assert eng.placements.n_replicas(0) == 1
        with pytest.raises(ValueError, match="last live replica"):
            eng.retire_replica(0, 0)


# ---------------------------------------------------------------------------
# end to end: the control plane scales up AND down, tokens exact
# ---------------------------------------------------------------------------
def test_autoscale_end_to_end_loopback(mixture):
    """Flood the hot expert past its lane capacity with a spare replica
    on the cold one: the policy must spawn for the hot expert and retire
    the idle cold replica, with the whole stream oracle-identical and a
    typed ``autoscale`` report section."""
    expert_params, router_params = mixture
    rng = np.random.default_rng(41)
    n = 24
    prompts = [rng.integers(0, ECFG.vocab_size, size=PREFIX).astype(np.int32)
               for _ in range(n)]
    routes = [int(baseline.route(
        RCFG, router_params, np.asarray(p)[None, :PREFIX], PREFIX)[0])
        for p in prompts]
    hot = max(range(E), key=routes.count)
    cold = 1 - hot
    hot_prompts = [p for p, e in zip(prompts, routes) if e == hot][:12]
    # down_idle long enough that only the cold expert's never-loaded
    # replica ripens mid-run (the hot one would flap: idle-retire at the
    # drain tail, pressure-respawn on the leftovers)
    scale = ScalePolicy(up_pressure=1, up_ticks=2, down_idle_ticks=10,
                        cooldown_ticks=4, max_replicas=2)
    with ServeFrontend(ECFG, RCFG, expert_params, router_params, ENG,
                       replicas={cold: 2}, scale=scale) as eng:
        reqs = [eng.submit(p, 4, arrival_tick=0) for p in hot_prompts]
        res = eng.run()
    ups = [ev for ev in eng.scale_events if ev.action == "up"]
    downs = [ev for ev in eng.scale_events if ev.action == "down"]
    assert ups and all(ev.expert == hot for ev in ups)
    assert ups[0].reason == "pressure"
    assert (cold, 1) in [(ev.expert, ev.replica) for ev in downs]
    a = res.autoscale
    assert isinstance(a, AutoscaleStats)
    assert a.scale_ups == len(ups) and a.scale_downs == len(downs) >= 1
    assert a.peak_replicas[hot] == 2
    assert all(isinstance(ev, ScaleEvent) for ev in a.events)
    d = res.to_dict()
    assert d["autoscale"]["scale_ups"] == a.scale_ups   # dict-compat report
    for r, p in zip(reqs, hot_prompts):
        assert r.expert == hot
        want = _oracle(expert_params[hot], p, 4, uid=r.uid)
        np.testing.assert_array_equal(np.asarray(r.tokens), want,
                                      err_msg=f"uid {r.uid}")
