"""Network serving: framing, registry, socket transport, worker fleet.

The ``repro.serving.net`` subsystem puts the transport seam on real TCP:
independently-started expert workers (own params, own KV pool, own
clock, self-ticking), a discovery registry with heartbeats, and N
stateless ``ServeFrontend`` instances connecting concurrently with
leased uid namespaces.  These tests pin:

* the wire layer — frame roundtrip, ``PeerGone`` on a vanished peer,
  the one-time version handshake rejecting mismatched builds in both
  directions, and placement cross-checks against the registry's claim;
* the registry — replica auto-assignment, heartbeat expiry dropping
  silent workers from placements, monotonic namespace leases;
* token identity — a tcp frontend against in-process workers must match
  the serial oracle bitwise (greedy + sampled + early stops), exactly
  like every other transport, because the counter-based sampler makes
  streams a pure function of ``(seed, uid, step)``;
* multi-frontend serving — two frontends on one fleet lease distinct
  namespaces, interleave their decodes, and never corrupt each other's
  streams;
* failure semantics — a worker death mid-stream raises a RuntimeError
  naming the ``(expert, replica)`` placement while the other slots keep
  serving, and ``run()`` degrades to partial stats with an explicit
  ``missing_replicas`` list instead of losing the report;
* the standalone entry points — a ``LocalFleet`` of real
  ``python -m repro.serving.net.{registry,expert_worker}`` subprocesses
  (slow: each worker re-imports jax and compiles its own programs).
"""
import dataclasses
import socket
import threading
import time

import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import router as routerlib
from repro.models import model as modellib
from repro.serving import (EngineConfig, Placement, SamplingParams,
                           ServeFrontend, baseline)
from repro.serving.frontend import MAX_UID_NAMESPACE, UID_NAMESPACE_STRIDE
from repro.serving.net import Registry, SocketTransport, framing
from repro.serving.net import registry as netreg
from repro.serving.net.expert_worker import ExpertWorker
from repro.serving.transport import WIRE_VERSION

ECFG = ModelConfig(name="net-expert", n_layers=2, d_model=64, n_heads=4,
                   n_kv_heads=4, d_ff=128, vocab_size=128, ffn_type="gelu",
                   loss_chunk=32, compute_dtype="float32",
                   param_dtype="float32")
RCFG = ModelConfig(name="net-router", n_layers=1, d_model=32, n_heads=2,
                   n_kv_heads=2, d_ff=64, vocab_size=128, ffn_type="gelu",
                   loss_chunk=32, compute_dtype="float32",
                   param_dtype="float32")
E, PREFIX, MAXLEN, BS = 2, 16, 48, 16
ENG = EngineConfig(lanes_per_expert=2, max_len=MAXLEN, prefix_len=PREFIX,
                   block_size=BS, route_batch=4)


@pytest.fixture(scope="module")
def mixture():
    key = jax.random.PRNGKey(0)
    router_params = routerlib.init_ensemble(key, RCFG, E)
    expert_params = [modellib.init_params(jax.random.fold_in(key, e), ECFG)
                     for e in range(E)]
    return expert_params, router_params


@pytest.fixture(scope="module")
def fleet(mixture):
    """A shared in-process fleet: registry + one worker per expert.

    Tests that must kill a worker boot their own fleet instead (killing
    this one would poison every later test in the module)."""
    expert_params, _ = mixture
    reg = Registry(ttl_s=30.0)
    workers = [ExpertWorker(ECFG, ENG, expert_params[e], e,
                            registry=reg.addr, warmup_len=PREFIX).start()
               for e in range(E)]
    yield reg
    for w in workers:
        w.stop()
    reg.stop()


def _tcp(reg, **kw):
    return dataclasses.replace(ENG, transport="tcp", registry=reg.addr, **kw)


def _oracle(params, prompt, n_new, sampling=None, uid=0, stops=()):
    return baseline.generate_request(ECFG, params, prompt, n_new,
                                     sampling=sampling, uid=uid,
                                     stop_tokens=stops, cache_len=MAXLEN)


# ---------------------------------------------------------------------------
# wire layer: framing + the one-time handshake
# ---------------------------------------------------------------------------
def test_framing_roundtrip_and_peer_gone():
    a, b = socket.socketpair()
    obj = {"x": np.arange(5, dtype=np.int32), "y": [1, (2, 3)], "z": None}
    framing.send_frame(a, obj)
    out = framing.recv_frame(b)
    np.testing.assert_array_equal(out["x"], obj["x"])
    assert out["y"] == obj["y"] and out["z"] is None
    a.close()
    with pytest.raises(framing.PeerGone):
        framing.recv_frame(b)
    b.close()


def test_parse_addr():
    assert framing.parse_addr("127.0.0.1:7070") == ("127.0.0.1", 7070)
    for bad in ("nohost", ":7", "h:notaport"):
        with pytest.raises(ValueError):
            framing.parse_addr(bad)


def _fake_worker(version=WIRE_VERSION, **extra):
    """A listener answering one connection's handshake, nothing more."""
    lst = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    lst.bind(("127.0.0.1", 0))
    lst.listen(1)
    host, port = lst.getsockname()[:2]

    def serve():
        conn, _ = lst.accept()
        framing.recv_frame(conn)              # client hello
        framing.send_frame(conn, framing.hello("expert-worker", version,
                                               **extra))
        time.sleep(0.5)
        conn.close()
        lst.close()

    threading.Thread(target=serve, daemon=True).start()
    return host, port


def test_handshake_rejects_mismatched_server():
    """A frontend connecting to a worker from a different build must fail
    at connect time, naming both versions — never desync later."""
    host, port = _fake_worker(version=999, expert=0, replica=0)
    with pytest.raises(RuntimeError, match=rf"v999.*v{WIRE_VERSION}"):
        SocketTransport([(host, port)], expect=[(0, 0)])


def test_handshake_rejects_mismatched_client():
    """The server side of the same coin: a registry refuses a hello from
    the wrong build and ships the reason back before closing."""
    with Registry(ttl_s=1.0) as reg:
        sock = framing.connect(framing.parse_addr(reg.addr), 5.0)
        try:
            with pytest.raises(RuntimeError,
                               match=r"rejected.*v999"):
                framing.client_handshake(sock, role="frontend", version=999)
        finally:
            sock.close()


def test_socket_transport_placement_mismatch():
    """The worker's hello identity is cross-checked against the registry's
    claim: a stale entry or port collision fails loudly, not silently
    streaming against the wrong expert."""
    host, port = _fake_worker(expert=5, replica=0)
    with pytest.raises(RuntimeError, match=r"placement mismatch"):
        SocketTransport([(host, port)], expect=[(0, 0)])


# ---------------------------------------------------------------------------
# registry: discovery, heartbeats, leases (no jax needed)
# ---------------------------------------------------------------------------
def test_registry_register_heartbeat_expiry():
    with Registry(ttl_s=0.3) as reg:
        r0 = netreg.call(reg.addr, "register",
                         {"expert": 0, "host": "h", "port": 1})
        assert r0["replica"] == 0 and r0["ttl_s"] == pytest.approx(0.3)
        r1 = netreg.call(reg.addr, "register",
                         {"expert": 0, "host": "h", "port": 2})
        assert r1["replica"] == 1              # auto-assigned, not clobbered
        placed = netreg.call(reg.addr, "placements")
        # typed Placement records on the wire; iterating one still
        # yields the legacy (expert, replica, host, port) shape
        assert all(isinstance(p, Placement) for p in placed)
        assert [tuple(p) for p in placed] == [(0, 0, "h", 1), (0, 1, "h", 2)]
        assert netreg.call(reg.addr, "heartbeat", (0, 0)) == "ok"
        assert netreg.call(reg.addr, "heartbeat", (0, 7)) == "unknown"
        time.sleep(0.45)                       # both workers go silent
        assert netreg.call(reg.addr, "placements") == []
        # a late heartbeat revives exactly that worker, nothing else
        assert netreg.call(reg.addr, "heartbeat", (0, 0)) == "ok"
        assert [tuple(p) for p in netreg.call(reg.addr, "placements")] == \
            [(0, 0, "h", 1)]
        with pytest.raises(RuntimeError, match=r"no live worker for "
                                               r"expert\(s\)"):
            netreg.wait_for_fleet(reg.addr, 2, timeout=0.4)


def test_registry_lease_monotonic():
    with Registry(ttl_s=1.0) as reg:
        assert [netreg.call(reg.addr, "lease") for _ in range(3)] == [0, 1, 2]


# ---------------------------------------------------------------------------
# config validation (no fleet needed)
# ---------------------------------------------------------------------------
def test_tcp_requires_registry(mixture):
    expert_params, router_params = mixture
    with pytest.raises(ValueError, match="registry"):
        ServeFrontend(ECFG, RCFG, expert_params, router_params,
                      dataclasses.replace(ENG, transport="tcp"))


def test_replicas_arg_rejected_on_tcp(mixture):
    """On tcp the fleet is the source of truth for replication — a
    replica map would silently disagree with what actually registered."""
    expert_params, router_params = mixture
    with pytest.raises(ValueError, match="replicas"):
        ServeFrontend(ECFG, RCFG, expert_params, router_params,
                      dataclasses.replace(ENG, transport="tcp",
                                          registry="127.0.0.1:1"),
                      replicas={0: 2})


def test_uid_namespace_bounds(mixture):
    expert_params, router_params = mixture
    with pytest.raises(ValueError, match="uid_namespace"):
        ServeFrontend(ECFG, RCFG, expert_params, router_params, ENG,
                      uid_namespace=MAX_UID_NAMESPACE + 1)


# ---------------------------------------------------------------------------
# tcp frontend vs the serial oracle (in-process workers, real sockets)
# ---------------------------------------------------------------------------
def test_tcp_identity_smoke(mixture, fleet):
    """Greedy + sampled + early stops over real TCP: tokens bitwise
    identical to the baseline oracle, stats complete, correct routes."""
    expert_params, router_params = mixture
    rng = np.random.default_rng(90)
    R = 6
    prompts = [rng.integers(0, ECFG.vocab_size,
                            size=int(rng.integers(PREFIX, 30))).astype(np.int32)
               for _ in range(R)]
    n_new = [int(rng.integers(2, 7)) for _ in range(R)]
    sps = [None if i % 2 == 0 else
           SamplingParams(temperature=0.9, top_k=8, seed=70 + i)
           for i in range(R)]
    stops = [frozenset() if i % 3 else
             frozenset(int(t) for t in
                       rng.integers(0, ECFG.vocab_size, size=12))
             for i in range(R)]
    with ServeFrontend(ECFG, RCFG, expert_params, router_params,
                       _tcp(fleet), uid_namespace=0) as eng:
        reqs = [eng.submit(prompts[i], n_new[i], sampling=sps[i],
                           stop_tokens=stops[i], arrival_tick=i // 3)
                for i in range(R)]
        assert [r.uid for r in reqs] == list(range(R))
        res = eng.run()
    assert res["transport"] == "tcp"
    assert res["missing_replicas"] == []
    want_routes = baseline.route(RCFG, router_params,
                                 np.stack([p[:PREFIX] for p in prompts]),
                                 PREFIX)
    for r in res["requests"]:
        assert r.expert == want_routes[r.uid]
        want = _oracle(expert_params[r.expert], prompts[r.uid],
                       n_new[r.uid], sampling=sps[r.uid], uid=r.uid,
                       stops=stops[r.uid])
        np.testing.assert_array_equal(np.asarray(r.tokens), want,
                                      err_msg=f"uid {r.uid}")
    assert sum(s["served"] for s in res["per_expert"].values()) == R


def test_uid_namespace_lease_and_stride(mixture, fleet):
    """Frontends built without an explicit namespace lease one from the
    registry; uids start at namespace * stride and the oracle keyed on
    the full namespaced uid still matches bitwise."""
    expert_params, router_params = mixture
    rng = np.random.default_rng(91)
    prompt = rng.integers(0, ECFG.vocab_size, size=PREFIX).astype(np.int32)
    with ServeFrontend(ECFG, RCFG, expert_params, router_params,
                       _tcp(fleet)) as fa:
        ns = fa.uid_namespace
        r = fa.submit(prompt, 3,
                      sampling=SamplingParams(temperature=0.8, seed=5))
        assert r.uid == ns * UID_NAMESPACE_STRIDE
        fa.run()
        np.testing.assert_array_equal(
            np.asarray(r.tokens),
            _oracle(expert_params[r.expert], prompt, 3,
                    sampling=SamplingParams(temperature=0.8, seed=5),
                    uid=r.uid))
    with ServeFrontend(ECFG, RCFG, expert_params, router_params,
                       _tcp(fleet)) as fb:
        assert fb.uid_namespace > ns          # leases never repeat


def test_two_frontends_share_one_fleet(mixture, fleet):
    """Two stateless frontends, one fleet, interleaved step()s: disjoint
    uids, zero cross-frontend stream corruption, every request bitwise
    equal to the oracle keyed on its namespaced uid."""
    expert_params, router_params = mixture
    rng = np.random.default_rng(92)
    with ServeFrontend(ECFG, RCFG, expert_params, router_params,
                       _tcp(fleet)) as fa, \
            ServeFrontend(ECFG, RCFG, expert_params, router_params,
                          _tcp(fleet)) as fb:
        assert fa.uid_namespace != fb.uid_namespace
        reqs = []
        for k in range(8):
            front = fa if k % 2 == 0 else fb
            prompt = rng.integers(
                0, ECFG.vocab_size,
                size=int(rng.integers(PREFIX, 30))).astype(np.int32)
            sp = None if k % 3 == 0 else SamplingParams(
                temperature=float(rng.uniform(0.5, 1.2)), top_k=8,
                seed=int(rng.integers(0, 1 << 16)))
            reqs.append((front, prompt, sp,
                         front.submit(prompt, int(rng.integers(2, 6)),
                                      sampling=sp, arrival_tick=0)))
        while fa.busy or fb.busy:
            if fa.busy:
                fa.step()
            if fb.busy:
                fb.step()
    uids_a = {r.uid for f, _, _, r in reqs if f is fa}
    uids_b = {r.uid for f, _, _, r in reqs if f is fb}
    assert not uids_a & uids_b
    for _, prompt, sp, r in reqs:
        want = _oracle(expert_params[r.expert], prompt, r.max_new_tokens,
                       sampling=sp, uid=r.uid)
        np.testing.assert_array_equal(np.asarray(r.tokens), want,
                                      err_msg=f"uid {r.uid}")


def test_replicated_tcp_fleet(mixture):
    """Two workers for expert 0 (replica indices auto-assigned by the
    registry), one for expert 1: the frontend derives the replica map
    from the fleet, least-loaded admission spreads requests, and tokens
    stay placement-invariant."""
    expert_params, router_params = mixture
    rng = np.random.default_rng(93)
    with Registry(ttl_s=30.0) as reg:
        workers = [ExpertWorker(ECFG, ENG, expert_params[e], e,
                                registry=reg.addr, warmup_len=PREFIX).start()
                   for e in (0, 0, 1)]
        try:
            with ServeFrontend(ECFG, RCFG, expert_params, router_params,
                               _tcp(reg), uid_namespace=0) as eng:
                assert eng.replicas == [2, 1]
                assert [(p.expert, p.replica) for p in eng.placements] \
                    == [(0, 0), (0, 1), (1, 0)]
                prompts = [rng.integers(0, ECFG.vocab_size,
                                        size=PREFIX).astype(np.int32)
                           for _ in range(6)]
                reqs = [eng.submit(p, 4, arrival_tick=0) for p in prompts]
                res = eng.run()
            assert res["missing_replicas"] == []
            assert res["per_expert"][0]["replicas"] == 2
            assert set(res["per_expert"][0]["per_replica"]) <= {0, 1}
            for i, r in enumerate(reqs):
                np.testing.assert_array_equal(
                    np.asarray(r.tokens),
                    _oracle(expert_params[r.expert], prompts[i], 4,
                            uid=r.uid))
        finally:
            for w in workers:
                w.stop()


# ---------------------------------------------------------------------------
# failure semantics
# ---------------------------------------------------------------------------
def test_worker_death_mid_stream_names_placement(mixture):
    """Killing a worker mid-stream must raise a RuntimeError naming the
    expert placement and address — and the surviving slot keeps
    answering."""
    expert_params, router_params = mixture
    rng = np.random.default_rng(94)
    with Registry(ttl_s=30.0) as reg:
        workers = [ExpertWorker(ECFG, ENG, expert_params[e], e,
                                registry=reg.addr, warmup_len=PREFIX).start()
                   for e in range(E)]
        try:
            with ServeFrontend(ECFG, RCFG, expert_params, router_params,
                               _tcp(reg), uid_namespace=0) as eng:
                reqs = [eng.submit(
                    rng.integers(0, ECFG.vocab_size,
                                 size=PREFIX).astype(np.int32),
                    16, arrival_tick=0) for _ in range(4)]
                eng.step()                    # route + enqueue everything
                victim = reqs[0].expert
                workers[victim].stop()        # crash, not a polite close
                with pytest.raises(
                        RuntimeError,
                        match=rf"expert {victim} replica 0 worker at .* "
                              rf"died mid-stream"):
                    for _ in range(200):
                        eng.step()
                # the other expert's slot is still alive and answering
                survivors = [p.slot for p in eng.placements
                             if p.expert != victim]
                for s in survivors:
                    assert eng._transport.stats(s).version == WIRE_VERSION
        finally:
            for w in workers:
                w.stop()


def test_run_partial_stats_on_dead_replica(mixture, monkeypatch):
    """run()'s aggregation must tolerate a slot whose StatsMsg never
    arrives: partial sums plus an explicit missing_replicas entry,
    instead of losing the whole report."""
    expert_params, router_params = mixture
    rng = np.random.default_rng(95)
    eng = ServeFrontend(ECFG, RCFG, expert_params, router_params, ENG)
    for _ in range(6):
        eng.submit(rng.integers(0, ECFG.vocab_size,
                                size=PREFIX).astype(np.int32), 3,
                   arrival_tick=0)
    orig = eng._transport.stats

    def stats(s):
        if s == 0:
            raise RuntimeError("expert 0 worker died (synthetic)")
        return orig(s)

    monkeypatch.setattr(eng._transport, "stats", stats)
    res = eng.run()
    assert res["missing_replicas"] == ["expert 0 replica 0"]
    st0 = res["per_expert"][0]
    assert st0["missing_replicas"] == [0]
    assert st0["served"] == 0 and st0["per_replica"] == {}
    assert st0["peak_blocks"] == 0            # max over no live replicas
    st1 = res["per_expert"][1]
    assert st1["missing_replicas"] == [] and st1["served"] >= 0


# ---------------------------------------------------------------------------
# the standalone entry points: real subprocesses (slow)
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_local_fleet_subprocess_end_to_end(mixture):
    """LocalFleet shells out to the real module CLIs — one registry and
    one expert_worker process per expert, params re-derived from the
    seed — and a tcp frontend must still match the oracle bitwise."""
    from repro.serving.net.fleet import LocalFleet
    expert_params, router_params = mixture
    rng = np.random.default_rng(96)
    prompts = [rng.integers(0, ECFG.vocab_size,
                            size=int(rng.integers(PREFIX, 28))).astype(np.int32)
               for _ in range(4)]
    sps = [None, SamplingParams(temperature=0.9, top_k=8, seed=11),
           None, SamplingParams(temperature=1.1, top_p=0.9, seed=12)]
    # seed=0 re-derives exactly the mixture fixture's expert params
    # (init_params(fold_in(PRNGKey(0), e))) inside each worker process
    with LocalFleet(ECFG, ENG, E, seed=0, warmup_len=PREFIX) as fleet:
        eng_cfg = dataclasses.replace(ENG, transport="tcp",
                                      registry=fleet.registry_addr)
        with ServeFrontend(ECFG, RCFG, expert_params, router_params,
                           eng_cfg, uid_namespace=0) as eng:
            reqs = [eng.submit(prompts[i], 4, sampling=sps[i],
                               arrival_tick=0) for i in range(4)]
            res = eng.run()
    assert res["transport"] == "tcp" and res["missing_replicas"] == []
    for i, r in enumerate(reqs):
        want = _oracle(expert_params[r.expert], prompts[i], 4,
                       sampling=sps[i], uid=r.uid)
        np.testing.assert_array_equal(np.asarray(r.tokens), want,
                                      err_msg=f"uid {r.uid}")
