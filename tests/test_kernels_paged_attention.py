"""Paged-attention decode kernel: dispatch validation + fuzz identity.

Three layers of oracle, locked together over random pool recipes
(lanes, block_size, table width, GQA ratios, head dims, ragged live
lengths, retired lanes, softcap on/off):

  1. the *resurrected gather path* below is a verbatim copy of the
     decode read that lived inline in ``models/common.attn_apply``
     before this kernel subpackage existed — ``ref.py`` must match it
     **bitwise**, because the engine's token-identity contract vs
     ``serving/baseline.py`` (greedy AND sampled) rides on that read;
  2. the Pallas kernel under ``interpret=True`` must match ``ref.py``
     within fp tolerance on live lanes (dead-lane output is unspecified:
     the kernel emits zeros where the gather's degenerate softmax emits
     a uniform average);
  3. the unified ``ops.decode_attention`` dispatch must reject bad
     ``impl`` values and layout/impl combinations loudly instead of
     silently falling back.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.paged_attention import ops, ref
from repro.kernels.paged_attention.paged_attention import (
    paged_decode_attention_pallas)


def _old_gather_decode(q, k_pool, v_pool, pos_pool, block_tables, *, q_pos,
                       softcap=0.0):
    """The deleted inline gather from ``attn_apply`` (pre-kernel), kept
    here test-only as the bitwise anchor for ``ref.py``."""
    B, nb = block_tables.shape
    bs = k_pool.shape[1]
    hkv, hd = k_pool.shape[2], k_pool.shape[3]
    scratch = k_pool.shape[0] - 1
    safe = jnp.where(block_tables >= 0, block_tables, scratch)
    kl = k_pool[safe].reshape(B, nb * bs, hkv, hd)
    vl = v_pool[safe].reshape(B, nb * bs, hkv, hd)
    pl = jnp.where(block_tables[..., None] >= 0, pos_pool[safe],
                   -1).reshape(B, nb * bs)
    return fa_ref.decode_attention_ref(q, kl, vl, q_pos=q_pos, kv_pos=pl,
                                       window=0, softcap=softcap)


def _random_pool(rng, *, B, nb, bs, Hq, Hkv, hd, dtype=jnp.float32):
    """A random but engine-shaped paged decode problem.

    Per lane: a live length in [0, nb*bs) (or a retired lane with
    ``q_pos = -1`` and an all ``-1`` table row), enough distinct pool
    blocks to cover it, written positions 0..q_pos in slab order, and -1
    position markers everywhere else.  Unwritten pool slots keep random
    K/V garbage; a slice of them also gets *stale position garbage*
    (> q_pos or from a previous tenant) that masking must hide.
    """
    n_blocks = B * nb + 2                      # pool + slack, + scratch row
    kshape = (n_blocks + 1, bs, Hkv, hd)
    k_pool = rng.standard_normal(kshape).astype(np.float32)
    v_pool = rng.standard_normal(kshape).astype(np.float32)
    pos_pool = np.full((n_blocks + 1, bs), -1, np.int32)

    free = list(rng.permutation(n_blocks))
    tables = np.full((B, nb), -1, np.int32)
    q_pos = np.full((B, 1), -1, np.int32)
    for b in range(B):
        if rng.random() < 0.2:
            continue                           # retired lane: all -1, pos -1
        live = int(rng.integers(1, nb * bs))   # tokens written incl. current
        q_pos[b, 0] = live - 1
        need = -(-live // bs)
        blocks = [free.pop() for _ in range(need)]
        tables[b, :need] = blocks
        for p in range(live):
            pos_pool[blocks[p // bs], p % bs] = p
        # stale garbage the mask must hide: a future position in the last
        # reserved block, beyond the written prefix
        if live % bs and rng.random() < 0.5:
            pos_pool[blocks[-1], live % bs] = live + int(rng.integers(1, 8))
    q = rng.standard_normal((B, 1, Hq, hd)).astype(np.float32)
    return (jnp.asarray(q, dtype), jnp.asarray(k_pool, dtype),
            jnp.asarray(v_pool, dtype), jnp.asarray(pos_pool),
            jnp.asarray(tables), jnp.asarray(q_pos))


N_FUZZ = 25


@pytest.mark.parametrize("seed", range(N_FUZZ))
def test_fuzz_ref_bitwise_vs_old_gather_and_pallas_close(seed):
    rng = np.random.default_rng(2000 + seed)
    B = int(rng.integers(1, 5))
    nb = int(rng.integers(1, 5))
    bs = int(rng.choice([4, 8, 16]))
    Hkv = int(rng.choice([1, 2, 4]))
    g = int(rng.choice([1, 2, 4]))
    hd = int(rng.choice([8, 16, 32]))
    softcap = float(rng.choice([0.0, 30.0]))
    q, k_pool, v_pool, pos_pool, tables, q_pos = _random_pool(
        rng, B=B, nb=nb, bs=bs, Hq=Hkv * g, Hkv=Hkv, hd=hd)

    want = _old_gather_decode(q, k_pool, v_pool, pos_pool, tables,
                              q_pos=q_pos, softcap=softcap)
    got_ref = ref.paged_decode_attention_ref(q, k_pool, v_pool, pos_pool,
                                             tables, q_pos=q_pos,
                                             softcap=softcap)
    # 1. jnp ref == resurrected gather path, bit for bit (all lanes)
    np.testing.assert_array_equal(np.asarray(got_ref), np.asarray(want),
                                  err_msg=f"seed {seed}")

    got_pl = paged_decode_attention_pallas(q, k_pool, v_pool, pos_pool,
                                           tables, q_pos=q_pos,
                                           softcap=softcap, interpret=True)
    # 2. Pallas(interpret) == ref within fp tolerance on live lanes
    live = np.asarray(q_pos)[:, 0] >= 0
    np.testing.assert_allclose(np.asarray(got_pl)[live],
                               np.asarray(got_ref)[live],
                               rtol=2e-5, atol=2e-5,
                               err_msg=f"seed {seed}")
    # dead lanes: kernel output defined as zeros (engine discards it)
    assert (np.asarray(got_pl)[~live] == 0.0).all()


def test_single_live_block_matches_full_attention_row():
    """One lane, one block: paged decode == last row of dense attention."""
    rng = np.random.default_rng(3)
    bs, Hkv, g, hd = 8, 2, 2, 16
    S = 6
    q, k_pool, v_pool, pos_pool, tables, q_pos = _random_pool(
        rng, B=1, nb=1, bs=bs, Hq=Hkv * g, Hkv=Hkv, hd=hd)
    tables = jnp.asarray([[0]], jnp.int32)
    q_pos = jnp.asarray([[S - 1]], jnp.int32)
    pos_pool = pos_pool.at[0].set(jnp.where(jnp.arange(bs) < S,
                                            jnp.arange(bs), -1))
    k = k_pool[0, :S][None]
    v = v_pool[0, :S][None]
    full = fa_ref.mha_reference(jnp.broadcast_to(q[:, 0:1, :, :],
                                                 (1, 1, Hkv * g, hd)),
                                k, v, causal=False)
    got = ref.paged_decode_attention_ref(q, k_pool, v_pool, pos_pool, tables,
                                         q_pos=q_pos)
    np.testing.assert_allclose(np.asarray(got)[0, 0], np.asarray(full)[0, -1],
                               rtol=1e-5, atol=1e-5)


def test_ops_dispatch_validates_impl():
    rng = np.random.default_rng(5)
    q, k_pool, v_pool, pos_pool, tables, q_pos = _random_pool(
        rng, B=2, nb=2, bs=4, Hq=2, Hkv=2, hd=8)
    with pytest.raises(ValueError, match="impl must be one of"):
        ops.decode_attention(q, k_pool, v_pool, q_pos=q_pos, kv_pos=pos_pool,
                             block_tables=tables, impl="triton")
    # dense layout has no Pallas kernel: loud error, not a silent fallback
    k = jnp.zeros((2, 8, 2, 8))
    kv_pos = jnp.zeros((2, 8), jnp.int32)
    with pytest.raises(ValueError, match="dense / sliding-window"):
        ops.decode_attention(q, k, k, q_pos=q_pos, kv_pos=kv_pos,
                             impl="pallas")
    # paged KV is full attention by construction
    with pytest.raises(ValueError, match="full-attention"):
        ops.decode_attention(q, k_pool, v_pool, q_pos=q_pos, kv_pos=pos_pool,
                             block_tables=tables, window=8)


def test_ops_dispatch_routes_both_impls():
    """impl='jnp' and impl='pallas' agree through the public entry point,
    and the dense branch reproduces the flash decode reference."""
    rng = np.random.default_rng(6)
    q, k_pool, v_pool, pos_pool, tables, q_pos = _random_pool(
        rng, B=3, nb=3, bs=8, Hq=4, Hkv=2, hd=16)
    a = ops.decode_attention(q, k_pool, v_pool, q_pos=q_pos, kv_pos=pos_pool,
                             block_tables=tables, softcap=20.0, impl="jnp")
    b = ops.decode_attention(q, k_pool, v_pool, q_pos=q_pos, kv_pos=pos_pool,
                             block_tables=tables, softcap=20.0, impl="pallas")
    live = np.asarray(q_pos)[:, 0] >= 0
    np.testing.assert_allclose(np.asarray(b)[live], np.asarray(a)[live],
                               rtol=2e-5, atol=2e-5)

    B, S, Hkv, hd = 2, 9, 2, 8
    qd = jnp.asarray(rng.standard_normal((B, 1, 4, hd)), jnp.float32)
    kd = jnp.asarray(rng.standard_normal((B, S, Hkv, hd)), jnp.float32)
    vd = jnp.asarray(rng.standard_normal((B, S, Hkv, hd)), jnp.float32)
    kv_pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    qp = jnp.full((B, 1), S - 1, jnp.int32)
    dense = ops.decode_attention(qd, kd, vd, q_pos=qp, kv_pos=kv_pos,
                                 window=4, impl="jnp")
    want = fa_ref.decode_attention_ref(qd, kd, vd, q_pos=qp, kv_pos=kv_pos,
                                       window=4)
    np.testing.assert_array_equal(np.asarray(dense), np.asarray(want))
