"""End-to-end paper claim at micro scale: a routed mixture of 2 experts
beats (i) a dense model trained on the same TOTAL tokens and (ii) an
unrouted single expert — on a 2-domain corpus this is the purest form of
Fig. 2 / Fig. 5."""
import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import em, mixture as mixlib
from repro.data import DataConfig, Stream, SyntheticCorpus, make_lm_batch
from repro.models import model as modellib
from repro.optim import AdamWConfig

RCFG = ModelConfig(name="e2e-router", n_layers=2, d_model=48, n_heads=4,
                   n_kv_heads=4, d_ff=192, vocab_size=128, ffn_type="gelu",
                   loss_chunk=32)
ECFG = ModelConfig(name="e2e-expert", n_layers=2, d_model=96, n_heads=4,
                   n_kv_heads=4, d_ff=384, vocab_size=128, ffn_type="gelu",
                   loss_chunk=32)


@pytest.mark.slow
def test_mixture_beats_dense_and_unrouted():
    corpus = SyntheticCorpus(DataConfig(vocab_size=128, seq_len=48,
                                        n_domains=2))
    emcfg = em.EMConfig(n_experts=2, prefix_len=24, em_iters=4,
                        chunk_size=1024, steps_per_iter=60, batch_size=32,
                        lr=3e-3)
    key = jax.random.PRNGKey(0)
    state = em.train_routers(corpus, RCFG, emcfg, key)
    assert state.history[-1]["purity"] > 0.9

    assign, doms, _ = em.shard_corpus(state, RCFG, corpus, 2048, emcfg)
    E, steps, bs = 2, 120, 16
    opt = AdamWConfig(peak_lr=2e-3, warmup_steps=10, total_steps=steps,
                      clip_norm=1.0)
    mix = mixlib.train_mixture_experts(ECFG, corpus, assign, steps, bs, opt,
                                       key, router_state=state,
                                       prefix_len=24, router_cfg=RCFG)
    dense = modellib.init_params(key, ECFG)
    optd = AdamWConfig(peak_lr=2e-3, warmup_steps=10, total_steps=E * steps,
                       clip_norm=1.0)
    dense, _ = mixlib.train_expert(ECFG, dense, Stream(corpus, bs), E * steps,
                                   optd)

    held = corpus.sequences(np.arange(50_000, 50_000 + 256))
    batch = make_lm_batch(*held)
    ppl_mix, eids, nll = mixlib.mixture_eval_ppl(mix, batch,
                                                 return_routes=True)
    ppl_dense = mixlib.dense_eval_ppl(ECFG, dense, batch)
    ppl_single = mixlib.dense_eval_ppl(ECFG, mix.expert_params[0], batch)

    # the paper's headline (Fig. 2): better ppl at equal total tokens
    assert ppl_mix < ppl_dense, (ppl_mix, ppl_dense)
    # routing matters: one expert alone is worse
    assert ppl_mix < ppl_single, (ppl_mix, ppl_single)
    # Fig. 5: every expert serves a substantial share
    shares = np.bincount(eids, minlength=2) / len(eids)
    assert shares.min() > 0.2, shares
    # routing recovers domains
    assert em.domain_purity(eids, held[1], 2) > 0.9


def test_route_uses_only_prefix():
    """Routing must depend only on the first M tokens (Eq. 8)."""
    mixst = mixlib.MixtureState(
        expert_cfg=ECFG, router_cfg=RCFG, expert_params=[],
        router_params=__import__("repro.core.router",
                                 fromlist=["router"]).init_ensemble(
            jax.random.PRNGKey(0), RCFG, 2),
        prefix_len=8)
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, 128)
    r1 = np.asarray(mixlib.route(mixst, toks))
    corrupted = toks.at[:, 8:].set(0)
    r2 = np.asarray(mixlib.route(mixst, corrupted))
    np.testing.assert_array_equal(r1, r2)
