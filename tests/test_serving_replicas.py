"""Hot-expert replication, least-loaded admission, and the wire version.

The frontend may run R >= 1 server slots per expert (``replicas=`` map):
same params, disjoint KV pools, requests admitted to the least-loaded
replica of their argmax expert.  The paper's no-talk premise is what
makes this free — replicas never learn of each other — and the
counter-based sampler (``(seed, uid, step)``) is what makes it safe:
tokens cannot depend on replica placement.  These tests pin that down:

* replica-invariance fuzz — ``replicas=1`` vs ``{0: 2, 1: 3}`` streams
  bitwise equal, both equal to the serial oracle;
* least-loaded admission units — a hot expert's requests spread across
  its replicas, ties break deterministically to replica 0;
* a dead replica surfaces a ``RuntimeError`` naming the expert AND the
  replica (slow, process transport);
* the explicit wire ``version`` on every message — a mismatch is
  rejected loudly at the transport boundary;
* ``StatsMsg.pending``/``active_lanes`` as the ground truth the
  sender-side ``Transport.load`` tracker is checked against;
* the consolidated API — ``repro.serving`` exports :class:`Placement`
  / :class:`PlacementMap` and no longer ships the retired
  ``MixtureServeEngine`` facade;
* ``repro.serving.cli.parse_replicas`` spec parsing.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import router as routerlib
from repro.models import model as modellib
from repro.serving import (EngineConfig, ExpertServer, LoopbackTransport,
                           Placement, PlacementMap, RequestMsg,
                           SamplingParams, ServeFrontend, WIRE_VERSION,
                           baseline, check_version)
from repro.serving.cli import parse_replicas

ECFG = ModelConfig(name="rep-expert", n_layers=2, d_model=64, n_heads=4,
                   n_kv_heads=4, d_ff=128, vocab_size=128, ffn_type="gelu",
                   loss_chunk=32, compute_dtype="float32",
                   param_dtype="float32")
RCFG = ModelConfig(name="rep-router", n_layers=1, d_model=32, n_heads=2,
                   n_kv_heads=2, d_ff=64, vocab_size=128, ffn_type="gelu",
                   loss_chunk=32, compute_dtype="float32",
                   param_dtype="float32")
E, PREFIX, MAXLEN, BS = 2, 16, 48, 16
ENG = EngineConfig(lanes_per_expert=2, max_len=MAXLEN, prefix_len=PREFIX,
                   block_size=BS, route_batch=4)


@pytest.fixture(scope="module")
def mixture():
    key = jax.random.PRNGKey(0)
    router_params = routerlib.init_ensemble(key, RCFG, E)
    expert_params = [modellib.init_params(jax.random.fold_in(key, e), ECFG)
                     for e in range(E)]
    return expert_params, router_params


def _oracle(params, prompt, n_new, sampling=None, uid=0, stops=()):
    return baseline.generate_request(ECFG, params, prompt, n_new,
                                     sampling=sampling, uid=uid,
                                     stop_tokens=stops, cache_len=MAXLEN)


def _workload(rng, n):
    prompts = [rng.integers(0, ECFG.vocab_size,
                            size=int(rng.integers(PREFIX, 30))).astype(np.int32)
               for _ in range(n)]
    n_new = [int(rng.integers(2, 7)) for _ in range(n)]
    sps = [None if rng.random() < 0.4 else
           SamplingParams(temperature=float(rng.uniform(0.3, 1.3)),
                          top_k=int(rng.choice([0, 2, 8])),
                          seed=int(rng.integers(0, 1 << 16)))
           for _ in range(n)]
    stops = [frozenset(int(t) for t in
                       rng.integers(0, ECFG.vocab_size, size=8))
             if rng.random() < 0.5 else frozenset() for _ in range(n)]
    return prompts, n_new, sps, stops


def _serve(mixture, prompts, n_new, sps, stops, arrivals, replicas=None):
    expert_params, router_params = mixture
    with ServeFrontend(ECFG, RCFG, expert_params, router_params, ENG,
                       replicas=replicas) as eng:
        reqs = [eng.submit(prompts[i], n_new[i], sampling=sps[i],
                           stop_tokens=stops[i], arrival_tick=arrivals[i])
                for i in range(len(prompts))]
        res = eng.run()
    return reqs, res


# ---------------------------------------------------------------------------
# replica invariance: tokens cannot depend on placement
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed", range(3))
def test_replica_invariance_fuzz(mixture, seed):
    """Acceptance: the same workload served with one server per expert
    and with replicas {0: 2, 1: 3} yields bitwise-identical tokens, both
    equal to the serial oracle — replica placement is unobservable."""
    expert_params, _ = mixture
    rng = np.random.default_rng(9100 + seed)
    n = int(rng.integers(5, 9))
    prompts, n_new, sps, stops = _workload(rng, n)
    arrivals = [int(rng.integers(0, 4)) for _ in range(n)]
    r1, _ = _serve(mixture, prompts, n_new, sps, stops, arrivals)
    rR, resR = _serve(mixture, prompts, n_new, sps, stops, arrivals,
                      replicas={0: 2, 1: 3})
    assert len(rR) == n
    for a, b in zip(r1, rR):
        assert a.uid == b.uid and a.expert == b.expert
        assert a.tokens == b.tokens, f"seed {seed} uid {a.uid}"
        want = _oracle(expert_params[a.expert], prompts[a.uid],
                       n_new[a.uid], sampling=sps[a.uid], uid=a.uid,
                       stops=stops[a.uid])
        np.testing.assert_array_equal(np.asarray(b.tokens), want,
                                      err_msg=f"seed {seed} uid {a.uid}")
    # the replicated run really used several slots per expert
    assert resR["per_expert"][0]["replicas"] == 2
    assert resR["per_expert"][1]["replicas"] == 3
    served = sum(s["served"] for s in resR["per_expert"].values())
    assert served == n


# ---------------------------------------------------------------------------
# least-loaded admission
# ---------------------------------------------------------------------------
def test_least_loaded_spreads_hot_expert(mixture):
    """Identical prompts all route to one expert; with 2 replicas the
    load tracker must alternate them, so both replicas end up serving."""
    expert_params, router_params = mixture
    rng = np.random.default_rng(9200)
    prompt = rng.integers(0, ECFG.vocab_size, size=PREFIX).astype(np.int32)
    with ServeFrontend(ECFG, RCFG, expert_params, router_params, ENG,
                       replicas={0: 2, 1: 2}) as eng:
        reqs = [eng.submit(prompt, 3, arrival_tick=0) for _ in range(6)]
        res = eng.run()
    e = reqs[0].expert
    assert all(r.expert == e for r in reqs)       # same prompt, same expert
    # simultaneous arrivals: load increments on every enqueue, so the
    # picks alternate 0,1,0,1,... deterministically
    assert [r.replica for r in reqs] == [0, 1, 0, 1, 0, 1]
    per_rep = res["per_expert"][e]["per_replica"]
    assert {rr: st["served"] for rr, st in per_rep.items()} == {0: 3, 1: 3}
    # the cold expert's replicas exist but served nothing
    cold = res["per_expert"][1 - e]
    assert cold["served"] == 0 and cold["replicas"] == 2


def test_tie_break_goes_to_lowest_replica(mixture):
    """All replicas idle = all loads equal: the first request must land
    on replica 0 (deterministic placement, not dict order)."""
    expert_params, router_params = mixture
    rng = np.random.default_rng(9201)
    prompt = rng.integers(0, ECFG.vocab_size, size=PREFIX).astype(np.int32)
    with ServeFrontend(ECFG, RCFG, expert_params, router_params, ENG,
                       replicas={0: 3, 1: 3}) as eng:
        r = eng.submit(prompt, 2, arrival_tick=0)
        eng.run()
    assert r.replica == 0


def test_replicas_map_validated(mixture):
    expert_params, router_params = mixture
    with pytest.raises(ValueError, match="names expert 5"):
        ServeFrontend(ECFG, RCFG, expert_params, router_params, ENG,
                      replicas={5: 2})
    with pytest.raises(ValueError, match=">= 1 replica"):
        ServeFrontend(ECFG, RCFG, expert_params, router_params, ENG,
                      replicas={0: 0})


# ---------------------------------------------------------------------------
# wire version: mismatches rejected loudly at the boundary
# ---------------------------------------------------------------------------
def test_wire_version_mismatch_rejected(mixture):
    expert_params, _ = mixture
    rng = np.random.default_rng(9300)
    prompt = rng.integers(0, ECFG.vocab_size, size=PREFIX).astype(np.int32)
    msg = RequestMsg(uid=0, prompt=prompt, max_new_tokens=2,
                     sampling=SamplingParams(), stop_tokens=frozenset(),
                     enqueue_tick=0)
    assert msg.version == WIRE_VERSION
    assert check_version(msg) is msg
    lt = LoopbackTransport([ExpertServer(ECFG, expert_params[0], ENG)])
    stale = dataclasses.replace(msg, version=99)
    with pytest.raises(RuntimeError, match="wire protocol mismatch"):
        lt.enqueue(0, stale)
    with pytest.raises(RuntimeError, match="version None"):
        check_version(object())
    lt.enqueue(0, msg)                     # current version passes
    while lt.busy(0):
        lt.tick(0)
    assert lt.stats(0).version == WIRE_VERSION


def test_stats_msg_is_load_ground_truth(mixture):
    """``load(s)`` is tracked sender-side; ``StatsMsg.pending`` +
    ``active_lanes`` is the server's own word — they must agree, both
    mid-flight (queued + decoding) and when drained."""
    expert_params, _ = mixture
    rng = np.random.default_rng(9301)
    lt = LoopbackTransport([ExpertServer(ECFG, expert_params[0], ENG)])
    for uid in range(3):                   # lanes=2: one must queue
        prompt = rng.integers(0, ECFG.vocab_size,
                              size=PREFIX).astype(np.int32)
        lt.enqueue(0, RequestMsg(uid=uid, prompt=prompt, max_new_tokens=4,
                                 sampling=SamplingParams(),
                                 stop_tokens=frozenset(), enqueue_tick=0))
    assert lt.load(0) == 3
    lt.tick(0)                             # admits up to `lanes` requests
    st = lt.stats(0)
    assert st.pending == 1 and st.active_lanes == 2
    assert lt.load(0) == st.pending + st.active_lanes == 3
    while lt.busy(0):
        lt.tick(0)
    st = lt.stats(0)
    assert lt.load(0) == st.pending + st.active_lanes == 0


# ---------------------------------------------------------------------------
# consolidated API: ServeFrontend is the entry point, Placement is public
# ---------------------------------------------------------------------------
def test_facade_is_gone_and_placement_is_public(mixture):
    """The one-release ``MixtureServeEngine`` deprecation window closed:
    the alias and its ``engine.py`` home are removed, ``bucket_len``
    re-exports from the package root, and the placement vocabulary the
    frontend speaks is first-class."""
    import repro.serving as serving
    assert not hasattr(serving, "MixtureServeEngine")
    with pytest.raises(ModuleNotFoundError):
        import repro.serving.engine  # noqa: F401
    from repro.serving import bucket_len
    from repro.serving.expert_server import bucket_len as real
    assert bucket_len is real

    expert_params, router_params = mixture
    eng = ServeFrontend(ECFG, RCFG, expert_params, router_params, ENG,
                        replicas={0: 2})
    assert isinstance(eng.placements, PlacementMap)
    by_key = {(p.expert, p.replica): p for p in eng.placements}
    assert set(by_key) == {(0, 0), (0, 1), (1, 0)}
    p = by_key[(0, 1)]
    assert isinstance(p, Placement)
    assert p.label == "expert 0 replica 1"
    assert eng.placements.get(p.slot) is p
    assert eng.placements.slots_of(0) == [by_key[(0, 0)].slot, p.slot]


def test_parse_replicas_spec():
    assert parse_replicas("") == {}
    assert parse_replicas("0:2") == {0: 2}
    assert parse_replicas(" 0:2 , 3:4 ") == {0: 2, 3: 4}
    with pytest.raises(ValueError, match="EXPERT:COUNT"):
        parse_replicas("0")
    with pytest.raises(ValueError, match="EXPERT:COUNT"):
        parse_replicas("0:x")
    with pytest.raises(ValueError, match="twice"):
        parse_replicas("0:2,0:3")


def test_duplicate_replicas_rejected_by_every_frontend(capsys):
    """Satellite: ``--replicas 0:2,0:3`` (one expert, two counts) must
    die at argument parsing in all three front-ends — and since
    :class:`ReplicaSpecError` is also an ``argparse.ArgumentTypeError``,
    the "names expert 0 twice" diagnosis reaches stderr instead of being
    swallowed into argparse's generic "invalid value"."""
    import importlib
    import pathlib
    import sys
    root = pathlib.Path(__file__).resolve().parents[1]
    for extra in ("examples", "benchmarks"):
        p = str(root / extra)
        if p not in sys.path:
            sys.path.append(p)
    parsers = {
        "launch": importlib.import_module("repro.launch.serve").build_parser,
        "example": importlib.import_module("serve_mixture").build_parser,
        "bench": importlib.import_module("serve_bench").build_parser,
    }
    for name, build in parsers.items():
        ap = build()
        with pytest.raises(SystemExit):
            ap.parse_args(["--replicas", "0:2,0:3"])
        err = capsys.readouterr().err
        assert "twice" in err and "expert 0" in err, (name, err)
        # a well-formed spec still parses identically everywhere
        assert build().parse_args(["--replicas", "1:2"]).replicas == {1: 2}


# ---------------------------------------------------------------------------
# process transport (slow: one spawned jax worker per slot)
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_process_transport_replica_identity_smoke(mixture):
    """2 replicas of expert 0 = 3 worker processes; tokens must stay
    bitwise identical to the serial oracle, and a replica worker killed
    under the engine must surface an error naming expert AND replica."""
    expert_params, router_params = mixture
    rng = np.random.default_rng(9400)
    n = 6
    prompts, n_new, sps, stops = _workload(rng, n)
    eng = ServeFrontend(
        ECFG, RCFG, expert_params, router_params,
        EngineConfig(lanes_per_expert=2, max_len=MAXLEN, prefix_len=PREFIX,
                     block_size=BS, route_batch=4, transport="process"),
        replicas={0: 2})
    with eng:
        assert eng._transport.labels == ["expert 0 replica 0",
                                         "expert 0 replica 1",
                                         "expert 1 replica 0"]
        reqs = [eng.submit(prompts[i], n_new[i], sampling=sps[i],
                           stop_tokens=stops[i], arrival_tick=i // 3)
                for i in range(n)]
        res = eng.run()
        assert len(res["requests"]) == n
        for r in res["requests"]:
            want = _oracle(expert_params[r.expert], prompts[r.uid],
                           n_new[r.uid], sampling=sps[r.uid], uid=r.uid,
                           stops=stops[r.uid])
            np.testing.assert_array_equal(np.asarray(r.tokens), want,
                                          err_msg=f"uid {r.uid}")
        assert res["per_expert"][0]["replicas"] == 2
        # dead-replica surfacing: kill slot 1 (expert 0, replica 1) and
        # the next op on it must name the placement, not a bare index
        tr = eng._transport
        tr._procs[1].terminate()
        tr._procs[1].join(timeout=10)
        with pytest.raises(RuntimeError, match="expert 0 replica 1"):
            tr.tick(1)
        # after a worker failure the transport refuses further traffic
        with pytest.raises(RuntimeError, match="broken"):
            tr.stats(0)
