"""Reduced-mesh dry-run integration tests.

Spawn subprocesses so the 8-fake-device XLA flag never leaks into this
process (smoke tests and benches must see 1 device).  Each subprocess
lowers + compiles train/prefill/decode for a smoke config on a (2,2) mesh
and the SmallTalk stacked step on a (2,2,2) mesh, asserting ZERO
pod-crossing collectives for the latter (the paper's claim, in the IR).
"""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config, smoke_variant
from repro.launch import hlo_cost, specs as speclib, steps as steplib
from repro.launch.mesh import make_test_mesh
from repro.models import model as modellib
from repro.parallel import act_sharding, sharding as shlib

arch, mode = sys.argv[1], sys.argv[2]
cfg = smoke_variant(get_config(arch))
mesh = make_test_mesh(multi_pod=(mode == "smalltalk"))
opt_cfg = steplib.default_opt_cfg(cfg)
named = lambda t: jax.tree_util.tree_map(
    lambda s: NamedSharding(mesh, s), t, is_leaf=lambda x: isinstance(x, P))
out = {}

def lower(step, args, shardings):
    with mesh, act_sharding.use(mesh):
        comp = jax.jit(step, in_shardings=shardings).lower(*args).compile()
    return comp

B, S = 8, 64
params = jax.eval_shape(lambda k: modellib.init_params(k, cfg),
                        jax.random.PRNGKey(0))
psh = shlib.param_specs(params, mesh, fsdp=False)

if mode == "smalltalk":
    from repro.launch.dryrun import _stack_spec, _stack_struct
    opt = speclib.opt_struct(params, opt_cfg)
    osh = shlib.opt_state_specs(psh, mesh, fsdp=False, params_shape=params)
    batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
             "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    bsh = shlib.batch_specs(batch, mesh, "data")
    E = 2
    params, opt, batch = (_stack_struct(t, E) for t in (params, opt, batch))
    psh, osh, bsh = (_stack_spec(t) for t in (psh, osh, bsh))
    step = steplib.build_mixture_train_step(cfg, opt_cfg)
    comp = lower(step, (params, opt, batch), (named(psh), named(osh), named(bsh)))
    cost = hlo_cost.analyze(comp.as_text(), pod_boundary=4)
    out["pod_crossing_bytes"] = cost.coll_pod_bytes
    out["collective_bytes"] = cost.coll_bytes
elif mode == "dense_train":
    opt = speclib.opt_struct(params, opt_cfg)
    osh = shlib.opt_state_specs(psh, mesh, fsdp=False, params_shape=params)
    batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
             "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    bsh = shlib.batch_specs(batch, mesh, "data")
    step = steplib.build_train_step(cfg, opt_cfg)
    comp = lower(step, (params, opt, batch), (named(psh), named(osh), named(bsh)))
    cost = hlo_cost.analyze(comp.as_text())
    out["flops"] = cost.flops
elif mode == "decode":
    batch = {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
             "positions": jax.ShapeDtypeStruct((B, 1), jnp.int32),
             "cache_index": jax.ShapeDtypeStruct((), jnp.int32)}
    caches = modellib.cache_specs(cfg, B, S)
    bsh = shlib.batch_specs(batch, mesh, "data")
    csh = shlib.cache_tree_specs(caches, mesh)
    step = steplib.build_decode_step(cfg)
    comp = lower(step, (params, batch, caches),
                 (named(psh), named(bsh), named(csh)))
    out["ok"] = True
out["status"] = "OK"
print("RESULT " + json.dumps(out))
"""


def run(arch: str, mode: str) -> dict:
    env = dict(os.environ, PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", SCRIPT, arch, mode],
                       capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["qwen2-1.5b", "zamba2-1.2b", "grok-1-314b"])
def test_dense_train_lowers(arch):
    assert run(arch, "dense_train")["status"] == "OK"


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["gemma2-27b", "xlstm-1.3b"])
def test_decode_lowers(arch):
    assert run(arch, "decode")["status"] == "OK"


@pytest.mark.slow
def test_smalltalk_pod_axis_has_zero_collectives():
    """The paper's communication claim, verified in the compiled HLO:
    expert-parallel training has NO collectives crossing the pod axis."""
    out = run("qwen2-1.5b", "smalltalk")
    assert out["status"] == "OK"
    assert out["pod_crossing_bytes"] == 0.0, out
    assert out["collective_bytes"] > 0          # intra-pod TP/DP still there
