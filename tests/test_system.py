"""System-level behaviour tests for the SmallTalk LM framework."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, list_configs, smoke_variant
from repro.configs.archs import ASSIGNED_NAMES


def test_all_assigned_archs_registered():
    names = list_configs()
    for a in ASSIGNED_NAMES:
        assert a in names
    assert len(ASSIGNED_NAMES) == 10
    # the paper's own models too
    for n in ("smalltalk-335m", "smalltalk-1.3b", "router-4m", "router-64m",
              "router-110m"):
        assert n in names


def test_router_4m_is_4m():
    from repro.models import model as modellib
    cfg = get_config("router-4m")
    params = modellib.init_params(jax.random.PRNGKey(0), cfg)
    n = modellib.param_count(params)
    # paper Table 1: 4.4M params (we tie embeddings; trunk ~1.3M + embed 3.1M)
    assert 3e6 < n < 6e6, n


def test_smoke_variants_are_reduced():
    for a in ASSIGNED_NAMES:
        cfg = smoke_variant(get_config(a))
        cfg.validate()
        assert cfg.n_layers <= 2
        assert cfg.d_model <= 512
        assert cfg.moe is None or cfg.moe.n_experts <= 4


def test_long_context_eligibility():
    """DESIGN.md §4 skip rules, encoded."""
    eligible = {a: get_config(a).subquadratic for a in ASSIGNED_NAMES}
    assert eligible["gemma2-27b"]        # alternating local/global
    assert eligible["zamba2-1.2b"]       # hybrid
    assert eligible["xlstm-1.3b"]        # recurrent
    for a in ("chatglm3-6b", "qwen2-1.5b", "qwen1.5-4b", "grok-1-314b",
              "arctic-480b", "qwen2-vl-7b"):
        assert not eligible[a], a


def test_mixture_config_attached():
    cfg = get_config("smalltalk-335m")
    assert cfg.mixture is not None
    assert cfg.mixture.prefix_len == 256
    assert cfg.mixture.router == "router-4m"
