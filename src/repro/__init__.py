"""repro: SmallTalk LM (ICLR 2025) — asynchronous mixture of language models
on a multi-pod JAX/TPU stack."""
__version__ = "1.0.0"
