"""Worker discovery for network serving.

The registry is the only piece of the fleet that knows who exists.
Expert workers ``register`` at boot (getting a replica index assigned if
they did not claim one) and ``heartbeat`` periodically; a worker whose
heartbeats stop is dropped from ``placements`` after ``ttl_s`` — the
registry never *kills* anything, it just stops advertising the silent
worker, so frontends that connect later route around it.  Frontends
``lease`` a monotonically increasing namespace index at construction so
N concurrent frontends never hand out colliding request uids (see
``ServeFrontend.uid_namespace``).

The registry carries **no request traffic** — after discovery,
frontends talk straight to the workers.  That keeps it a pure control
plane: losing it mid-serve only blocks *new* frontends/workers from
joining, never tokens in flight.  State is in-memory on purpose; a
restarted registry repopulates from the next round of heartbeats
(workers re-register when a heartbeat comes back ``unknown``).

Run standalone::

    python -m repro.serving.net.registry --port 7070

or in-process (tests, ``LocalFleet``)::

    with Registry(ttl_s=5.0) as reg:
        ...reg.addr...

Ops (one request/reply pair per connection, framed + handshaked as in
:mod:`repro.serving.net.framing`):

====================  =======================================  ==========================
op                    args                                     reply
====================  =======================================  ==========================
``register``          ``{expert, host, port[, replica]}``      ``{replica, ttl_s}``
``heartbeat``         ``(expert, replica)``                    ``"ok"`` | ``"unknown"``
``placements``        —                                        ``[Placement(expert, replica, host, port)]``
``lease``             —                                        ``int`` (0, 1, 2, ...)
``stop``              —                                        ``"ok"`` (shuts the registry down)
====================  =======================================  ==========================
"""
from __future__ import annotations

import argparse
import socket
import threading
import time

from repro.serving.net import framing
from repro.serving.placement import Placement


class Registry:
    """Threaded TCP discovery endpoint. One short-lived connection per op."""

    def __init__(self, host: str = "127.0.0.1", port: int = 0,
                 ttl_s: float = 10.0):
        if ttl_s <= 0:
            raise ValueError(f"ttl_s must be positive, got {ttl_s}")
        self.ttl_s = float(ttl_s)
        self._lock = threading.Lock()
        # (expert, replica) -> (host, port, last_seen_monotonic)
        self._workers: dict[tuple[int, int], tuple[str, int, float]] = {}
        self._leases = 0
        self._stop = threading.Event()
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((host, port))
        self._sock.listen(64)
        self._sock.settimeout(0.2)       # so the accept loop sees _stop
        self.host, self.port = self._sock.getsockname()[:2]
        self._thread = threading.Thread(target=self._serve, daemon=True,
                                        name="serve-registry")
        self._thread.start()

    @property
    def addr(self) -> str:
        return f"{self.host}:{self.port}"

    # -- server side --------------------------------------------------------
    def _serve(self) -> None:
        while not self._stop.is_set():
            try:
                conn, _ = self._sock.accept()
            except socket.timeout:
                continue
            except OSError:
                break
            threading.Thread(target=self._handle_conn, args=(conn,),
                             daemon=True).start()
        self._sock.close()

    def _handle_conn(self, conn: socket.socket) -> None:
        with conn:
            conn.settimeout(5.0)
            if framing.server_handshake(conn, role="registry") is None:
                return                      # mismatch already shipped back
            try:
                op, args = framing.recv_frame(conn)
                framing.send_frame(conn, self._handle(op, args))
            except framing.PeerGone:
                pass

    def _handle(self, op: str, args):
        now = time.monotonic()
        with self._lock:
            if op == "register":
                e = int(args["expert"])
                r = args.get("replica")
                if r is None:
                    taken = {rr for (ee, rr) in self._workers if ee == e}
                    r = next(i for i in range(len(taken) + 1)
                             if i not in taken)
                self._workers[(e, int(r))] = (args["host"], int(args["port"]),
                                              now)
                return {"replica": int(r), "ttl_s": self.ttl_s}
            if op == "heartbeat":
                key = (int(args[0]), int(args[1]))
                if key not in self._workers:
                    return "unknown"        # worker should re-register
                host, port, _ = self._workers[key]
                self._workers[key] = (host, port, now)
                return "ok"
            if op == "placements":
                # typed Placement records on the wire (slot unbound: the
                # frontend binds transport slots itself); iterating one
                # still yields the legacy (e, r, host, port) tuple shape
                return sorted(
                    (Placement(expert=e, replica=r, host=host, port=port)
                     for (e, r), (host, port, seen) in self._workers.items()
                     if now - seen <= self.ttl_s),
                    key=lambda p: (p.expert, p.replica, p.host, p.port))
            if op == "lease":
                lease, self._leases = self._leases, self._leases + 1
                return lease
            if op == "stop":
                self._stop.set()
                return "ok"
            raise ValueError(f"unknown registry op {op!r}")

    def stop(self) -> None:
        self._stop.set()
        self._thread.join(timeout=2.0)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.stop()


# -- client side -------------------------------------------------------------
def call(registry: str, op: str, args=None, *, timeout: float = 10.0):
    """One-shot registry op over a fresh (handshaked) connection."""
    sock = framing.connect(framing.parse_addr(registry), timeout)
    try:
        framing.client_handshake(sock, role=f"registry-client:{op}")
        framing.send_frame(sock, (op, args))
        return framing.recv_frame(sock)
    finally:
        sock.close()


def wait_for_fleet(registry: str, n_experts: int, *,
                   timeout: float = 30.0, poll_s: float = 0.2) -> list:
    """Poll ``placements`` until every expert in ``range(n_experts)`` has
    at least one live worker; returns the placement list.  Raises
    ``RuntimeError`` naming the experts still missing on timeout."""
    deadline = time.monotonic() + timeout
    placements: list = []
    while True:
        placements = call(registry, "placements", timeout=timeout)
        covered = {e for (e, r, host, port) in placements}
        missing = sorted(set(range(n_experts)) - covered)
        if not missing:
            return placements
        if time.monotonic() >= deadline:
            raise RuntimeError(
                f"registry {registry} has no live worker for expert(s) "
                f"{missing} after {timeout:.1f}s (live placements: "
                f"{placements}) — start them with "
                f"`python -m repro.serving.net.expert_worker --expert E "
                f"--registry {registry} ...`")
        time.sleep(poll_s)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="Discovery registry for network mixture serving.")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="0 picks an ephemeral port (printed on stdout)")
    ap.add_argument("--ttl", type=float, default=10.0,
                    help="seconds without a heartbeat before a worker "
                         "is dropped from placements")
    args = ap.parse_args(argv)
    reg = Registry(host=args.host, port=args.port, ttl_s=args.ttl)
    # single machine-readable line so spawners can scrape the address
    print(f"REGISTRY {reg.addr}", flush=True)
    try:
        while True:
            time.sleep(3600)
    except KeyboardInterrupt:
        pass
    finally:
        reg.stop()


if __name__ == "__main__":
    main()
