"""Raw-TCP :class:`repro.serving.transport.Transport` onto a worker fleet.

One long-lived TCP connection per (expert, replica) slot, carrying
length-prefixed pickled frames (:mod:`repro.serving.net.framing`).  The
``WIRE_VERSION`` handshake runs **once per connection** — after it, no
message is re-validated, and the worker's hello is cross-checked against
the placement the registry advertised, so a frontend can never silently
stream against the wrong expert.

Semantics match :class:`repro.serving.transport.ProcessTransport` with
one twist: network workers tick themselves (see
:mod:`repro.serving.net.expert_worker`), so ``tick(s)`` here is a long
**poll** — "send me whatever expert ``s`` has emitted for me, waiting up
to ``poll_s`` if nothing yet".  ``busy``/``load`` stay sender-side
(enqueues minus ``done`` deltas), so scheduling never round-trips.
``tick_many`` pipelines the polls (send all, then collect): waiting on N
busy workers costs one poll interval, not N.

Failures are **per slot**: a dead worker marks only its own slot broken
(each socket is an independent ordered stream, unlike a shared pipe
pool), and every later op on that slot raises a ``RuntimeError`` naming
the ``(expert, replica)`` placement and its address — the other slots
keep serving, and any poll replies of theirs in flight when the death
surfaced are drained and buffered so no token delta is ever lost.  ``close()`` sends a polite ``close`` op and shuts the
sockets; the workers themselves keep running for other frontends (a
frontend is a client of the fleet, never its owner).
"""
from __future__ import annotations

import socket

from repro.serving.net import framing
from repro.serving.transport import Transport, _RemoteError


class SocketTransport(Transport):
    """TCP client transport onto independently-started expert workers.

    ``addrs`` maps slot index -> ``(host, port)``; ``expect`` (optional,
    same order) carries the registry's ``(expert, replica)`` claim per
    slot, verified against each worker's handshake hello.
    """

    def __init__(self, addrs, labels=None, *, expect=None,
                 connect_timeout: float = 10.0, read_timeout: float = 60.0,
                 poll_s: float = 0.02):
        addrs = [tuple(a) for a in addrs]
        labels = list(labels) if labels is not None else \
            [f"expert {s}" for s in range(len(addrs))]
        self._addrs: list = []
        self.labels: list = []
        self._connect_timeout = float(connect_timeout)
        self._poll_s = float(poll_s)
        self._read_timeout = float(read_timeout)
        self._outstanding: list[int] = []
        # deltas received but not yet handed to the caller: when one slot
        # dies mid tick_many, the other slots' poll replies must still be
        # read (each socket is an ordered request/reply stream — leaving a
        # reply unread would desync every later op) and must not be lost
        # (the worker already handed them over)
        self._pending: dict[int, list] = {}
        self._dead: list[str | None] = []
        self._closed = False
        self._socks: list[socket.socket | None] = []
        try:
            for s, addr in enumerate(addrs):
                self.add_slot(addr, labels[s],
                              expect=None if expect is None else expect[s])
        except Exception:
            for sock in self._socks:
                if sock is not None:
                    try:
                        sock.close()
                    except OSError:
                        pass
            raise

    # -- dynamic slot membership ---------------------------------------------
    def slots(self):
        # dead slots stay listed (ops on them raise, surfacing the death
        # with its placement label); only retired slots leave the table
        return [s for s, sock in enumerate(self._socks) if sock is not None]

    def add_slot(self, target, label, *, expect=None):
        """Connect one more worker mid-serve: ``target`` is its
        ``(host, port)``; ``expect`` (a ``Placement`` or ``(e, r)``
        tuple) cross-checks the worker's handshake identity.  Network
        workers pre-warm at boot, so the slot is admissible at once."""
        if self._closed:
            raise RuntimeError("SocketTransport is closed; build a fresh "
                               "engine to serve again")
        addr = tuple(target)
        try:
            sock = framing.connect(addr, self._connect_timeout)
        except OSError as e:
            raise RuntimeError(
                f"cannot reach {label} worker at "
                f"{addr[0]}:{addr[1]}: {e}") from None
        hello = framing.client_handshake(sock, role="frontend")
        claim = None if expect is None else tuple(expect)[:2]
        ident = (hello.get("expert"), hello.get("replica"))
        if claim is not None and ident != claim:
            sock.close()
            raise RuntimeError(
                f"placement mismatch at {addr[0]}:{addr[1]}: the "
                f"registry advertised expert {claim[0]} replica "
                f"{claim[1]} but the worker identifies as expert "
                f"{ident[0]} replica {ident[1]} — stale registry "
                f"entry or a port collision")
        sock.settimeout(self._read_timeout)
        self._addrs.append(addr)
        self.labels.append(label)
        self._outstanding.append(0)
        self._dead.append(None)
        self._socks.append(sock)
        return len(self._socks) - 1

    def remove_slot(self, s):
        """Retire slot ``s``: polite ``close`` frame, then drop the
        socket — the worker itself keeps running for other frontends
        (a frontend never owns the fleet)."""
        sock = self._socks[s]
        if sock is None:
            return
        self._socks[s] = None
        self._pending.pop(s, None)
        if self._dead[s] is None:
            try:
                framing.send_frame(sock, ("close", None))
            except framing.PeerGone:
                pass
        try:
            sock.close()
        except OSError:
            pass

    def recall(self, s):
        self._send(s, "recall", None)
        uids = self._recv(s)
        # recalled requests leave this slot for good — decrement the
        # sender-side load or the retired slot leaks load forever
        self._outstanding[s] -= len(uids)
        return list(uids)

    # -- failure plumbing ----------------------------------------------------
    def _fail(self, s: int, reason: str) -> RuntimeError:
        self._dead[s] = reason
        try:
            self._socks[s].close()
        except OSError:
            pass
        host, port = self._addrs[s]
        return RuntimeError(
            f"{self.labels[s]} worker at {host}:{port} died mid-stream "
            f"({reason}) — its in-flight requests are lost; the remaining "
            f"slots keep serving")

    def _check(self, s: int) -> None:
        if self._closed:
            raise RuntimeError("SocketTransport is closed; build a fresh "
                               "engine to serve again")
        if self._socks[s] is None:
            raise RuntimeError(f"{self.labels[s]} slot was retired")
        if self._dead[s] is not None:
            host, port = self._addrs[s]
            raise RuntimeError(
                f"{self.labels[s]} worker at {host}:{port} is dead "
                f"({self._dead[s]})")

    def _send(self, s: int, op: str, args) -> None:
        self._check(s)
        try:
            framing.send_frame(self._socks[s], (op, args))
        except framing.PeerGone as e:
            raise self._fail(s, str(e)) from None

    def _recv(self, s: int):
        self._check(s)
        try:
            out = framing.recv_frame(self._socks[s])
        except socket.timeout:
            raise self._fail(
                s, f"no reply within {self._read_timeout:.0f}s") from None
        except (framing.PeerGone, OSError) as e:
            raise self._fail(s, str(e) or type(e).__name__) from None
        if isinstance(out, _RemoteError):
            # the worker is tearing down after shipping its traceback
            self._dead[s] = "worker exception"
            raise RuntimeError(f"{self.labels[s]} worker failed:\n"
                               f"{out.trace}")
        return out

    # -- Transport surface ---------------------------------------------------
    def enqueue(self, s, msg):
        # no per-message check_version: the connection handshake already
        # proved both ends run the same build
        self._outstanding[s] += 1
        self._send(s, "enqueue", msg)

    def _absorb(self, s, deltas):
        self._outstanding[s] -= sum(d.done for d in deltas)
        return deltas

    def tick(self, s):
        stash = self._pending.pop(s, None)
        if stash:
            return self._absorb(s, stash)
        self._send(s, "poll", self._poll_s)
        return self._absorb(s, self._recv(s))

    def tick_many(self, servers):
        servers = list(servers)
        sent, err = [], None
        for s in servers:                 # overlap the workers' poll waits
            if self._pending.get(s):
                continue                  # deliver the stash before polling
            try:
                self._send(s, "poll", self._poll_s)
                sent.append(s)
            except RuntimeError as e:
                if err is None:
                    err = e
        for s in sent:
            try:
                self._pending.setdefault(s, []).extend(self._recv(s))
            except RuntimeError as e:
                if err is None:
                    err = e
        if err is not None:
            raise err    # live slots' deltas stay stashed for later ticks
        return [(s, self._absorb(s, self._pending.pop(s, [])))
                for s in servers]

    def busy(self, s):
        return self._outstanding[s] > 0

    def load(self, s):
        return self._outstanding[s]

    def stats(self, s):
        self._send(s, "stats", None)
        return self._recv(s)

    def reset_stats(self):
        for s in self.slots():
            if self._dead[s] is None:     # partial stats tolerate the dead
                self._send(s, "reset_stats", None)
                self._recv(s)

    def warmup(self, prompt_len, sampled):
        # per-worker jit caches: warm every slot, concurrently (workers
        # pre-warm at boot, so this normally returns compiled-cache hits)
        live = self.slots()
        for s in live:
            self._send(s, "warmup", (prompt_len, sampled))
        for s in live:
            self._recv(s)

    def sync(self):
        # best-effort over the live slots: sync only exists so timing
        # stats exclude queued device work — a slot dying here must not
        # take down the end-of-run report (its death is already surfaced
        # by the tick that lost the request, or by the stats() attempt)
        live = [s for s in self.slots() if self._dead[s] is None]
        for s in live:
            try:
                self._send(s, "sync", None)
            except RuntimeError:
                pass
        for s in live:
            if self._dead[s] is None:
                try:
                    self._recv(s)
                except RuntimeError:
                    pass

    def close(self):
        if self._closed:
            return
        self._closed = True
        for s, sock in enumerate(self._socks):
            if sock is None or self._dead[s] is not None:
                continue
            try:
                framing.send_frame(sock, ("close", None))
            except framing.PeerGone:
                pass
            try:
                sock.close()
            except OSError:
                pass
        self._socks = []

    def __del__(self):
        try:
            self.close()
        except Exception:
            pass
