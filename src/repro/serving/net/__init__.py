"""Network serving: experts as independently-started TCP services.

The multi-host form of the paper's no-talk premise: each expert worker
(:mod:`repro.serving.net.expert_worker`) owns its params + KV pool and
ticks on its own clock; the registry (:mod:`repro.serving.net.registry`)
is a discovery-only control plane; any number of stateless frontends
connect through :class:`SocketTransport` with
``EngineConfig(transport="tcp", registry="host:port")``.  The router
score matrix — i.e. the routed ``RequestMsg`` stream — is the only
traffic that ever crosses hosts.

Importing this package pulls in the frontend-side pieces only —
``expert_worker`` (which builds an ``ExpertServer``) and ``fleet``
(which spawns processes) are deliberately not imported here.  See
``src/repro/serving/README.md`` ("Network serving") for the topology,
handshake protocol, and failure semantics.
"""
from repro.serving.net.framing import MAGIC, PeerGone, parse_addr
from repro.serving.net.registry import Registry, wait_for_fleet
from repro.serving.net.socket_transport import SocketTransport

__all__ = ["MAGIC", "PeerGone", "Registry", "SocketTransport",
           "parse_addr", "wait_for_fleet"]
