"""Wire format shared by every network serving endpoint.

One frame = a 4-byte big-endian length prefix + a pickled python object.
Pickle keeps the payload exactly the objects the in-process transports
already exchange (``RequestMsg`` / ``TokenDeltaMsg`` / ``StatsMsg`` with
their numpy prompts), so the :class:`repro.serving.transport.Transport`
seam needs no parallel serialization layer — but it also means the
protocol is for a **trusted cluster network only**: unpickling attacker
bytes executes code.  Do not expose these ports to the internet.

Every connection opens with a one-time **handshake** instead of
per-message version stamps: the client sends a hello frame carrying the
protocol magic, its :data:`repro.serving.transport.WIRE_VERSION`, and
its role; the server validates and answers with its own hello.  A
mismatched build is rejected loudly *once, at connect time* — after
that, neither side re-validates the ``version`` field riding on each
message dataclass (it stays for wire compat), keeping the per-delta hot
path free of checks.

``PeerGone`` is the one exception callers need to map to placement
labels: it means the other end vanished mid-frame (process died, socket
reset), which the transports surface as "expert E replica R worker
died", never a bare EOF.
"""
from __future__ import annotations

import pickle
import socket
import struct

from repro.serving.transport import WIRE_VERSION

MAGIC = "repro-serve-net"
_LEN = struct.Struct(">I")
MAX_FRAME = 1 << 30              # 1 GiB: a corrupt length prefix fails fast


class PeerGone(ConnectionError):
    """The remote end closed or reset the connection mid-protocol."""


def send_frame(sock: socket.socket, obj) -> None:
    payload = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
    try:
        sock.sendall(_LEN.pack(len(payload)) + payload)
    except (BrokenPipeError, ConnectionResetError, OSError) as e:
        raise PeerGone(str(e)) from None


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    buf = bytearray()
    while len(buf) < n:
        try:
            chunk = sock.recv(n - len(buf))
        except (ConnectionResetError, BrokenPipeError) as e:
            raise PeerGone(str(e)) from None
        if not chunk:
            raise PeerGone("connection closed by peer")
        buf += chunk
    return bytes(buf)


def recv_frame(sock: socket.socket):
    (n,) = _LEN.unpack(_recv_exact(sock, _LEN.size))
    if n > MAX_FRAME:
        raise PeerGone(f"frame length {n} exceeds {MAX_FRAME} — "
                       f"not a {MAGIC} peer")
    return pickle.loads(_recv_exact(sock, n))


# -- the one-time connection handshake --------------------------------------
def hello(role: str, version: int = WIRE_VERSION, **extra) -> dict:
    return {"magic": MAGIC, "wire": version, "role": role, **extra}


def client_handshake(sock: socket.socket, role: str,
                     version: int = WIRE_VERSION) -> dict:
    """Open a connection as ``role``; returns the server's hello.

    Raises ``RuntimeError`` naming both builds on a version mismatch —
    once per connection, so no message on this socket is ever
    re-validated.
    """
    send_frame(sock, hello(role, version))
    reply = recv_frame(sock)
    if not isinstance(reply, dict) or reply.get("magic") != MAGIC:
        raise RuntimeError(f"peer did not speak the {MAGIC} protocol "
                           f"(got {type(reply).__name__})")
    if "error" in reply:
        raise RuntimeError(f"peer rejected the connection: {reply['error']}")
    if reply.get("wire") != version:
        raise RuntimeError(
            f"wire protocol mismatch: peer speaks v{reply.get('wire')!r} "
            f"but this build speaks v{version} — frontend, registry and "
            f"expert workers must run the same serving build")
    return reply


def server_handshake(sock: socket.socket,
                     version: int = WIRE_VERSION, role: str = "server",
                     **extra) -> dict | None:
    """Answer a client hello; returns it, or None if the client was
    rejected (wrong magic or a mismatched build — the rejection reason
    is shipped back before closing, so the client fails loudly too)."""
    try:
        h = recv_frame(sock)
    except PeerGone:
        return None
    if not isinstance(h, dict) or h.get("magic") != MAGIC:
        try:
            send_frame(sock, {"magic": MAGIC,
                              "error": "not a repro-serve-net hello"})
        except PeerGone:
            pass
        return None
    if h.get("wire") != version:
        try:
            send_frame(sock, {
                "magic": MAGIC,
                "error": f"wire protocol mismatch: you speak "
                         f"v{h.get('wire')!r}, this server speaks "
                         f"v{version}"})
        except PeerGone:
            pass
        return None
    send_frame(sock, hello(role, version, **extra))
    return h


def parse_addr(spec: str) -> tuple[str, int]:
    """``"host:port"`` -> ``(host, port)``, validated."""
    host, sep, port = spec.rpartition(":")
    if not sep or not host:
        raise ValueError(f"bad address {spec!r}: expected HOST:PORT")
    try:
        return host, int(port)
    except ValueError:
        raise ValueError(f"bad port in address {spec!r}") from None


def connect(addr: tuple[str, int], timeout: float) -> socket.socket:
    """TCP connect with a timeout; the socket keeps it as read timeout."""
    sock = socket.create_connection(addr, timeout=timeout)
    sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    sock.settimeout(timeout)
    return sock
