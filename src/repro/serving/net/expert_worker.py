"""Standalone network expert worker: one ``ExpertServer`` as a TCP service.

This is the paper's asynchrony claim made literal at serving time: each
expert boots **independently** with its own params and KV pool, ticks on
its **own clock in its own thread**, and never exchanges a byte with any
other expert.  Frontends connect over TCP (see
:mod:`repro.serving.net.socket_transport`) and speak the same three
message types as every other transport; the worker registers with the
discovery registry and heartbeats so frontends can find it.

Unlike the in-process transports — where the frontend's ``tick(s)``
literally steps the server — a network worker **ticks itself**: a server
thread runs ``ExpertServer.tick()`` whenever there is work and buffers
each emitted ``TokenDeltaMsg`` for the connection that enqueued that
request's uid.  The frontend's ``tick`` becomes a long-poll (``poll``
op) draining that buffer.  Token identity is untouched: the
counter-based sampler makes every stream a pure function of
``(seed, uid, step)``, so who ticks, and how the ticks interleave with
polls, cannot change a single token (the identity oracles in
``tests/test_serving_net.py`` hold this to bitwise).

Launch::

    python -m repro.serving.net.expert_worker \\
        --spec fleet_spec.pkl --expert 2 --registry 127.0.0.1:7070

``--spec`` is a pickle holding ``{"ecfg", "eng"}`` plus either
``"params_by_expert"`` (host param trees keyed by expert id) or a
``"seed"`` from which params are derived exactly like
``benchmarks/serve_bench.py`` does (``init_params(fold_in(key, e))``).

Per-connection wire ops (after the one-time handshake):

==============  =========================  =================================
op              args                       reply
==============  =========================  =================================
``enqueue``     ``RequestMsg``             — (fire-and-forget)
``poll``        timeout seconds (float)    ``list[TokenDeltaMsg]``
``stats``       —                          ``StatsMsg``
``reset_stats``  —                         ``None``
``warmup``      ``(prompt_len, sampled)``  ``None``
``sync``        —                          ``None``
``recall``      —                          ``list[int]`` (this frontend's
                                           queued uids, drained — the
                                           scale-down quiesce handback)
``close``       —                          — (connection ends; worker lives)
==============  =========================  =================================

Failure semantics: a Python exception in the serving loop is shipped to
every connected frontend as a ``_RemoteError`` (traceback included) on
its next reply; an abrupt death (kill -9, machine loss) surfaces as a
reset socket, which ``SocketTransport`` reports with the ``(expert,
replica)`` placement label.  A frontend that disconnects mid-stream
just stops receiving its deltas — the worker finishes the in-flight
work and frees the lanes; nothing else is affected.
"""
from __future__ import annotations

import argparse
import pickle
import queue
import socket
import threading
import time
import traceback

import jax
import numpy as np

from repro.serving.expert_server import ExpertServer
from repro.serving.net import framing, registry as registrylib
from repro.serving.transport import _RemoteError

_CALL_TIMEOUT_S = 600.0      # reply-box wait: covers a cold warmup compile
_POLL_CAP_S = 1.0            # stay responsive to shutdown while polling
_IDLE_WAIT_S = 0.01


class _Conn:
    """Per-frontend connection state: a delta buffer the server thread
    fills and the connection thread drains into ``poll`` replies."""

    def __init__(self, sock: socket.socket, peer: str):
        self.sock = sock
        self.peer = peer
        self.alive = True
        self._cv = threading.Condition()
        self._deltas: list = []

    def push(self, deltas) -> None:
        with self._cv:
            self._deltas.extend(deltas)
            self._cv.notify()

    def wake(self) -> None:
        with self._cv:
            self._cv.notify()

    def take(self, timeout: float) -> list:
        with self._cv:
            if not self._deltas:
                self._cv.wait(timeout)
            out, self._deltas = self._deltas, []
            return out


class ExpertWorker:
    """One ``ExpertServer`` served over TCP; self-ticking.

    Usable in-process (tests, notebooks) or via ``main()`` as a
    standalone process.  ``start()`` warms the jit caches, binds the
    port, registers with the registry (which assigns the replica index
    if ``replica`` is None), and spins up the accept / server / heartbeat
    threads.  ``stop()`` slams every socket shut — from a connected
    frontend's point of view it is indistinguishable from a crash, which
    is exactly what the worker-death tests use it for.
    """

    def __init__(self, ecfg, eng, params, expert: int, *,
                 replica: int | None = None, host: str = "127.0.0.1",
                 port: int = 0, registry: str = "",
                 advertise_host: str = "", warmup_len: int | None = None,
                 warmup: bool = True):
        self.ecfg, self.eng = ecfg, eng
        self.expert = int(expert)
        self.replica = replica
        self.registry = registry
        self._warmup = warmup
        self._warmup_len = warmup_len
        self._ttl = 10.0
        self._failure: str | None = None
        self._stop = threading.Event()
        self._work = threading.Event()
        self._inbox: queue.Queue = queue.Queue()
        self._owner: dict[int, _Conn] = {}       # uid -> enqueuing conn
        self._conns: set[_Conn] = set()
        self._lock = threading.Lock()
        self._threads: list[threading.Thread] = []
        self._server = ExpertServer(ecfg, jax.device_put(params), eng)
        self._listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._listener.bind((host, port))
        self._listener.listen(64)
        self._listener.settimeout(0.2)
        self.host, self.port = self._listener.getsockname()[:2]
        self.advertise_host = advertise_host or self.host

    @property
    def addr(self) -> str:
        return f"{self.advertise_host}:{self.port}"

    # -- lifecycle ----------------------------------------------------------
    def start(self) -> "ExpertWorker":
        if self._warmup:
            # warm both decode programs *before* advertising ourselves, so
            # no frontend ever pays a cold compile against its read timeout
            self._server.warmup(self._warmup_len, sampled=False)
            self._server.warmup(self._warmup_len, sampled=True)
        if self.registry:
            self._register()
        elif self.replica is None:
            self.replica = 0
        for target, name in ((self._accept_loop, "accept"),
                             (self._server_loop, "server"),
                             (self._heartbeat_loop, "heartbeat")):
            t = threading.Thread(target=target, daemon=True,
                                 name=f"expert{self.expert}-{name}")
            t.start()
            self._threads.append(t)
        return self

    def stop(self) -> None:
        """Shut down abruptly: close the listener and every live
        connection without protocol (frontends see a dead peer)."""
        self._stop.set()
        self._work.set()
        try:
            self._listener.close()
        except OSError:
            pass
        with self._lock:
            conns = list(self._conns)
        for c in conns:
            c.alive = False
            try:
                c.sock.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                c.sock.close()
            except OSError:
                pass
            c.wake()
        for t in self._threads:
            t.join(timeout=5.0)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.stop()

    def _register(self) -> None:
        reply = registrylib.call(self.registry, "register", {
            "expert": self.expert, "replica": self.replica,
            "host": self.advertise_host, "port": self.port})
        self.replica = reply["replica"]
        self._ttl = float(reply["ttl_s"])

    def _heartbeat_loop(self) -> None:
        if not self.registry:
            return
        interval = max(self._ttl / 3.0, 0.05)
        while not self._stop.wait(interval):
            try:
                out = registrylib.call(self.registry, "heartbeat",
                                       (self.expert, self.replica),
                                       timeout=5.0)
                if out == "unknown":      # registry restarted: re-enlist
                    self._register()
            except Exception:
                # registry being down never stops token generation — the
                # registry is discovery only; retry next interval
                pass

    # -- the serving thread: owns the ExpertServer --------------------------
    def _server_loop(self) -> None:
        try:
            while not self._stop.is_set():
                moved = self._drain_inbox()
                if self._server.busy:
                    deltas = self._server.tick()
                    if deltas:
                        self._dispatch(deltas)
                elif not moved:
                    self._work.wait(_IDLE_WAIT_S)
                    self._work.clear()
        except Exception:
            self._failure = traceback.format_exc()
            self._drain_inbox()               # fail the waiting reply boxes
            with self._lock:
                conns = list(self._conns)
            for c in conns:                   # wake pollers into the error
                c.wake()

    def _drain_inbox(self) -> bool:
        moved = False
        while True:
            try:
                op, args, box, conn = self._inbox.get_nowait()
            except queue.Empty:
                return moved
            moved = True
            if self._failure is not None:
                if box is not None:
                    box.put(_RemoteError(self._failure))
                continue
            if op == "enqueue":
                self._server.enqueue(args)
                self._owner[args.uid] = conn
            elif op == "stats":
                box.put(self._server.stats())
            elif op == "reset_stats":
                self._server.reset_stats()
                box.put(None)
            elif op == "warmup":
                self._server.warmup(args[0], sampled=args[1])
                box.put(None)
            elif op == "sync":
                self._server.sync()
                box.put(None)
            elif op == "recall":
                # quiesce for ONE frontend: only its queued uids come
                # back — another frontend's requests on this shared
                # worker are untouched
                mine = {u for u, c in self._owner.items() if c is conn}
                uids = self._server.recall_pending(mine)
                for u in uids:
                    self._owner.pop(u, None)
                box.put(uids)
            else:
                box.put(_RemoteError(f"unknown worker op {op!r}"))

    def _dispatch(self, deltas) -> None:
        for d in deltas:
            conn = self._owner.get(d.uid)
            if d.done:
                self._owner.pop(d.uid, None)
            if conn is not None and conn.alive:
                conn.push([d])
            # a vanished frontend's deltas are dropped on the floor — the
            # server still finishes the request and frees its lane

    def _call(self, op, args, conn):
        """Connection thread -> server thread round trip."""
        box: queue.Queue = queue.Queue(1)
        self._inbox.put((op, args, box, conn))
        self._work.set()
        try:
            return box.get(timeout=_CALL_TIMEOUT_S)
        except queue.Empty:
            return _RemoteError(f"worker op {op!r} timed out after "
                                f"{_CALL_TIMEOUT_S:.0f}s")

    # -- per-connection threads ---------------------------------------------
    def _accept_loop(self) -> None:
        while not self._stop.is_set():
            try:
                sock, peer = self._listener.accept()
            except socket.timeout:
                continue
            except OSError:
                return
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            threading.Thread(target=self._conn_loop,
                             args=(sock, f"{peer[0]}:{peer[1]}"),
                             daemon=True).start()

    def _conn_loop(self, sock: socket.socket, peer: str) -> None:
        if framing.server_handshake(sock, role="expert-worker",
                                    expert=self.expert,
                                    replica=self.replica) is None:
            sock.close()
            return
        conn = _Conn(sock, peer)
        with self._lock:
            self._conns.add(conn)
        try:
            while not self._stop.is_set():
                try:
                    op, args = framing.recv_frame(sock)
                except (framing.PeerGone, OSError):
                    return
                if op == "close":
                    return
                if op == "enqueue":
                    if self._failure is None:   # else the next poll reports
                        self._inbox.put((op, args, None, conn))
                        self._work.set()
                elif op == "poll":
                    if self._failure is not None:
                        framing.send_frame(sock, _RemoteError(self._failure))
                        continue
                    deltas = conn.take(min(max(float(args), 0.0),
                                           _POLL_CAP_S))
                    if self._failure is not None and not deltas:
                        framing.send_frame(sock, _RemoteError(self._failure))
                    else:
                        framing.send_frame(sock, deltas)
                elif op in ("stats", "reset_stats", "warmup", "sync",
                            "recall"):
                    framing.send_frame(sock, self._call(op, args, conn))
                else:
                    framing.send_frame(
                        sock, _RemoteError(f"unknown wire op {op!r}"))
        except framing.PeerGone:
            pass
        finally:
            conn.alive = False
            with self._lock:
                self._conns.discard(conn)
            try:
                sock.close()
            except OSError:
                pass


def params_from_spec(spec: dict, expert: int):
    """Resolve one expert's host params from a fleet spec pickle."""
    if "params_by_expert" in spec:
        return spec["params_by_expert"][expert]
    if "seed" in spec:
        from repro.models import model as modellib
        key = jax.random.fold_in(jax.random.PRNGKey(int(spec["seed"])),
                                 expert)
        return modellib.init_params(key, spec["ecfg"])
    raise ValueError("fleet spec must carry 'params_by_expert' or 'seed'")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="Serve one mixture expert over TCP.")
    ap.add_argument("--spec", required=True,
                    help="pickle with {'ecfg','eng'} plus "
                         "'params_by_expert' or 'seed'")
    ap.add_argument("--expert", type=int, required=True)
    ap.add_argument("--replica", type=int, default=None,
                    help="default: assigned by the registry")
    ap.add_argument("--registry", default="",
                    help="HOST:PORT of the discovery registry")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0)
    ap.add_argument("--advertise-host", default="",
                    help="address to register (default: bound host)")
    ap.add_argument("--warmup-len", type=int, default=None)
    ap.add_argument("--no-warmup", action="store_true")
    args = ap.parse_args(argv)

    with open(args.spec, "rb") as f:
        spec = pickle.load(f)
    params = jax.tree_util.tree_map(np.asarray,
                                    params_from_spec(spec, args.expert))
    worker = ExpertWorker(
        spec["ecfg"], spec["eng"], params, args.expert,
        replica=args.replica, host=args.host, port=args.port,
        registry=args.registry, advertise_host=args.advertise_host,
        warmup_len=args.warmup_len, warmup=not args.no_warmup)
    worker.start()
    print(f"WORKER expert={worker.expert} replica={worker.replica} "
          f"{worker.addr}", flush=True)
    try:
        while True:
            time.sleep(3600)
    except KeyboardInterrupt:
        pass
    finally:
        worker.stop()


if __name__ == "__main__":
    main()
