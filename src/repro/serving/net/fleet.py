"""Spawn a local worker fleet: registry + expert workers as OS processes.

This is the convenience layer for a **single machine**: it shells out to
the exact same module CLIs an operator would run by hand on a real
cluster (``python -m repro.serving.net.registry`` and ``python -m
repro.serving.net.expert_worker``), so a ``LocalFleet`` run in CI proves
the standalone entry points, not a shortcut around them.  On real
multi-host deployments you run those CLIs yourself — one registry, one
worker per (expert, replica) wherever its params live — and point any
number of frontends at the registry with
``EngineConfig(transport="tcp", registry="host:port")``.

Params travel to the workers through a **spec pickle** on local disk
(``{"ecfg", "eng"}`` plus ``"params_by_expert"`` or ``"seed"``), never
through the frontend: the whole point of the paper's no-talk serving
story is that a frontend only ever ships router-scored requests, so it
must not need the expert weights at all.  Pass ``params_by_expert`` as
host (numpy) trees, or ``seed`` to have each worker derive its own
params exactly like ``benchmarks/serve_bench.py``'s ``build``.

``replicas`` maps expert id -> worker count (default 1 each); every
worker is its own process with its own KV pool.  Worker stdout/stderr
land in per-worker log files inside the spec's temp directory, and a
worker that dies before registering fails ``start`` loudly with the
tail of its log.
"""
from __future__ import annotations

import os
import pickle
import subprocess
import sys
import tempfile
import threading
import time

from repro.serving.net import framing, registry as registrylib

_LOG_TAIL = 4000


def _reap(proc) -> None:
    """Wait out a terminated worker, escalating to SIGKILL: keeps
    ``stop_replica`` non-blocking without leaking zombies."""
    try:
        proc.wait(timeout=10)
    except subprocess.TimeoutExpired:
        proc.kill()
        try:
            proc.wait(timeout=5)
        except subprocess.TimeoutExpired:
            pass


class LocalFleet:
    """Registry + expert-worker subprocesses on localhost; a context
    manager that terminates the whole fleet on exit."""

    def __init__(self, ecfg, eng, n_experts: int, *, seed: int | None = None,
                 params_by_expert=None, replicas: dict | None = None,
                 ttl_s: float = 10.0, warmup_len: int | None = None,
                 warmup: bool = True, start_timeout_s: float = 600.0):
        if (seed is None) == (params_by_expert is None):
            raise ValueError("pass exactly one of seed / params_by_expert")
        self.n_experts = int(n_experts)
        self._tmp = tempfile.TemporaryDirectory(prefix="repro-fleet-")
        self._procs: list[subprocess.Popen] = []
        self._logs: list[str] = []
        self.registry_addr = ""
        env = dict(os.environ)
        src = os.path.dirname(os.path.dirname(os.path.dirname(
            os.path.dirname(os.path.abspath(__file__)))))
        extra = env.get("PYTHONPATH", "")
        env["PYTHONPATH"] = src + (os.pathsep + extra if extra else "")
        self._env = env
        self._warmup_len = warmup_len
        self._warmup = warmup
        try:
            self._start_registry(env, ttl_s)
            spec = {"ecfg": ecfg, "eng": eng}
            if params_by_expert is not None:
                spec["params_by_expert"] = dict(params_by_expert)
            else:
                spec["seed"] = int(seed)
            self._spec_path = os.path.join(self._tmp.name, "fleet_spec.pkl")
            with open(self._spec_path, "wb") as f:
                pickle.dump(spec, f)
            replicas = dict(replicas or {})
            for e in range(self.n_experts):
                for _ in range(max(int(replicas.get(e, 1)), 1)):
                    self._start_worker(env, self._spec_path, e,
                                       warmup_len, warmup)
            self._wait_ready(start_timeout_s)
        except Exception:
            self.close()
            raise

    def _start_registry(self, env, ttl_s: float) -> None:
        log = os.path.join(self._tmp.name, "registry.log")
        proc = subprocess.Popen(
            [sys.executable, "-m", "repro.serving.net.registry",
             "--port", "0", "--ttl", str(ttl_s)],
            env=env, stdout=subprocess.PIPE, stderr=open(log, "wb"),
            text=True)
        self._procs.append(proc)
        self._logs.append(log)
        line = proc.stdout.readline().strip()   # "REGISTRY host:port"
        if not line.startswith("REGISTRY "):
            raise RuntimeError(
                f"registry failed to start (said {line!r}); see "
                f"{self._tail(log)}")
        self.registry_addr = line.split(None, 1)[1]
        framing.parse_addr(self.registry_addr)  # validate the scrape

    def _start_worker(self, env, spec_path: str, expert: int,
                      warmup_len: int | None, warmup: bool) -> None:
        log = os.path.join(self._tmp.name,
                           f"worker-e{expert}-{len(self._procs)}.log")
        cmd = [sys.executable, "-m", "repro.serving.net.expert_worker",
               "--spec", spec_path, "--expert", str(expert),
               "--registry", self.registry_addr]
        if warmup_len is not None:
            cmd += ["--warmup-len", str(warmup_len)]
        if not warmup:
            cmd += ["--no-warmup"]
        out = open(log, "wb")
        proc = subprocess.Popen(cmd, env=env, stdout=out,
                                stderr=subprocess.STDOUT)
        self._procs.append(proc)
        self._logs.append(log)

    def _wait_ready(self, timeout_s: float) -> None:
        deadline = time.monotonic() + timeout_s
        while True:
            for proc, log in zip(self._procs, self._logs):
                if proc.poll() is not None:
                    raise RuntimeError(
                        f"fleet process exited with code {proc.returncode} "
                        f"before the fleet came up; its log: "
                        f"{self._tail(log)}")
            try:
                registrylib.wait_for_fleet(
                    self.registry_addr, self.n_experts,
                    timeout=min(2.0, max(deadline - time.monotonic(), 0.1)))
                return
            except RuntimeError:
                if time.monotonic() >= deadline:
                    raise

    # -- the ServeFrontend scale_executor protocol ---------------------------
    def start_replica(self, expert: int) -> None:
        """Boot one more worker for ``expert`` (the autoscaler's
        scale-up request).  Returns immediately — the worker warms, then
        registers; the frontend adopts it off the registry's next
        ``placements`` answer."""
        self._start_worker(self._env, self._spec_path, int(expert),
                           self._warmup_len, self._warmup)

    def stop_replica(self, placement) -> bool:
        """Terminate the worker process serving ``placement`` (the
        autoscaler's scale-down, after the frontend drained it).
        Workers are matched by the ``WORKER expert=E replica=R addr``
        line they print at boot; returns False when no live process
        matches (already gone — e.g. retired by another frontend)."""
        want = (f"WORKER expert={placement.expert} "
                f"replica={placement.replica} "
                f"{placement.host}:{placement.port}")
        for proc, log in zip(self._procs, self._logs):
            if proc.poll() is not None:
                continue
            try:
                with open(log, "rb") as f:
                    head = f.read(_LOG_TAIL).decode(errors="replace")
            except OSError:
                continue
            if want in head:
                proc.terminate()
                # reap off-path: this runs inside the frontend's step
                # loop (scale-down finalize), which must not stall on a
                # worker's exit
                threading.Thread(target=_reap, args=(proc,),
                                 daemon=True).start()
                return True
        return False

    def _tail(self, log: str) -> str:
        try:
            with open(log, "rb") as f:
                data = f.read()[-_LOG_TAIL:]
            return f"{log}:\n{data.decode(errors='replace')}"
        except OSError:
            return f"{log} (unreadable)"

    def close(self) -> None:
        for proc in self._procs:
            if proc.poll() is None:
                proc.terminate()
        for proc in self._procs:
            try:
                proc.wait(timeout=10)
            except subprocess.TimeoutExpired:
                proc.kill()
                proc.wait(timeout=5)
            if proc.stdout is not None:
                proc.stdout.close()
        self._procs = []
        self._tmp.cleanup()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
