"""Router frontend: the only place where experts are visible together.

The paper's inference story (§2.2) is that a tiny router ensemble scores
the request prefix and exactly ONE expert serves the request — so the
mixture costs 1/E of its parameters at inference, and the router scores
are the only cross-expert traffic (§1, App. A.4).  This frontend is that
thin layer: batched prefix scoring, expert argmax, uid assignment, and
reassembly of the per-token :class:`repro.serving.transport.TokenDeltaMsg`
records coming back from the expert servers into the live
:class:`repro.serving.scheduler.Request` objects callers hold.

Experts are driven **without a barrier**: every
:class:`repro.serving.expert_server.ExpertServer` keeps its own tick
clock and the frontend only ticks servers that have work
(``transport.tick_many``), so a hot expert never waits on idle ones —
the paper's asynchrony applied to serving.  Token streams cannot depend
on that freedom: sampling is counter-based per ``(seed, uid, step)`` and
each request lives entirely inside one expert, so any per-expert tick
interleaving yields bit-identical tokens (the fuzz oracles in
``tests/test_serving.py`` hold on every transport).

The transport boundary is pluggable (:mod:`repro.serving.transport`):
``EngineConfig.transport`` selects the in-process loopback default, one
spawned process per server, or raw TCP to a registry-discovered worker
fleet — the frontend code is identical either way, because only
serializable messages ever cross it.

**Replication** (the ``replicas`` constructor map) is the paper's
no-talk premise cashed in at serving time: because experts share
nothing, a hot expert can be cloned R times with zero coordination —
the frontend runs R server slots holding the same params and admits
each routed request to the **least-loaded** replica (queue depth +
occupied lanes, tracked from the message flow; ties break to the lowest
slot).  The live admission map is a
:class:`repro.serving.placement.PlacementMap`; replicas never learn of
each other, and tokens cannot depend on the placement (the fuzz oracles
in ``tests/test_serving_replicas.py``).

**Autoscaling** (the ``scale`` constructor policy) makes the replica
map *live*: a deterministic control loop
(:class:`repro.serving.autoscale.Autoscaler`) watches the same
sender-side load tracker least-loaded admission uses and, between
ticks, spawns or retires replicas without dropping in-flight requests.
Scale-up warms the new slot off-path and admits it only when
``slot_ready``; scale-down quiesces — the replica leaves the admission
map, its queued-but-unadmitted requests are recalled and re-routed
(they have emitted zero tokens, so re-routing is invisible to token
identity), its lanes drain to completion, and only then is the slot
released, its counters folded into the run report.  On tcp the
registry does half the work: scale-up asks the ``scale_executor`` to
boot a worker and adopts it off the next ``placements`` answer;
scale-down drops the slot and (optionally) asks the executor to stop
the process.  Because placement never touches the sampler key, tokens
stay bitwise identical to the serial oracle even while the placement
varies mid-run (``tests/test_serving_autoscale.py``).
"""
from __future__ import annotations

import dataclasses
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import assignment as asg
from repro.core import router as routerlib
from repro.models import model as modellib
from repro.serving import cache as cachelib
from repro.serving.autoscale import (Autoscaler, ScaleEvent, ScalePolicy,
                                     SlotLoad)
from repro.serving.expert_server import (EngineConfig, ExpertServer,
                                         resolve_shapes)
from repro.serving.net import registry as netreg
from repro.serving.net.socket_transport import SocketTransport
from repro.serving.placement import Placement, PlacementMap
from repro.serving.report import (AutoscaleStats, PrefixSharingStats,
                                  RunReport)
from repro.serving.sampling import SamplingParams
from repro.serving.scheduler import Request, RequestQueue
from repro.serving.transport import (LoopbackTransport, ProcessTransport,
                                     RequestMsg, TokenDeltaMsg)

# Frontend n gets uids [n * STRIDE, (n+1) * STRIDE): N stateless frontends
# serving one worker fleet can never collide on a uid, so their streams
# can never cross (the workers key delta routing AND the counter-based
# sampler on the uid).  The stride must keep every uid inside the uint32
# domain of `jax.random.fold_in` (see repro.serving.sampling.request_key),
# which caps the namespace index at 255 — far beyond any sane frontend
# count, checked at construction.
UID_NAMESPACE_STRIDE = 1 << 24
MAX_UID_NAMESPACE = (1 << 32) // UID_NAMESPACE_STRIDE - 1


@dataclasses.dataclass(frozen=True)
class TokenDelta:
    """One streamed token: request, its value/position, and completion."""
    request: Request
    token: int
    index: int                    # position within request.tokens
    done: bool                    # True on the request's final token
    tick: int


@functools.lru_cache(maxsize=None)
def _router_fns(rcfg):
    """One jitted router-scoring program per (frozen) router config."""
    return jax.jit(
        lambda rp, toks: routerlib.ensemble_scores(rp, rcfg, toks))


class ServeFrontend:
    """Queue + router + per-expert servers behind a transport.

    This is the full continuous-batching engine the old monolith was:
    ``submit`` -> router scores the prefix, argmax picks ONE expert ->
    the request crosses the transport as a :class:`RequestMsg` -> that
    expert admits it into its fixed-lane decode batch over the paged
    block-pool KV cache -> per-token deltas stream back and are
    reassembled here.  See :class:`repro.serving.expert_server`
    for everything per-expert and :mod:`repro.serving.transport` for the
    boundary.

    ``replicas`` maps expert id -> R >= 1 (unlisted experts get 1): the
    frontend runs R server slots per hot expert — same params, disjoint
    KV pools — and admits each request to the least-loaded replica of
    its argmax expert.  Router scores stay the only cross-replica
    traffic, and tokens are placement-invariant (see module docstring).

    ``scale`` installs a :class:`repro.serving.autoscale.ScalePolicy`:
    the frontend then grows/shrinks the replica map live between ticks
    (see the module docstring's Autoscaling paragraph).
    ``scale_executor`` (tcp only) is anything with
    ``start_replica(expert)`` / ``stop_replica(placement)`` — e.g. a
    :class:`repro.serving.net.fleet.LocalFleet`; without one, a tcp
    frontend still adopts workers others start and still retires idle
    replicas from its own admission.
    """

    def __init__(self, ecfg, rcfg, expert_params: list, router_params,
                 eng: EngineConfig = EngineConfig(), replicas=None,
                 uid_namespace: int | None = None,
                 scale: ScalePolicy | None = None, scale_executor=None):
        shapes = resolve_shapes(ecfg, eng)    # validate before any spawn
        self.ecfg, self.rcfg, self.eng = ecfg, rcfg, eng
        self.expert_params = list(expert_params)
        self.router_params = router_params
        self.n_experts = len(self.expert_params)
        if eng.transport == "tcp":
            if replicas:
                raise ValueError(
                    "replicas= is derived from the worker fleet on "
                    "transport='tcp' — start more expert_worker processes "
                    "for a hot expert instead of passing a replica map")
            # the fleet is the source of truth: whatever workers
            # registered (and still heartbeat) are the slots
            fleet = netreg.wait_for_fleet(eng.registry, self.n_experts,
                                          timeout=eng.net_timeout_s)
            placed = [Placement(int(e), int(r), slot=s,
                                host=host, port=int(port))
                      for s, (e, r, host, port) in enumerate(fleet)]
        else:
            counts = [1] * self.n_experts
            for e, r in dict(replicas or {}).items():
                e, r = int(e), int(r)
                if not 0 <= e < self.n_experts:
                    raise ValueError(f"replicas names expert {e}, but the "
                                     f"mixture has {self.n_experts}")
                if r < 1:
                    raise ValueError(f"expert {e} needs >= 1 replica, "
                                     f"got {r}")
                counts[e] = r
            # flat server slots: expert e occupies R_e consecutive slots,
            # and the transport addresses slots — it never hears about
            # experts
            placed, slot = [], 0
            for e in range(self.n_experts):
                for r in range(counts[e]):
                    placed.append(Placement(e, r, slot=slot))
                    slot += 1
        self.placements = PlacementMap(placed)
        self.pad_safe = shapes.pad_safe
        self.has_pool = shapes.has_pool
        self.lane_blocks = shapes.lane_blocks
        self.pool_blocks = shapes.pool_blocks
        self.decode_impl = shapes.decode_impl
        self.prefill_impl = shapes.prefill_impl
        labels = [p.label for p in placed]
        if eng.transport == "tcp":
            self._transport = SocketTransport(
                [p.addr for p in placed], labels,
                expect=placed,
                connect_timeout=eng.net_timeout_s,
                read_timeout=eng.net_timeout_s,
                poll_s=eng.net_poll_ms / 1000.0)
        elif eng.transport == "process":
            slot_params = [self.expert_params[p.expert] for p in placed]
            self._transport = ProcessTransport(ecfg, eng, slot_params,
                                               labels)
        else:
            self._transport = LoopbackTransport(
                [ExpertServer(ecfg, self.expert_params[p.expert], eng)
                 for p in placed], labels)
        if uid_namespace is None:
            # each tcp frontend leases a namespace so N frontends on one
            # fleet never collide; the local transports own their fleet
            # outright and keep the plain 0.. uid space (== the serial
            # oracle's)
            uid_namespace = netreg.call(eng.registry, "lease",
                                        timeout=eng.net_timeout_s) \
                if eng.transport == "tcp" else 0
        self.uid_namespace = int(uid_namespace)
        if not 0 <= self.uid_namespace <= MAX_UID_NAMESPACE:
            raise ValueError(f"uid_namespace must be in "
                             f"[0, {MAX_UID_NAMESPACE}], got "
                             f"{self.uid_namespace}")
        # -- autoscale control plane --
        self.scale = scale.validate() if scale is not None else None
        self._scaler = Autoscaler(self.scale, self.n_experts,
                                  eng.lanes_per_expert) \
            if self.scale is not None else None
        self._scale_executor = scale_executor
        self._warming: dict[int, Placement] = {}      # slot -> spawned, cold
        self._draining: dict[int, tuple] = {}         # slot -> (Placement,
                                                      #          reason)
        self._retired_stats: list = []                # (Placement, StatsMsg?)
        self.scale_events: list[ScaleEvent] = []
        self._warmup_args: tuple | None = None
        self._retired_keys: set = set()               # tcp: never re-adopt
        self._tcp_spawning: dict[int, int] = {}       # expert -> boots asked
        self._peak = [self.placements.n_replicas(e)
                      for e in range(self.n_experts)]
        self.queue = RequestQueue()
        self.tick = 0
        self._uid = self.uid_namespace * UID_NAMESPACE_STRIDE
        self._t0: float | None = None
        self.last_deltas: list[TokenDelta] = []
        self._live: dict[int, Request] = {}   # uid -> un-finished Request
        self._score_fn = _router_fns(rcfg)

    # -- lifecycle ---------------------------------------------------------
    @property
    def replicas(self) -> list[int]:
        """Live (admissible) replica count per expert — with a
        ScalePolicy installed this varies over the run."""
        return [self.placements.n_replicas(e) for e in range(self.n_experts)]

    @property
    def n_servers(self) -> int:
        """Live admissible server slots (draining/warming excluded)."""
        return len(self.placements)

    @property
    def _experts(self):
        """Loopback-only: the in-process ExpertServer states (tests, debug
        introspection).  The process transport has no local servers — use
        :meth:`run`'s per-expert stats instead."""
        return self._transport.servers

    def close(self) -> None:
        """Release the transport (worker processes, pipes); idempotent."""
        self._transport.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # -- warmup ------------------------------------------------------------
    def warmup(self, prompt_len: int | None = None, *,
               sampled: bool = True) -> None:
        """Compile every serving shape up front, off the timed path.

        Warms the router-scoring program plus every expert server's
        admission/decode shapes (loopback warms one server — the jitted
        programs are shared in process; the process transport warms all
        workers concurrently, since each owns its own compile cache).
        ``prompt_len`` selects which prefill bucket to warm (defaults to
        the routing prefix length); call again for other buckets.
        ``sampled=False`` skips the sampled pass — a greedy-only
        deployment then never compiles the sampler programs.  The
        autoscaler warms scaled-up replicas with the same arguments.
        """
        # router scoring always runs on (route_batch, prefix_len) chunks
        self._score_fn(self.router_params,
                       jnp.zeros((self.eng.route_batch, self.eng.prefix_len),
                                 jnp.int32))
        # synthetic warmup tokens never reach the frontend: each server
        # drops its own warmup deltas and restores its clock/stats
        self._warmup_args = (prompt_len, sampled)
        self._transport.warmup(prompt_len, sampled)

    # -- request intake ----------------------------------------------------
    def submit(self, prompt, max_new_tokens: int,
               sampling: SamplingParams | None = None,
               stop_tokens=(),
               arrival_tick: int | None = None) -> Request:
        """Queue one generation request; returns its live Request record.

        ``sampling`` defaults to greedy; ``stop_tokens`` is any iterable
        of token ids that end the sequence early (the stop token is kept
        as the final emitted token, and the request's KV blocks are freed
        the same tick).
        """
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size == 0:
            raise ValueError("empty prompt")
        if len(prompt) < self.eng.prefix_len:
            raise ValueError(f"prompt shorter than routing prefix "
                             f"({len(prompt)} < {self.eng.prefix_len})")
        if len(prompt) + max_new_tokens > self.eng.max_len:
            raise ValueError(f"prompt {len(prompt)} + {max_new_tokens} new "
                             f"tokens exceeds lane budget {self.eng.max_len}")
        sampling = SamplingParams() if sampling is None else sampling
        if not isinstance(sampling, SamplingParams):
            raise TypeError(f"sampling must be a SamplingParams, "
                            f"got {type(sampling).__name__}")
        stop_tokens = frozenset(int(t) for t in stop_tokens)
        bad = [t for t in stop_tokens if not 0 <= t < self.ecfg.vocab_size]
        if bad:
            raise ValueError(f"stop tokens outside vocab: {sorted(bad)}")
        req = Request(uid=self._uid, prompt=prompt,
                      max_new_tokens=max_new_tokens,
                      sampling=sampling, stop_tokens=stop_tokens,
                      arrival_tick=self.tick if arrival_tick is None
                      else arrival_tick)
        self._uid += 1
        self._live[req.uid] = req
        self.queue.push(req)
        return req

    # -- routing -----------------------------------------------------------
    def _pick_replica(self, e: int) -> int:
        """Least-loaded admission: the slot of expert ``e`` with the
        fewest in-flight requests (queued + in a lane, tracked from the
        message flow — no stats round-trip).  Ties break to the lowest
        slot, i.e. the lowest replica index, so placement is
        deterministic."""
        slots = self.placements.slots_of(e)
        if not slots:
            raise RuntimeError(f"no live replica of expert {e} to admit to")
        return min(slots, key=lambda s: (self._transport.load(s), s))

    def _route(self, reqs: list[Request]) -> None:
        """Score prefixes in padded fixed-width batches, argmax an expert,
        and hand each request across the transport — to the least-loaded
        replica when the expert has several."""
        pl, rb = self.eng.prefix_len, self.eng.route_batch
        prefixes = np.stack([r.prompt[:pl] for r in reqs])
        for i in range(0, len(reqs), rb):
            chunk = prefixes[i:i + rb]
            n = len(chunk)
            if n < rb:        # pad with copies of row 0; scores are per-row
                chunk = np.concatenate([chunk, np.broadcast_to(
                    chunk[:1], (rb - n,) + chunk.shape[1:])])
            scores = np.asarray(self._score_fn(self.router_params,
                                               jnp.asarray(chunk)))
            eids = np.asarray(asg.argmax_assignment(scores[:n]))
            for r, e in zip(reqs[i:i + n], eids):
                r.expert = int(e)
                r.route_tick = self.tick
                slot = self._pick_replica(r.expert)
                r.replica = self.placements[slot].replica
                self._transport.enqueue(slot, RequestMsg(
                    uid=r.uid, prompt=r.prompt,
                    max_new_tokens=r.max_new_tokens, sampling=r.sampling,
                    stop_tokens=r.stop_tokens, enqueue_tick=self.tick))

    # -- autoscaling -------------------------------------------------------
    def _adopt(self, p: Placement, reason: str) -> None:
        """A new replica enters admission: the scale-up takes effect."""
        self.placements.add(p)
        self._peak[p.expert] = max(self._peak[p.expert],
                                   self.placements.n_replicas(p.expert))
        if self._scaler is not None:
            # cooldown restarts when the capacity lands, not when the
            # spawn was decided — a slow warmup must not leave the new
            # member instantly ripe for an idle retire
            self._scaler.note_adopted(p.expert, p.slot, self.tick)
        self.scale_events.append(ScaleEvent(
            tick=self.tick, action="up", expert=p.expert,
            replica=p.replica, reason=reason))

    def _poll_warming(self) -> None:
        for s in sorted(self._warming):
            if self._transport.slot_ready(s):
                self._adopt(self._warming.pop(s), reason="pressure")

    def _scale_up(self, e: int) -> None:
        if self.eng.transport == "tcp":
            # the registry owns replica identity on tcp: ask the executor
            # to boot a worker, adopt it off the next placements answer
            if self._scale_executor is not None:
                self._scale_executor.start_replica(e)
                self._tcp_spawning[e] = self._tcp_spawning.get(e, 0) + 1
            return
        taken = [p.replica for p in self._warming.values()
                 if p.expert == e]
        taken += [p.replica for p, _ in self._draining.values()
                  if p.expert == e]
        p = Placement(e, self.placements.next_replica(e, taken))
        if self.eng.transport == "process":
            slot = self._transport.add_slot(self.expert_params[e], p.label)
            # warm off-path: the worker imports jax and compiles while
            # serving continues; _poll_warming admits it once ready
            args = self._warmup_args or (None, True)
            self._transport.warmup_slot(slot, *args)
            self._warming[slot] = p.bind(slot)
        else:
            # loopback shares the config-keyed jit cache: a new server is
            # warm by construction, admissible immediately
            slot = self._transport.add_slot(
                ExpertServer(self.ecfg, self.expert_params[e], self.eng),
                p.label)
            self._adopt(p.bind(slot), reason="pressure")

    def _begin_retire(self, slot: int, reason: str) -> None:
        """Quiesce one replica: leave admission, recall its queued
        requests (re-routed to survivors — they have emitted zero
        tokens, so their streams cannot tell), let its lanes drain."""
        p = self.placements.remove(slot)
        self._draining[slot] = (p, reason)
        uids = self._transport.recall(slot)
        reqs = [self._live[u] for u in uids if u in self._live]
        if reqs:
            self._route(reqs)

    def retire_replica(self, expert: int, replica: int, *,
                       reason: str = "manual") -> None:
        """Manually quiesce one live replica (the autoscaler's scale-down
        path, exposed for operators and tests).  The slot is released —
        and a ``"down"`` event recorded — once its lanes drain."""
        p = self.placements.find(int(expert), int(replica))
        if p is None:
            raise ValueError(f"expert {expert} replica {replica} is not a "
                             f"live replica")
        if self.placements.n_replicas(int(expert)) <= 1:
            raise ValueError(f"cannot retire the last live replica of "
                             f"expert {expert}")
        self._begin_retire(p.slot, reason)

    def _finalize_drains(self) -> None:
        """Release every drained slot: stash its counters for the run
        report, free the backend resources, record the down event."""
        for s in sorted(self._draining):
            if self._transport.busy(s):
                continue
            p, reason = self._draining.pop(s)
            st = None
            try:
                st = self._transport.stats(s)
            except RuntimeError:
                pass                       # died while draining: no counters
            self._retired_stats.append((p, st))
            self._transport.remove_slot(s)
            if self.eng.transport == "tcp":
                self._retired_keys.add(p.key)
                if self._scale_executor is not None:
                    self._scale_executor.stop_replica(p)
            self.scale_events.append(ScaleEvent(
                tick=self.tick, action="down", expert=p.expert,
                replica=p.replica, reason=reason))

    def _sync_fleet(self) -> None:
        """tcp: re-derive placements from the registry between ticks —
        adopt workers that joined since (heartbeat expiry is the
        registry's half of scale-down; ours is ``_retired_keys``, so a
        replica this frontend retired is never re-adopted)."""
        try:
            fleet = netreg.call(self.eng.registry, "placements",
                                timeout=self.eng.net_timeout_s)
        except Exception:
            return    # registry is discovery-only: keep serving without it
        known = {p.key for p in self.placements}
        known |= {p.key for p in self._warming.values()}
        known |= {p.key for p, _ in self._draining.values()}
        known |= self._retired_keys
        for e, r, host, port in fleet:
            p = Placement(int(e), int(r), host=host, port=int(port))
            if p.key in known:
                continue
            try:
                slot = self._transport.add_slot(p.addr, p.label, expect=p)
            except RuntimeError:
                continue          # died between registering and our connect
            if self._tcp_spawning.get(p.expert, 0) > 0:
                self._tcp_spawning[p.expert] -= 1
            self._adopt(p.bind(slot), reason="fleet")

    def _autoscale_eval(self) -> None:
        if self.eng.transport == "tcp":
            self._sync_fleet()
        loads = {e: [SlotLoad(s, self._transport.load(s))
                     for s in self.placements.slots_of(e)]
                 for e in range(self.n_experts)}
        warming = {e: sum(p.expert == e for p in self._warming.values())
                   + self._tcp_spawning.get(e, 0)
                   for e in range(self.n_experts)}
        for act in self._scaler.observe(self.tick, loads, warming):
            if act[0] == "up":
                self._scale_up(act[1])
            else:
                self._begin_retire(act[2], reason="idle")

    # -- delta reassembly --------------------------------------------------
    def _deliver(self, msg: TokenDeltaMsg,
                 completed: list[Request]) -> None:
        """Fold one wire delta back into its live Request record."""
        req = self._live[msg.uid]
        req.tokens.append(msg.token)
        if msg.index == 0:
            req.admit_tick = msg.admit_tick
            req.t_first = time.perf_counter() - self._t0
        self.last_deltas.append(TokenDelta(
            request=req, token=msg.token, index=msg.index, done=msg.done,
            tick=msg.tick))
        if msg.done:
            req.finish_reason = msg.finish_reason
            req.finish_tick = msg.tick
            req.t_done = time.perf_counter() - self._t0
            del self._live[msg.uid]
            completed.append(req)

    # -- main loop ---------------------------------------------------------
    def step(self) -> list[Request]:
        """One frontend tick: route arrivals, run the scale loop, tick
        every busy server (draining ones included — their lanes must
        finish), release slots that just drained.

        Each expert advances on its own clock — idle experts are not
        ticked at all, and the process transport overlaps the busy ones'
        compute.  Returns the requests that finished this tick; the
        individual tokens it emitted (one :class:`TokenDelta` per token,
        in emission order) are left in :attr:`last_deltas` until the
        next step.
        """
        if self._t0 is None:
            self._t0 = time.perf_counter()
        self.last_deltas = []
        arrived = self.queue.pop_arrived(self.tick)
        if arrived:
            self._route(arrived)
        if self._warming:
            self._poll_warming()
        if self._scaler is not None and self.tick % self.scale.every == 0:
            self._autoscale_eval()
        completed: list[Request] = []
        tick_slots = sorted(set(self.placements.slots())
                            | set(self._draining))
        working = [s for s in tick_slots if self._transport.busy(s)]
        for _, msgs in self._transport.tick_many(working):
            for msg in msgs:
                self._deliver(msg, completed)
        if self._draining:
            self._finalize_drains()
        self.tick += 1
        return completed

    def _skip_idle_gap(self) -> None:
        """Fast-forward the tick counter over an empty simulated gap."""
        nxt = self.queue.next_arrival()
        if nxt is not None and nxt > self.tick \
                and not self._transport.any_busy:
            self.tick = nxt

    def stream(self):
        """Drive the engine, yielding one :class:`TokenDelta` per token.

        Deltas arrive in emission order (tick by tick, admissions before
        decodes); a request's final delta has ``done=True``, after which
        its lane and KV blocks are already recycled.  New requests may be
        submitted between deltas; the generator runs until the engine
        fully drains.
        """
        if self._t0 is None:
            self._t0 = time.perf_counter()
        while self.busy:
            self._skip_idle_gap()
            self.step()
            yield from self.last_deltas
        self._t0 = None               # fresh clock origin for a later run

    @property
    def busy(self) -> bool:
        return bool(len(self.queue)) or self._transport.any_busy

    @property
    def n_unadmitted(self) -> int:
        """Live requests that never got a decode lane (still in the
        arrival queue, or queued inside an expert under pool pressure).

        Their ``queue_ticks`` is still the 0 placeholder, so queue-wait
        aggregates silently undercount if they are folded in — report
        them separately instead (``run()`` surfaces this as
        ``n_unadmitted``; mid-run ``step()`` drivers can watch it live).
        """
        return sum(r.admit_tick < 0 for r in self._live.values())

    def kv_bytes_per_expert(self) -> int:
        """Device bytes held by one expert's decode caches.

        Computed from the cache specs, so it needs no access to the
        (possibly remote) device trees.
        """
        return cachelib.kv_cache_bytes(modellib.paged_cache_specs(
            self.ecfg, self.eng.lanes_per_expert, self.pool_blocks,
            self.eng.block_size, self.eng.max_len))

    def run(self) -> RunReport:
        """Drive ticks until drained; returns a :class:`RunReport`
        (requests + aggregate stats; dict-compatible — ``res["key"]``
        and ``res.to_dict()`` give the historical shape).

        Stats cover this run only (a warmup run on the same instance —
        which shares the jit caches — does not pollute a later timed
        run).  When some step() calls already ran, their time origin is
        kept so request timestamps stay on one clock; a fresh run()
        restarts the origin."""
        self._transport.reset_stats()
        self._retired_stats = []
        ev_mark = len(self.scale_events)
        self._peak = [self.placements.n_replicas(e)
                      for e in range(self.n_experts)]
        tick0 = self.tick
        t_start = time.perf_counter()
        if self._t0 is None:
            self._t0 = t_start
        completed: list[Request] = []
        n_steps = 0
        while self.busy:
            self._skip_idle_gap()     # jump empty gaps to the next arrival
            completed += self.step()
            n_steps += 1
        self._transport.sync()
        wall = time.perf_counter() - t_start
        self._t0 = None
        # one StatsMsg per live server slot, aggregated per expert (a hot
        # expert's counters sum over its replicas, replicas retired
        # mid-run included; the per-replica breakdown lists the live
        # ones for load-balance observability).  A slot whose StatsMsg
        # never arrives — its worker died — degrades to partial stats
        # with an explicit missing_replicas entry instead of losing the
        # whole report.
        slot_stats: dict[int, object] = {}
        missing: list[str] = []
        for p in self.placements:
            try:
                slot_stats[p.slot] = self._transport.stats(p.slot)
            except RuntimeError:
                slot_stats[p.slot] = None
                missing.append(p.label)
        retired = list(self._retired_stats)
        live = [st for st in slot_stats.values() if st is not None] \
            + [st for _, st in retired if st is not None]
        useful = sum(len(r.tokens) for r in completed)
        decode_calls = sum(st.decode_calls for st in live)
        lane_steps = sum(st.occupied_lane_steps for st in live)
        paged_rd = sum(st.paged_read_bytes for st in live)
        gathered_rd = sum(st.gathered_read_bytes for st in live)
        prefill_wr_fused = sum(st.prefill_write_fused_bytes for st in live)
        prefill_wr_slab = sum(st.prefill_write_slab_bytes for st in live)
        epilogue_bytes = sum(st.epilogue_logits_bytes for st in live)
        prefills = sum(st.prefill_calls for st in live)
        lanes = self.eng.lanes_per_expert

        def expert_stats(e):
            reps = self.placements.replicas_of(e)
            ss_live = [(p, slot_stats[p.slot]) for p in reps]
            ss = [st for _, st in ss_live if st is not None]
            ss += [st for p, st in retired
                   if p.expert == e and st is not None]
            dc = sum(st.decode_calls for st in ss)
            return {
                "served": sum(st.n_served for st in ss),
                "decode_calls": dc,
                "prefills": sum(st.prefill_calls for st in ss),
                "peak_blocks": max((st.peak_blocks for st in ss), default=0),
                "queue_wait_ticks": sum(st.queue_wait_ticks for st in ss),
                "prefix_hit_blocks": sum(st.prefix_hit_blocks for st in ss),
                "prefill_tokens_saved": sum(st.prefill_tokens_saved
                                            for st in ss),
                "occupancy": sum(st.occupied_lane_steps for st in ss)
                / max(dc * lanes, 1),
                "replicas": len(reps),
                "missing_replicas": [p.replica for p, st in ss_live
                                     if st is None],
                "per_replica": {
                    p.replica: {
                        "served": st.n_served,
                        "queue_wait_ticks": st.queue_wait_ticks,
                        "occupancy": st.occupied_lane_steps
                        / max(st.decode_calls * lanes, 1)}
                    for p, st in ss_live if st is not None},
            }
        autoscale = None
        if self.scale is not None:
            evs = self.scale_events[ev_mark:]
            autoscale = AutoscaleStats(
                scale_ups=sum(ev.action == "up" for ev in evs),
                scale_downs=sum(ev.action == "down" for ev in evs),
                peak_replicas={e: self._peak[e]
                               for e in range(self.n_experts)},
                final_replicas={e: self.placements.n_replicas(e)
                                for e in range(self.n_experts)},
                events=list(evs))
        return RunReport(
            requests=sorted(completed, key=lambda r: r.uid),
            ticks=self.tick - tick0,   # simulated span (incl. skipped gaps)
            steps=n_steps,             # scheduler iterations actually run
            wall_s=wall,
            useful_tokens=useful,
            early_stops=sum(r.finish_reason == "stop_token"
                            for r in completed),
            n_unadmitted=self.n_unadmitted,
            missing_replicas=missing,
            prefix_sharing=PrefixSharingStats(
                enabled=self.eng.prefix_cache,
                hit_blocks=sum(st.prefix_hit_blocks for st in live),
                prefill_tokens_saved=sum(st.prefill_tokens_saved
                                         for st in live),
                cached_blocks=sum(st.cached_blocks for st in live)),
            tokens_per_s=useful / max(wall, 1e-9),
            mean_ttft_s=float(np.mean([r.t_first for r in completed]))
            if completed else 0.0,
            occupancy=lane_steps / max(decode_calls * lanes, 1),
            prefill_calls=prefills,
            kv_bytes_per_lane=self.kv_bytes_per_expert() // lanes,
            decode_impl=self.decode_impl,
            prefill_impl=self.prefill_impl,
            transport=self.eng.transport,
            decode_read_bytes={
                "paged": paged_rd,
                "gathered": gathered_rd,
                "paged_per_tick": paged_rd // max(decode_calls, 1),
                "gathered_per_tick": gathered_rd // max(decode_calls, 1),
            },
            prefill_write_bytes={
                "fused": prefill_wr_fused,
                "slab": prefill_wr_slab,
                "fused_per_prefill": prefill_wr_fused // max(prefills, 1),
                "slab_per_prefill": prefill_wr_slab // max(prefills, 1),
            },
            epilogue_logits_bytes=epilogue_bytes,
            per_expert={e: expert_stats(e)
                        for e in range(self.n_experts)},
            autoscale=autoscale)
