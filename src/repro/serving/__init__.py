"""Continuous-batching serving for the asynchronous mixture.

The public entry point is :class:`ServeFrontend` — construct it with the
mixture (expert configs/params + router ensemble), an
:class:`EngineConfig` for the shape/scheduling knobs, and an optional
``replicas`` map cloning hot experts (the paper's no-talk premise makes
replication free: replicas share nothing, and each request is admitted
to the least-loaded replica of its argmax expert)::

    from repro.serving import EngineConfig, SamplingParams, ServeFrontend

    with ServeFrontend(ecfg, rcfg, expert_params, router_params,
                       EngineConfig(lanes_per_expert=4, max_len=128),
                       replicas={0: 2}) as eng:        # expert 0 is hot
        req = eng.submit(prompt, max_new_tokens=32,
                         sampling=SamplingParams(temperature=0.8, seed=1),
                         stop_tokens={0})
        for delta in eng.stream():                     # or eng.run()
            ...

Per-request generation is controlled by :class:`SamplingParams`
(temperature/top-k/top-p/seed; temperature 0 = greedy) and stop tokens,
sampled inside the per-expert jitted decode step with counter-based RNG
— tokens are a pure function of ``(seed, uid, step)``, invariant to
lane placement, tick interleaving, transport, and replica count.
Callers hold the :class:`Request` records ``submit`` returns; the
engine folds per-token deltas back into them.

Internally the engine is a router frontend
(:mod:`repro.serving.frontend`), one self-contained
:class:`ExpertServer` per (expert, replica) slot
(:mod:`repro.serving.expert_server`), and a pluggable versioned message
transport (:mod:`repro.serving.transport`) — in-process loopback by
default, one OS process per slot with
``EngineConfig(transport="process")``, or raw TCP to an independently
started worker fleet with ``EngineConfig(transport="tcp",
registry="host:port")`` (:mod:`repro.serving.net`: registry discovery,
self-ticking expert workers, connection-time ``WIRE_VERSION``
handshake, and leased uid namespaces so many stateless frontends can
share one fleet).  Each server shares prompt
prefixes copy-on-write through a refcounted radix cache over its paged
KV pool (:class:`PrefixCache`): repeated system prompts prefill once,
later admissions replay only their novel suffix (chunked by
``EngineConfig.prefill_chunk_tokens``), and tokens stay bitwise
identical with the cache on or off (``prefix_cache=False`` disables).  See
``src/repro/serving/README.md`` for the layering, the message protocol,
and the replication/admission policy.  :mod:`repro.serving.cli` defines
the shared command-line surface for the serving entry points;
:mod:`repro.serving.baseline` keeps the original one-shot serial path
as the numerical oracle and benchmark baseline.

:class:`MixtureServeEngine` is the deprecated pre-split name for
:class:`ServeFrontend`; it still works (old import paths included) but
warns on construction.
"""
from repro.serving.engine import EngineConfig, MixtureServeEngine, TokenDelta
from repro.serving.expert_server import ExpertServer
from repro.serving.frontend import ServeFrontend
from repro.serving.net import SocketTransport
from repro.serving.sampling import SamplingParams
from repro.serving.scheduler import (BlockAllocator, PrefixCache, Request,
                                     RequestQueue, SlotAllocator)
from repro.serving.transport import (LoopbackTransport, ProcessTransport,
                                     RequestMsg, StatsMsg, TokenDeltaMsg,
                                     Transport, WIRE_VERSION, check_version)

__all__ = ["BlockAllocator", "EngineConfig", "ExpertServer",
           "LoopbackTransport", "MixtureServeEngine", "PrefixCache",
           "ProcessTransport", "Request", "RequestMsg", "RequestQueue",
           "SamplingParams", "ServeFrontend", "SlotAllocator",
           "SocketTransport", "StatsMsg",
           "TokenDelta", "TokenDeltaMsg", "Transport", "WIRE_VERSION",
           "check_version"]
