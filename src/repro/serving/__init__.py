"""Continuous-batching serving for the asynchronous mixture.

This docstring is the API reference for the package: everything
exported below is the supported surface, grouped here by layer.

**Engine** — the public entry point is :class:`ServeFrontend`:
construct it with the mixture (expert configs/params + router
ensemble), an :class:`EngineConfig` for the shape/scheduling knobs, an
optional ``replicas`` map cloning hot experts, and an optional
:class:`ScalePolicy` that keeps the replica map live (the paper's
no-talk premise makes both free: replicas share nothing, each request
is admitted to the least-loaded replica of its argmax expert, and
replicas can join or leave mid-serve without touching token
identity)::

    from repro.serving import (EngineConfig, SamplingParams,
                               ScalePolicy, ServeFrontend)

    with ServeFrontend(ecfg, rcfg, expert_params, router_params,
                       EngineConfig(lanes_per_expert=4, max_len=128),
                       replicas={0: 2},          # expert 0 starts hot
                       scale=ScalePolicy()) as eng:
        req = eng.submit(prompt, max_new_tokens=32,
                         sampling=SamplingParams(temperature=0.8, seed=1),
                         stop_tokens={0})
        for delta in eng.stream():                 # or eng.run()
            ...

``run()`` returns a typed :class:`RunReport` (dict-compatible with the
historical report shape); with a policy installed its ``autoscale``
field is an :class:`AutoscaleStats`.

**Sampling** — :class:`SamplingParams`
(temperature/top-k/top-p/seed; temperature 0 = greedy) plus stop
tokens, sampled inside the per-expert jitted decode step with
counter-based RNG: tokens are a pure function of ``(seed, uid, step)``,
invariant to lane placement, tick interleaving, transport, replica
count, and live placement changes.  Callers hold the :class:`Request`
records ``submit`` returns; the engine folds per-token deltas back
into them.

**Placement** — :class:`Placement` names one (expert, replica) slot
(plus its address on tcp) and derives its human label in one place;
:class:`PlacementMap` is the frontend's live admission table.
:class:`ScalePolicy` / :class:`Autoscaler` / :class:`ScaleEvent` are
the deterministic scale loop (:mod:`repro.serving.autoscale`):
scale-up warms a slot off-path before admitting it; scale-down
quiesces — recall queued requests, drain lanes, release the slot.

**Servers and transports** — one self-contained :class:`ExpertServer`
per (expert, replica) slot (:mod:`repro.serving.expert_server`, also
home of ``bucket_len``/``PAD_SAFE_KINDS``/``resolve_shapes``) behind a
pluggable versioned message transport (:mod:`repro.serving.transport`):
in-process :class:`LoopbackTransport` by default, one OS process per
slot (:class:`ProcessTransport`) with
``EngineConfig(transport="process")``, or raw TCP
(:class:`SocketTransport`) to an independently started worker fleet
with ``EngineConfig(transport="tcp", registry="host:port")``
(:mod:`repro.serving.net`: registry discovery, self-ticking expert
workers, connection-time :data:`WIRE_VERSION` handshake, and leased
uid namespaces so many stateless frontends can share one fleet).  All
three support dynamic slot membership (``add_slot`` / ``remove_slot``
/ ``recall``) — the autoscaler's seam.

**KV cache** — each server shares prompt prefixes copy-on-write
through a refcounted radix cache over its paged KV pool
(:class:`PrefixCache`): repeated system prompts prefill once, later
admissions replay only their novel suffix (chunked by
``EngineConfig.prefill_chunk_tokens``), and tokens stay bitwise
identical with the cache on or off (``prefix_cache=False`` disables).
:class:`BlockAllocator` / :class:`SlotAllocator` are the underlying
pool bookkeeping (:mod:`repro.serving.scheduler`, with
:class:`RequestQueue` for arrival-time ordering).

See ``src/repro/serving/README.md`` for the layering, the message
protocol, the replication/admission policy, and the autoscaling
protocol.  :mod:`repro.serving.cli` defines the shared command-line
surface for the serving entry points; :mod:`repro.serving.baseline`
keeps the original one-shot serial path as the numerical oracle and
benchmark baseline.
"""
from repro.serving.autoscale import Autoscaler, ScaleEvent, ScalePolicy
from repro.serving.expert_server import (EngineConfig, ExpertServer,
                                         PAD_SAFE_KINDS, bucket_len,
                                         resolve_shapes)
from repro.serving.frontend import ServeFrontend, TokenDelta
from repro.serving.net import SocketTransport
from repro.serving.placement import Placement, PlacementMap
from repro.serving.report import (AutoscaleStats, PrefixSharingStats,
                                  RunReport)
from repro.serving.sampling import SamplingParams
from repro.serving.scheduler import (BlockAllocator, PrefixCache, Request,
                                     RequestQueue, SlotAllocator)
from repro.serving.transport import (LoopbackTransport, ProcessTransport,
                                     RequestMsg, StatsMsg, TokenDeltaMsg,
                                     Transport, WIRE_VERSION, check_version)

__all__ = ["Autoscaler", "AutoscaleStats", "BlockAllocator", "EngineConfig",
           "ExpertServer", "LoopbackTransport", "PAD_SAFE_KINDS",
           "Placement", "PlacementMap", "PrefixCache", "PrefixSharingStats",
           "ProcessTransport", "Request", "RequestMsg", "RequestQueue",
           "RunReport", "SamplingParams", "ScaleEvent", "ScalePolicy",
           "ServeFrontend", "SlotAllocator", "SocketTransport", "StatsMsg",
           "TokenDelta", "TokenDeltaMsg", "Transport", "WIRE_VERSION",
           "bucket_len", "check_version", "resolve_shapes"]
