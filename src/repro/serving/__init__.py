"""Continuous-batching serving for the asynchronous mixture.

:class:`MixtureServeEngine` is the production path: router-scored
admission into per-expert fixed-lane decode batches with a slotted KV
cache.  :mod:`repro.serving.baseline` keeps the original one-shot serial
path as the numerical oracle and benchmark baseline.
"""
from repro.serving.engine import EngineConfig, MixtureServeEngine
from repro.serving.scheduler import Request, RequestQueue, SlotAllocator

__all__ = ["EngineConfig", "MixtureServeEngine", "Request", "RequestQueue",
           "SlotAllocator"]
