"""Continuous-batching serving for the asynchronous mixture.

:class:`MixtureServeEngine` is the production path: router-scored
batched admission into per-expert fixed-lane decode batches over a paged
block-pool KV cache (:mod:`repro.serving.cache`), with per-request
:class:`SamplingParams` (greedy by default) and stop-token conditions
sampled inside the jitted decode step (:mod:`repro.serving.sampling`)
and a streaming interface (:meth:`MixtureServeEngine.stream`) yielding
:class:`TokenDelta` records as tokens decode.
:mod:`repro.serving.baseline` keeps the original one-shot serial path —
extended with the identical sampler — as the numerical oracle and
benchmark baseline.
"""
from repro.serving.engine import EngineConfig, MixtureServeEngine, TokenDelta
from repro.serving.sampling import SamplingParams
from repro.serving.scheduler import (BlockAllocator, Request, RequestQueue,
                                     SlotAllocator)

__all__ = ["BlockAllocator", "EngineConfig", "MixtureServeEngine", "Request",
           "RequestQueue", "SamplingParams", "SlotAllocator", "TokenDelta"]
