"""Continuous-batching serving for the asynchronous mixture.

:class:`MixtureServeEngine` is the production path: router-scored
batched admission into per-expert fixed-lane decode batches over a paged
block-pool KV cache (:mod:`repro.serving.cache`).
:mod:`repro.serving.baseline` keeps the original one-shot serial path as
the numerical oracle and benchmark baseline.
"""
from repro.serving.engine import EngineConfig, MixtureServeEngine
from repro.serving.scheduler import (BlockAllocator, Request, RequestQueue,
                                     SlotAllocator)

__all__ = ["BlockAllocator", "EngineConfig", "MixtureServeEngine", "Request",
           "RequestQueue", "SlotAllocator"]
