"""Continuous-batching serving for the asynchronous mixture.

:class:`MixtureServeEngine` is the production path: router-scored
batched admission into per-expert fixed-lane decode batches over a paged
block-pool KV cache (:mod:`repro.serving.cache`), with per-request
:class:`SamplingParams` (greedy by default) and stop-token conditions
sampled inside the jitted decode step (:mod:`repro.serving.sampling`)
and a streaming interface (:meth:`MixtureServeEngine.stream`) yielding
:class:`TokenDelta` records as tokens decode.

Internally the engine is split into a router frontend
(:mod:`repro.serving.frontend`), one self-contained
:class:`ExpertServer` per expert (:mod:`repro.serving.expert_server`),
and a pluggable message transport (:mod:`repro.serving.transport`) —
in-process loopback by default, or one OS process per expert with
``EngineConfig(transport="process")``.  See
``src/repro/serving/README.md`` for the layering and the message
protocol.  :mod:`repro.serving.baseline` keeps the original one-shot
serial path — extended with the identical sampler — as the numerical
oracle and benchmark baseline.
"""
from repro.serving.engine import EngineConfig, MixtureServeEngine, TokenDelta
from repro.serving.expert_server import ExpertServer
from repro.serving.frontend import ServeFrontend
from repro.serving.sampling import SamplingParams
from repro.serving.scheduler import (BlockAllocator, Request, RequestQueue,
                                     SlotAllocator)
from repro.serving.transport import (LoopbackTransport, ProcessTransport,
                                     RequestMsg, StatsMsg, TokenDeltaMsg,
                                     Transport)

__all__ = ["BlockAllocator", "EngineConfig", "ExpertServer",
           "LoopbackTransport", "MixtureServeEngine", "ProcessTransport",
           "Request", "RequestMsg", "RequestQueue", "SamplingParams",
           "ServeFrontend", "SlotAllocator", "StatsMsg", "TokenDelta",
           "TokenDeltaMsg", "Transport"]
