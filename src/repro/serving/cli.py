"""Shared command-line surface for the serving entry points.

``repro.launch.serve``, ``examples/serve_mixture.py`` and
``benchmarks/serve_bench.py`` all expose the same engine knobs —
transport, decode kernel, paged-KV shape, replication, sampling recipe.
Defining the flags once here keeps them from drifting across the three
front-ends: a new knob (like ``--replicas``) lands everywhere with one
edit, with identical names, types, and help text.

Only ``argparse`` and :mod:`repro.serving.sampling` are imported — this
module stays importable without touching jax, so ``--help`` is instant.
"""
from __future__ import annotations

import argparse

from repro.serving.sampling import SamplingParams


class ReplicaSpecError(ValueError, argparse.ArgumentTypeError):
    """A malformed ``--replicas`` spec.  Doubly derived on purpose:
    library callers catch the plain :class:`ValueError`, while argparse
    shows :class:`argparse.ArgumentTypeError` messages verbatim — a bare
    ValueError from a ``type=`` callable would be swallowed into an
    unhelpful "invalid parse_replicas value"."""


def parse_replicas(spec: str) -> dict[int, int]:
    """``"0:2,3:4"`` -> ``{0: 2, 3: 4}`` (expert id -> replica count).

    The empty string means no replication.  A repeated expert id raises
    (two counts for one expert is always a typo, and silently letting
    the last one win would mask it).  Validation beyond syntax — expert
    ids in range, counts >= 1 — happens in
    :class:`repro.serving.ServeFrontend`, which knows the mixture size.
    """
    out: dict[int, int] = {}
    for part in filter(None, (p.strip() for p in spec.split(","))):
        e, sep, r = part.partition(":")
        try:
            if not sep:
                raise ValueError
            expert, count = int(e), int(r)
        except ValueError:
            raise ReplicaSpecError(
                f"bad --replicas entry {part!r}: expected EXPERT:COUNT "
                f"(e.g. 0:2,3:4)") from None
        if expert in out:
            raise ReplicaSpecError(f"--replicas names expert {expert} twice")
        out[expert] = count
    return out


def add_engine_args(ap: argparse.ArgumentParser, *, lanes: int = 4,
                    block_size: int = 16) -> argparse.ArgumentParser:
    """The engine-shape/backend flags every serving front-end exposes."""
    g = ap.add_argument_group("engine")
    g.add_argument("--lanes", type=int, default=lanes,
                   help="decode lanes per expert server (fixed batch width)")
    g.add_argument("--block-size", type=int, default=block_size,
                   help="tokens per paged KV block")
    g.add_argument("--blocks-per-expert", type=int, default=0,
                   help="KV pool blocks per expert server "
                        "(0 = lanes*max_len/block_size, i.e. no pressure)")
    g.add_argument("--decode-impl", choices=["auto", "jnp", "pallas"],
                   default="auto",
                   help="paged decode attention: jnp gather reference or "
                        "the Pallas block-table kernel (interpret-mode on "
                        "CPU; auto follows the expert config)")
    g.add_argument("--prefill-impl", choices=["auto", "jnp", "pallas"],
                   default="auto",
                   help="admission prefill: jnp/pallas run the fused "
                        "paged prefill (attention + direct pool write, no "
                        "dense slab); auto follows the expert config on "
                        "fused-capable shapes and falls back to the "
                        "slab+scatter path otherwise")
    g.add_argument("--transport", choices=["loopback", "process", "tcp"],
                   default="loopback",
                   help="expert backend: in-process loopback, one spawned "
                        "OS process per (expert, replica) server, or tcp — "
                        "independently-started network expert workers "
                        "discovered via --registry (router-scored requests "
                        "are the only cross-host traffic)")
    g.add_argument("--registry", default="",
                   help="tcp transport: HOST:PORT of the "
                        "repro.serving.net.registry the expert workers "
                        "registered with (serve_bench self-starts a local "
                        "fleet when omitted; other front-ends require it)")
    g.add_argument("--net-timeout", type=float, default=60.0,
                   help="tcp transport: connect/read timeout per wire op "
                        "(seconds)")
    g.add_argument("--net-poll-ms", type=int, default=20,
                   help="tcp transport: how long a worker holds a poll "
                        "open waiting for new tokens")
    g.add_argument("--replicas", type=parse_replicas, default={},
                   help="hot-expert replication as EXPERT:COUNT pairs, "
                        "e.g. '0:2' runs two servers for expert 0; "
                        "requests go to the least-loaded replica "
                        "(default: one server per expert)")
    g.add_argument("--no-prefix-cache", action="store_true",
                   help="disable prefix-sharing KV: every request "
                        "prefills its full prompt even when the leading "
                        "blocks are cached")
    g.add_argument("--prefill-chunk-tokens", type=int, default=0,
                   help="per-tick token budget for replaying a cached-"
                        "prefix request's novel prompt suffix "
                        "(0 = unlimited: finish the suffix in one tick)")
    return ap


def engine_config_from_args(args: argparse.Namespace, *, max_len: int,
                            prefix_len: int,
                            min_prefill_bucket: int | None = None,
                            route_batch: int | None = None):
    """Build the :class:`repro.serving.EngineConfig` the
    ``add_engine_args`` flags describe.

    The shape knobs no front-end exposes as flags (``max_len``,
    ``prefix_len``, and optionally the prefill bucket / route batch) are
    keyword-only — each caller derives them from its own workload.
    Imported lazily so this module stays jax-free for ``--help``.
    """
    from repro.serving.expert_server import EngineConfig

    kw = dict(lanes_per_expert=args.lanes, max_len=max_len,
              prefix_len=prefix_len, block_size=args.block_size,
              pool_blocks=args.blocks_per_expert,
              decode_impl=args.decode_impl, prefill_impl=args.prefill_impl,
              transport=args.transport,
              registry=args.registry, net_timeout_s=args.net_timeout,
              net_poll_ms=args.net_poll_ms,
              prefix_cache=not args.no_prefix_cache,
              prefill_chunk_tokens=args.prefill_chunk_tokens)
    if min_prefill_bucket is not None:
        kw["min_prefill_bucket"] = min_prefill_bucket
    if route_batch is not None:
        kw["route_batch"] = route_batch
    return EngineConfig(**kw)


def add_autoscale_args(ap: argparse.ArgumentParser) -> argparse.ArgumentParser:
    """The live-autoscaling flags (``repro.serving.autoscale.ScalePolicy``
    knobs; the defaults here mirror the dataclass defaults)."""
    g = ap.add_argument_group("autoscale")
    g.add_argument("--autoscale", action="store_true",
                   help="grow/shrink the replica map live: spawn a replica "
                        "when an expert's backlog exceeds its lane capacity, "
                        "quiesce and retire one after sustained idleness "
                        "(tokens stay bitwise identical either way)")
    g.add_argument("--scale-up-pressure", type=int, default=1,
                   help="queued-beyond-capacity requests that count as "
                        "pressure on one expert")
    g.add_argument("--scale-up-ticks", type=int, default=2,
                   help="consecutive pressured evaluations before a "
                        "scale-up (hysteresis)")
    g.add_argument("--scale-down-idle", type=int, default=8,
                   help="consecutive zero-load evaluations before a "
                        "replica is retired")
    g.add_argument("--scale-cooldown", type=int, default=16,
                   help="ticks after any scale op before the same expert "
                        "may scale again")
    g.add_argument("--scale-min-replicas", type=int, default=1,
                   help="never retire below this many replicas per expert")
    g.add_argument("--scale-max-replicas", type=int, default=4,
                   help="never spawn beyond this many replicas per expert")
    g.add_argument("--scale-every", type=int, default=1,
                   help="evaluate the policy every N frontend ticks")
    return ap


def scale_policy_from_args(args: argparse.Namespace):
    """The :class:`repro.serving.autoscale.ScalePolicy` the
    ``add_autoscale_args`` flags describe, or ``None`` without
    ``--autoscale``.  Imported lazily to keep ``--help`` jax-free."""
    if not args.autoscale:
        return None
    from repro.serving.autoscale import ScalePolicy

    return ScalePolicy(up_pressure=args.scale_up_pressure,
                       up_ticks=args.scale_up_ticks,
                       down_idle_ticks=args.scale_down_idle,
                       cooldown_ticks=args.scale_cooldown,
                       min_replicas=args.scale_min_replicas,
                       max_replicas=args.scale_max_replicas,
                       every=args.scale_every).validate()


def add_sampling_args(ap: argparse.ArgumentParser, *,
                      temperature: float = 0.0, top_k: int = 0,
                      top_p: float = 1.0) -> argparse.ArgumentParser:
    """The per-request sampling-recipe flags (defaults differ per tool:
    the CLI serves greedy unless asked, the bench's sampled mode wants a
    spicier recipe — hence the keyword overrides)."""
    g = ap.add_argument_group("sampling")
    g.add_argument("--temperature", type=float, default=temperature,
                   help="sampling temperature (0 = greedy argmax)")
    g.add_argument("--top-k", type=int, default=top_k,
                   help="keep only the k highest logits (0 = disabled)")
    g.add_argument("--top-p", type=float, default=top_p,
                   help="nucleus sampling mass (1 = disabled)")
    g.add_argument("--sample-seed", type=int, default=0,
                   help="RNG root; tokens are a pure function of "
                        "(seed, request uid, step)")
    return ap


def sampling_from_args(args: argparse.Namespace) -> SamplingParams:
    """The frozen recipe the ``add_sampling_args`` flags describe."""
    return SamplingParams(temperature=args.temperature, top_k=args.top_k,
                          top_p=args.top_p, seed=args.sample_seed)
