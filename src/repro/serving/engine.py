"""Continuous-batching serving engine for the routed mixture (paper §2.2).

The paper's inference story is that a tiny router ensemble scores the
request prefix and exactly ONE expert serves the request — so the mixture
costs 1/E of its parameters at inference.  That only pays off at scale if
the serving path keeps every expert's decode lanes full.  This engine
does that with the classic continuous-batching loop:

  submit -> [router scores prefix, argmax expert]      (batched, padded)
         -> per-expert FIFO until a decode lane frees
         -> prefill into a slotted lane cache           (bucketed lengths)
         -> joined into that expert's fixed-lane decode batch mid-flight

Every tick runs ONE jitted ``decode_step`` per expert with active lanes,
over stable shapes ``(lanes, 1)`` — finished sequences are evicted and
queued requests admitted between ticks without ever recompiling.  Decode
is greedy and matches the one-shot :func:`repro.serving.baseline.generate`
token-for-token: the first token comes from the prefill logits, each
decode feeds the previous token at its lane's own position (per-slot
``positions`` / ``cache_index`` vectors, see ``models/model.decode_step``).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import base as cfglib
from repro.core import assignment as asg
from repro.core import router as routerlib
from repro.models import model as modellib
from repro.serving import cache as cachelib
from repro.serving.scheduler import Request, RequestQueue, SlotAllocator

PAD_SAFE_KINDS = (cfglib.ATTN, cfglib.ATTN_SHARED)


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Shape/scheduling knobs (all static: they define the compiled shapes)."""
    lanes_per_expert: int = 4     # fixed decode-batch width per expert
    max_len: int = 128            # per-lane KV budget (prompt + new tokens)
    prefix_len: int = 32          # router scoring prefix M
    route_batch: int = 8          # router calls are padded to this many rows
    min_prefill_bucket: int = 16  # smallest power-of-2 prompt bucket


@dataclasses.dataclass
class _Expert:
    """Mutable per-expert serving state (host side + one device cache tree)."""
    caches: object
    alloc: SlotAllocator
    pending: deque
    tok: np.ndarray               # (lanes,) last emitted token per lane
    pos: np.ndarray               # (lanes,) next decode position per lane
    active: np.ndarray            # (lanes,) bool
    req: list                     # slot -> Request | None
    n_served: int = 0
    decode_calls: int = 0
    prefill_calls: int = 0
    occupied_lane_steps: int = 0  # sum of active lanes over decode calls


class MixtureServeEngine:
    """Queue + scheduler + per-expert continuous decode batches."""

    def __init__(self, ecfg, rcfg, expert_params: list, router_params,
                 eng: EngineConfig = EngineConfig()):
        if not ecfg.causal:
            raise ValueError("serving needs a causal (decoder) expert config")
        self.ecfg, self.rcfg, self.eng = ecfg, rcfg, eng
        self.expert_params = list(expert_params)
        self.router_params = router_params
        self.n_experts = len(self.expert_params)
        # prompt-length bucketing pads on the right; that is exact for full
        # attention (causal mask hides the future) but would pollute
        # rotating-window KV buffers and recurrent (SSM/xLSTM) states, so
        # those archs fall back to exact-length prefill compiles.
        self.pad_safe = all(k in PAD_SAFE_KINDS for k in ecfg.layer_pattern)

        L, M = eng.lanes_per_expert, eng.max_len
        self._experts = [
            _Expert(caches=cachelib.init_lane_caches(ecfg, L, M),
                    alloc=SlotAllocator(L), pending=deque(),
                    tok=np.zeros(L, np.int32), pos=np.zeros(L, np.int32),
                    active=np.zeros(L, bool), req=[None] * L)
            for _ in range(self.n_experts)]
        self.queue = RequestQueue()
        self.tick = 0
        self._uid = 0
        self._t0: float | None = None

        self._decode_fn = jax.jit(
            lambda p, toks, pos, ci, c: modellib.decode_step(
                p, ecfg, {"tokens": toks, "positions": pos,
                          "cache_index": ci}, c))
        self._prefill_fn = jax.jit(
            lambda p, toks, last: modellib.prefill(
                p, ecfg, {"tokens": toks}, cache_len=M, last_index=last))
        self._score_fn = jax.jit(
            lambda rp, toks: routerlib.ensemble_scores(rp, rcfg, toks))
        self._insert_fn = jax.jit(cachelib.insert_request)
        self._release_fn = jax.jit(cachelib.release_slots)

    # -- request intake ----------------------------------------------------
    def submit(self, prompt, max_new_tokens: int,
               arrival_tick: int | None = None) -> Request:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if len(prompt) < self.eng.prefix_len:
            raise ValueError(f"prompt shorter than routing prefix "
                             f"({len(prompt)} < {self.eng.prefix_len})")
        if len(prompt) + max_new_tokens > self.eng.max_len:
            raise ValueError(f"prompt {len(prompt)} + {max_new_tokens} new "
                             f"tokens exceeds lane budget {self.eng.max_len}")
        req = Request(uid=self._uid, prompt=prompt,
                      max_new_tokens=max_new_tokens,
                      arrival_tick=self.tick if arrival_tick is None
                      else arrival_tick)
        self._uid += 1
        self.queue.push(req)
        return req

    # -- routing -----------------------------------------------------------
    def _route(self, reqs: list[Request]) -> None:
        """Score prefixes in padded fixed-width batches, argmax an expert."""
        pl, rb = self.eng.prefix_len, self.eng.route_batch
        prefixes = np.stack([r.prompt[:pl] for r in reqs])
        for i in range(0, len(reqs), rb):
            chunk = prefixes[i:i + rb]
            n = len(chunk)
            if n < rb:        # pad with copies of row 0; scores are per-row
                chunk = np.concatenate([chunk, np.repeat(chunk[:1],
                                                         rb - n, 0)])
            scores = np.asarray(self._score_fn(self.router_params,
                                               jnp.asarray(chunk)))
            eids = np.asarray(asg.argmax_assignment(scores[:n]))
            for r, e in zip(reqs[i:i + n], eids):
                r.expert = int(e)
                r.route_tick = self.tick
                self._experts[r.expert].pending.append(r)

    # -- lane lifecycle ----------------------------------------------------
    def _bucket(self, n: int) -> int:
        if not self.pad_safe:
            return n
        b = self.eng.min_prefill_bucket
        while b < n:
            b *= 2
        return min(b, self.eng.max_len)

    def _admit(self, e: int, st: _Expert, completed: list[Request]) -> None:
        params = self.expert_params[e]
        while st.pending and st.alloc.n_free:
            req = st.pending.popleft()
            slot = st.alloc.alloc()
            n = len(req.prompt)
            padded = np.zeros(self._bucket(n), np.int32)
            padded[:n] = req.prompt
            logits, rcache = self._prefill_fn(
                params, jnp.asarray(padded[None]),
                jnp.full((1,), n - 1, jnp.int32))
            st.prefill_calls += 1
            st.caches = self._insert_fn(st.caches, rcache,
                                        np.int32(slot), np.int32(n))
            first = int(np.argmax(np.asarray(logits[0])))
            req.tokens.append(first)
            req.admit_tick = self.tick
            req.t_first = time.perf_counter() - self._t0
            st.tok[slot], st.pos[slot] = first, n
            st.active[slot], st.req[slot] = True, req
            if req.max_new_tokens == 1:
                self._finish(st, slot, completed)

    def _finish(self, st: _Expert, slot: int, completed: list[Request]) -> None:
        req = st.req[slot]
        req.finish_tick = self.tick
        req.t_done = time.perf_counter() - self._t0
        st.active[slot] = False
        st.req[slot] = None
        st.tok[slot] = st.pos[slot] = 0
        st.alloc.free(slot)
        st.n_served += 1
        completed.append(req)

    def _decode(self, e: int, st: _Expert, completed: list[Request]) -> None:
        if not st.active.any():
            return
        # inactive lanes decode at position -1: every KV slot is masked for
        # them and their writes land as empty (-1) markers, so a free lane
        # can ride along in the fixed-shape batch at zero correctness cost
        pos = np.where(st.active, st.pos, -1).astype(np.int32)
        logits, st.caches = self._decode_fn(
            self.expert_params[e], jnp.asarray(st.tok[:, None]),
            jnp.asarray(pos[:, None]), jnp.asarray(pos), st.caches)
        st.decode_calls += 1
        st.occupied_lane_steps += int(st.active.sum())
        nxt = np.asarray(jnp.argmax(logits[:, 0], -1)).astype(np.int32)
        freed = np.zeros(len(st.active), bool)
        for slot in np.nonzero(st.active)[0]:
            req = st.req[slot]
            req.tokens.append(int(nxt[slot]))
            st.tok[slot] = nxt[slot]
            st.pos[slot] += 1
            if len(req.tokens) >= req.max_new_tokens:
                freed[slot] = True
                self._finish(st, int(slot), completed)
        if freed.any():
            st.caches = self._release_fn(st.caches, jnp.asarray(freed))

    # -- main loop ---------------------------------------------------------
    def step(self) -> list[Request]:
        """One scheduler tick: route arrivals, admit, decode every expert."""
        if self._t0 is None:
            self._t0 = time.perf_counter()
        arrived = self.queue.pop_arrived(self.tick)
        if arrived:
            self._route(arrived)
        completed: list[Request] = []
        for e, st in enumerate(self._experts):
            self._admit(e, st, completed)
            self._decode(e, st, completed)
        self.tick += 1
        return completed

    @property
    def busy(self) -> bool:
        return bool(len(self.queue)) or any(
            st.pending or st.active.any() for st in self._experts)

    def run(self) -> dict:
        """Drive ticks until drained; returns requests + aggregate stats.

        Stats cover this run only (a warmup run on the same instance — which
        shares the jit caches — does not pollute a later timed run).  When
        some step() calls already ran, their time origin is kept so request
        timestamps stay on one clock; a fresh run() restarts the origin."""
        for st in self._experts:
            st.n_served = st.decode_calls = st.prefill_calls = 0
            st.occupied_lane_steps = 0
        tick0 = self.tick
        t_start = time.perf_counter()
        if self._t0 is None:
            self._t0 = t_start
        completed: list[Request] = []
        n_steps = 0
        while self.busy:
            # fast-forward idle gaps to the next simulated arrival
            nxt = self.queue.next_arrival()
            if nxt is not None and nxt > self.tick and not any(
                    st.pending or st.active.any() for st in self._experts):
                self.tick = nxt
            completed += self.step()
            n_steps += 1
        jax.block_until_ready([st.caches for st in self._experts])
        wall = time.perf_counter() - t_start
        self._t0 = None
        useful = sum(len(r.tokens) for r in completed)
        decode_calls = sum(st.decode_calls for st in self._experts)
        lane_steps = sum(st.occupied_lane_steps for st in self._experts)
        return {
            "requests": sorted(completed, key=lambda r: r.uid),
            "ticks": self.tick - tick0,    # simulated span (incl. skipped gaps)
            "steps": n_steps,              # scheduler iterations actually run
            "wall_s": wall,
            "useful_tokens": useful,
            "tokens_per_s": useful / max(wall, 1e-9),
            "mean_ttft_s": float(np.mean([r.t_first for r in completed]))
            if completed else 0.0,
            "occupancy": lane_steps / max(
                decode_calls * self.eng.lanes_per_expert, 1),
            "per_expert": {
                e: {"served": st.n_served, "decode_calls": st.decode_calls,
                    "prefills": st.prefill_calls}
                for e, st in enumerate(self._experts)},
        }
