"""Continuous-batching serving engine for the routed mixture (paper §2.2).

The paper's inference story is that a tiny router ensemble scores the
request prefix and exactly ONE expert serves the request — so the mixture
costs 1/E of its parameters at inference.  That only pays off at scale if
the serving path keeps every expert's decode lanes full.  This engine
does that with the classic continuous-batching loop:

  submit -> [router scores prefix, argmax expert]      (batched, padded)
         -> per-expert FIFO until a decode lane AND pool blocks free
         -> batched prefill into the paged block-pool KV cache
         -> joined into that expert's fixed-lane decode batch mid-flight

KV memory is *paged* (see :mod:`repro.serving.cache`): full-attention
layers share a per-expert pool of ``block_size``-token blocks and each
lane holds a block table instead of a dense ``max_len`` slab, so the
pool can be sized below ``lanes * max_len`` and admission reserves only
``ceil(len(prompt)+max_new-1) / block_size)`` blocks per request.  The
decode *read* goes through the unified paged-attention dispatch
(:mod:`repro.kernels.paged_attention.ops`): ``EngineConfig.decode_impl``
selects the jnp gather reference (tokens bit-identical to the baseline
oracle) or the Pallas block-table kernel that reads only live blocks;
either way :meth:`MixtureServeEngine.run` reports the paged read
bytes/tick next to what the old gathered ``(lanes, max_len)`` view
would have cost (``decode_read_bytes``).

Admission is *batched*: one tick drains up to ``lanes_per_expert``
pending requests into a single prefill call padded to a fixed batch
width and one shared prompt-length bucket (one compile per bucket, not
per request), then inserts all of them with a single jitted scatter.
Archs whose prefill is not right-pad-safe (sliding-window, SSM, xLSTM)
fall back to exact-length one-request prefills.

Every tick runs ONE jitted ``decode_step`` per expert with active lanes,
over stable shapes ``(lanes, 1)`` — finished sequences are evicted and
queued requests admitted between ticks without ever recompiling.  The
next token is drawn *inside* that jit by the shared row-wise sampler
(:mod:`repro.serving.sampling`): per-lane ``temperature`` / ``top_k`` /
``top_p`` arrays plus a counter-based RNG key per lane
(``fold_in(fold_in(PRNGKey(seed), uid), step)``) are plain traced
operands, so any mix of greedy and sampled requests shares one compiled
program and a request's tokens are invariant to which lane it lands in.
Greedy requests (``temperature=0``, the default) still match the
one-shot :func:`repro.serving.baseline.generate` token-for-token, and
sampled requests match ``baseline.generate`` run with the same
``SamplingParams`` and uid — the first token comes from the prefill
logits, each decode feeds the previous token at its lane's own position
(per-slot ``positions`` / ``cache_index`` vectors plus ``block_tables``,
see ``models/model.decode_step``).

A request ends when it hits its ``max_new_tokens`` budget or emits one
of its ``stop_tokens`` — early stops free the lane and its KV pool
blocks the same tick, so a queued request can take them at the next
admission.  Callers either drive :meth:`MixtureServeEngine.run` for a
batch result or iterate :meth:`MixtureServeEngine.stream` to consume
per-token :class:`TokenDelta` records as they decode.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import base as cfglib
from repro.core import assignment as asg
from repro.core import router as routerlib
from repro.models import model as modellib
from repro.serving import cache as cachelib
from repro.serving import sampling as samplib
from repro.serving.sampling import SamplingParams
from repro.serving.scheduler import (BlockAllocator, Request, RequestQueue,
                                     SlotAllocator)

PAD_SAFE_KINDS = (cfglib.ATTN, cfglib.ATTN_SHARED)


@dataclasses.dataclass(frozen=True)
class TokenDelta:
    """One streamed token: request, its value/position, and completion."""
    request: Request
    token: int
    index: int                    # position within request.tokens
    done: bool                    # True on the request's final token
    tick: int


def bucket_len(n: int, min_bucket: int, max_len: int) -> int:
    """Prompt-length bucket: ``min_bucket`` doubled until >= n, capped at
    ``max_len``.  Monotone in ``n``, so admission batches can pad to the
    largest bucket among their members."""
    if min_bucket < 1:
        raise ValueError(f"min_bucket must be >= 1, got {min_bucket}")
    b = min_bucket
    while b < n:
        b *= 2
    return min(b, max_len)


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Shape/scheduling knobs (all static: they define the compiled shapes)."""
    lanes_per_expert: int = 4     # fixed decode-batch width per expert
    max_len: int = 128            # per-lane KV budget (prompt + new tokens)
    prefix_len: int = 32          # router scoring prefix M
    route_batch: int = 8          # router calls are padded to this many rows
    min_prefill_bucket: int = 16  # smallest power-of-2 prompt bucket
    block_size: int = 16          # tokens per paged KV block
    pool_blocks: int = 0          # KV blocks per expert; 0 -> lanes*max_len/bs
    decode_impl: str = "auto"     # paged decode kernel: auto|jnp|pallas
                                  # (auto follows the expert cfg's use_pallas)


@functools.lru_cache(maxsize=None)
def _jit_fns(ecfg, dcfg, rcfg, max_len: int):
    """Jitted serving kernels, shared across engine instances.

    Keyed on the (hashable, frozen) configs so fuzz suites building many
    engines reuse one compile cache instead of re-jitting per instance.
    ``dcfg`` is the decode-side expert config — identical to ``ecfg``
    except possibly ``use_pallas``, so ``EngineConfig.decode_impl`` can
    flip the paged-attention kernel without dragging prefill onto the
    Pallas flash path.
    """
    def decode_and_sample(p, toks, pos, ci, bt, c, keys, steps, temps,
                          top_ks, top_ps):
        logits, nc = modellib.decode_step(
            p, dcfg, {"tokens": toks, "positions": pos, "cache_index": ci,
                      "block_tables": bt}, c)
        return samplib.sample_tokens(logits[:, 0], keys, steps, temps,
                                     top_ks, top_ps), nc

    def decode_greedy(p, toks, pos, ci, bt, c):
        # all-greedy ticks skip the sampler entirely (its sort/softmax
        # work per lane per token is pure waste when every temp is 0);
        # both programs compile once, so mode flips never recompile
        logits, nc = modellib.decode_step(
            p, dcfg, {"tokens": toks, "positions": pos, "cache_index": ci,
                      "block_tables": bt}, c)
        return jnp.argmax(logits[:, 0], -1).astype(jnp.int32), nc

    decode = jax.jit(decode_and_sample)
    decode_g = jax.jit(decode_greedy)
    prefill = jax.jit(
        lambda p, toks, last: modellib.prefill(
            p, ecfg, {"tokens": toks}, cache_len=max_len, last_index=last))
    score = jax.jit(
        lambda rp, toks: routerlib.ensemble_scores(rp, rcfg, toks))
    insert = jax.jit(functools.partial(cachelib.insert_requests, ecfg))
    return decode, decode_g, prefill, score, insert, samplib.sample_tokens_jit


@dataclasses.dataclass
class _Expert:
    """Mutable per-expert serving state (host side + one device cache tree)."""
    caches: object
    alloc: SlotAllocator
    balloc: BlockAllocator
    pending: deque
    tok: np.ndarray               # (lanes,) last emitted token per lane
    pos: np.ndarray               # (lanes,) next decode position per lane
    active: np.ndarray            # (lanes,) bool
    req: list                     # slot -> Request | None
    block_tables: np.ndarray      # (lanes, max_len // block_size) int32
    blocks: list                  # slot -> list[int] reserved pool blocks
    # per-lane sampling state, fed straight into the jitted decode+sample
    keys: np.ndarray              # (lanes, 2) uint32 request RNG roots
    steps: np.ndarray             # (lanes,) int32 next token counter
    temp: np.ndarray              # (lanes,) float32; 0 = greedy
    topk: np.ndarray              # (lanes,) int32; 0 = disabled
    topp: np.ndarray              # (lanes,) float32; 1 = disabled
    n_served: int = 0
    decode_calls: int = 0
    prefill_calls: int = 0
    occupied_lane_steps: int = 0  # sum of active lanes over decode calls
    # KV read traffic of the paged decode path vs the gathered view it
    # replaced (bookkeeping from reserved-block counts, impl-independent)
    paged_read_bytes: int = 0
    gathered_read_bytes: int = 0


class MixtureServeEngine:
    """Queue + scheduler + per-expert continuous decode batches."""

    def __init__(self, ecfg, rcfg, expert_params: list, router_params,
                 eng: EngineConfig = EngineConfig()):
        if not ecfg.causal:
            raise ValueError("serving needs a causal (decoder) expert config")
        self.ecfg, self.rcfg, self.eng = ecfg, rcfg, eng
        self.expert_params = list(expert_params)
        self.router_params = router_params
        self.n_experts = len(self.expert_params)
        # prompt-length bucketing pads on the right; that is exact for full
        # attention (causal mask hides the future) but would pollute
        # rotating-window KV buffers and recurrent (SSM/xLSTM) states, so
        # those archs fall back to exact-length prefill compiles.
        self.pad_safe = all(k in PAD_SAFE_KINDS for k in ecfg.layer_pattern)
        # only full-attention layers hold paged KV; pure-recurrent /
        # sliding-window experts never touch the block pool
        self.has_pool = any(k in cachelib.POOL_KINDS
                            for k in ecfg.layer_pattern)

        if eng.min_prefill_bucket < 1:
            raise ValueError(f"min_prefill_bucket must be >= 1, "
                             f"got {eng.min_prefill_bucket}")
        if eng.decode_impl not in ("auto", "jnp", "pallas"):
            raise ValueError(f"decode_impl must be 'auto', 'jnp' or "
                             f"'pallas', got {eng.decode_impl!r}")
        # decode_impl overrides use_pallas for the jitted decode programs
        # only: prefill keeps the expert config's own kernel choice
        dcfg = ecfg if eng.decode_impl == "auto" else \
            ecfg.replace(use_pallas=eng.decode_impl == "pallas")
        self.decode_impl = "pallas" if dcfg.use_pallas else "jnp"
        L, M, bs = eng.lanes_per_expert, eng.max_len, eng.block_size
        if self.has_pool and M % bs:
            raise ValueError(f"max_len {M} not a multiple of "
                             f"block_size {bs}")
        self.lane_blocks = -(-M // bs)
        pool = eng.pool_blocks or L * self.lane_blocks
        if self.has_pool and pool < self.lane_blocks:
            raise ValueError(
                f"pool_blocks {pool} cannot hold one max-size request "
                f"({self.lane_blocks} blocks) — the queue would deadlock")
        self.pool_blocks = pool
        # per-(block, layer) decode read traffic: k + v + slot positions
        self._pool_layers = sum(k in cachelib.POOL_KINDS
                                for k in ecfg.layer_pattern)
        self._block_read_bytes = bs * (
            2 * ecfg.n_kv_heads * ecfg.resolved_head_dim
            * np.dtype(ecfg.compute_dtype).itemsize
            + np.dtype(np.int32).itemsize)
        self._experts = [
            _Expert(caches=cachelib.init_paged_caches(ecfg, L, pool, bs, M),
                    alloc=SlotAllocator(L), balloc=BlockAllocator(pool),
                    pending=deque(),
                    tok=np.zeros(L, np.int32), pos=np.zeros(L, np.int32),
                    active=np.zeros(L, bool), req=[None] * L,
                    block_tables=np.full((L, self.lane_blocks), -1, np.int32),
                    blocks=[[] for _ in range(L)],
                    keys=np.zeros((L, 2), np.uint32),
                    steps=np.zeros(L, np.int32),
                    temp=np.zeros(L, np.float32),
                    topk=np.zeros(L, np.int32),
                    topp=np.ones(L, np.float32))
            for _ in range(self.n_experts)]
        self.queue = RequestQueue()
        self.tick = 0
        self._uid = 0
        self._t0: float | None = None
        self.last_deltas: list[TokenDelta] = []
        (self._decode_fn, self._decode_greedy_fn, self._prefill_fn,
         self._score_fn, self._insert_fn, self._sample_fn) = \
            _jit_fns(ecfg, dcfg, rcfg, M)

    # -- warmup ------------------------------------------------------------
    def warmup(self, prompt_len: int | None = None, *,
               sampled: bool = True) -> None:
        """Compile every serving shape up front, off the timed path.

        Drives expert 0's admission/decode directly (bypassing routing,
        which could scatter a warmup batch across experts and leave the
        wider admission widths uncompiled) with synthetic requests at
        every power-of-two admission width.  The jitted functions are
        shared across experts, so one expert's shapes warm them all.
        ``prompt_len`` selects which prefill bucket to warm (defaults to
        the routing prefix length); call again for other buckets.
        ``sampled=False`` skips the second, sampled warmup pass — a
        greedy-only deployment then never compiles the sampler programs.
        """
        pl = min(prompt_len or self.eng.prefix_len, self.eng.max_len - 2)
        L = self.eng.lanes_per_expert
        if self._t0 is None:
            self._t0 = time.perf_counter()
        # router scoring always runs on (route_batch, prefix_len) chunks
        self._score_fn(self.router_params,
                       jnp.zeros((self.eng.route_batch, self.eng.prefix_len),
                                 jnp.int32))
        st = self._experts[0]
        # one greedy pass (argmax-only decode program) and one sampled pass
        # (mixed decode program + per-width sampler) so a live mix of
        # recipes hits only warm compiles
        for temp in (0.0, 1.0) if sampled else (0.0,):
            for k in sorted({min(1 << (b - 1).bit_length(), L)
                             for b in range(1, L + 1)}):
                for _ in range(k):
                    st.pending.append(Request(
                        uid=-1, prompt=np.zeros(pl, np.int32),
                        max_new_tokens=2,
                        sampling=SamplingParams(temperature=temp)))
                sink: list[Request] = []
                while st.pending or st.active.any():
                    self._admit(0, st, sink)
                    self._decode(0, st, sink)
        self._t0 = None
        self.last_deltas = []         # don't surface synthetic warmup tokens

    # -- request intake ----------------------------------------------------
    def submit(self, prompt, max_new_tokens: int,
               sampling: SamplingParams | None = None,
               stop_tokens=(),
               arrival_tick: int | None = None) -> Request:
        """Queue one generation request; returns its live Request record.

        ``sampling`` defaults to greedy; ``stop_tokens`` is any iterable
        of token ids that end the sequence early (the stop token is kept
        as the final emitted token, and the request's KV blocks are freed
        the same tick).
        """
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size == 0:
            raise ValueError("empty prompt")
        if len(prompt) < self.eng.prefix_len:
            raise ValueError(f"prompt shorter than routing prefix "
                             f"({len(prompt)} < {self.eng.prefix_len})")
        if len(prompt) + max_new_tokens > self.eng.max_len:
            raise ValueError(f"prompt {len(prompt)} + {max_new_tokens} new "
                             f"tokens exceeds lane budget {self.eng.max_len}")
        sampling = SamplingParams() if sampling is None else sampling
        if not isinstance(sampling, SamplingParams):
            raise TypeError(f"sampling must be a SamplingParams, "
                            f"got {type(sampling).__name__}")
        stop_tokens = frozenset(int(t) for t in stop_tokens)
        bad = [t for t in stop_tokens if not 0 <= t < self.ecfg.vocab_size]
        if bad:
            raise ValueError(f"stop tokens outside vocab: {sorted(bad)}")
        req = Request(uid=self._uid, prompt=prompt,
                      max_new_tokens=max_new_tokens,
                      sampling=sampling, stop_tokens=stop_tokens,
                      arrival_tick=self.tick if arrival_tick is None
                      else arrival_tick)
        self._uid += 1
        self.queue.push(req)
        return req

    # -- routing -----------------------------------------------------------
    def _route(self, reqs: list[Request]) -> None:
        """Score prefixes in padded fixed-width batches, argmax an expert."""
        pl, rb = self.eng.prefix_len, self.eng.route_batch
        prefixes = np.stack([r.prompt[:pl] for r in reqs])
        for i in range(0, len(reqs), rb):
            chunk = prefixes[i:i + rb]
            n = len(chunk)
            if n < rb:        # pad with copies of row 0; scores are per-row
                chunk = np.concatenate([chunk, np.broadcast_to(
                    chunk[:1], (rb - n,) + chunk.shape[1:])])
            scores = np.asarray(self._score_fn(self.router_params,
                                               jnp.asarray(chunk)))
            eids = np.asarray(asg.argmax_assignment(scores[:n]))
            for r, e in zip(reqs[i:i + n], eids):
                r.expert = int(e)
                r.route_tick = self.tick
                self._experts[r.expert].pending.append(r)

    # -- lane lifecycle ----------------------------------------------------
    def _bucket(self, n: int) -> int:
        if not self.pad_safe:
            return n
        return bucket_len(n, self.eng.min_prefill_bucket, self.eng.max_len)

    def _blocks_needed(self, req: Request) -> int:
        """Pool blocks covering every KV write the request will make.

        Positions written: 0..len(prompt)-1 by prefill, then one per fed-
        back token — the final emitted token is never written, so the
        highest position is len(prompt) + max_new - 2.
        """
        if not self.has_pool:
            return 0
        used = len(req.prompt) + req.max_new_tokens - 1
        return -(-used // self.eng.block_size)

    def _admit(self, e: int, st: _Expert, completed: list[Request]) -> None:
        """Drain pending requests into free lanes with one batched prefill.

        FIFO admission: take from the queue head while a decode lane and
        (full-attention archs) enough pool blocks are available.  All
        drained requests share one prefill call padded to the fixed lane
        width and the largest prompt bucket among them (non-pad-safe archs
        prefill one request at a time at exact length), then land in the
        caches via one jitted scatter.
        """
        batch: list[tuple[Request, int, np.ndarray]] = []
        while st.pending and st.alloc.n_free:
            req = st.pending[0]
            blocks = st.balloc.alloc_n(self._blocks_needed(req))
            if blocks is None:
                break                       # pool full: wait, keep FIFO order
            st.pending.popleft()
            slot = st.alloc.alloc()
            row = np.full(self.lane_blocks, -1, np.int32)
            row[:len(blocks)] = blocks
            st.blocks[slot] = blocks
            batch.append((req, slot, row))
        if not batch:
            return

        params = self.expert_params[e]
        L = self.eng.lanes_per_expert
        lens = np.array([len(r.prompt) for r, _, _ in batch])
        # per-request sampling operands for the first token (counter 0);
        # greedy requests keep a zero key and never touch the RNG
        keys = np.stack([np.zeros(2, np.uint32) if r.sampling.greedy
                         else samplib.request_key(r.sampling.seed, r.uid)
                         for r, _, _ in batch])
        temps = np.array([r.sampling.temperature for r, _, _ in batch],
                         np.float32)
        topks = np.array([r.sampling.top_k for r, _, _ in batch], np.int32)
        topps = np.array([r.sampling.top_p for r, _, _ in batch], np.float32)

        def first_tokens(logits, idx):
            """Sample token 0 for batch members ``idx`` from their prefill
            logits rows (padding rows ride along as greedy no-ops)."""
            n = len(idx)
            if not (temps[idx] > 0.0).any():          # all greedy: plain argmax
                return np.asarray(jnp.argmax(logits[:n], -1))
            pad = logits.shape[0] - n
            return np.asarray(self._sample_fn(
                logits,
                np.concatenate([keys[idx], np.zeros((pad, 2), np.uint32)]),
                np.zeros(n + pad, np.int32),
                np.concatenate([temps[idx], np.zeros(pad, np.float32)]),
                np.concatenate([topks[idx], np.zeros(pad, np.int32)]),
                np.concatenate([topps[idx], np.ones(pad, np.float32)])))[:n]

        if self.pad_safe:
            # one (K, bucket) prefill for the whole drain: K is the batch
            # width padded to the next power of two (bounded compile count,
            # no full-lane-width compute for single admissions), bucket =
            # the largest prompt bucket among the drained requests
            K = min(1 << (len(batch) - 1).bit_length(), L)
            bucket = max(self._bucket(int(n)) for n in lens)
            toks = np.zeros((K, bucket), np.int32)
            last = np.zeros(K, np.int32)
            for i, (req, _, _) in enumerate(batch):
                toks[i, :lens[i]] = req.prompt
                last[i] = lens[i] - 1
            logits, rcache = self._prefill_fn(params, jnp.asarray(toks),
                                              jnp.asarray(last))
            st.prefill_calls += 1
            rows = np.full((K, self.lane_blocks), -1, np.int32)
            slots = np.full(K, L, np.int32)       # out-of-range -> dropped
            true = np.zeros(K, np.int32)
            for i, (_, slot, row) in enumerate(batch):
                rows[i], slots[i], true[i] = row, slot, lens[i]
            st.caches = self._insert_fn(st.caches, rcache, rows, slots, true)
            firsts = first_tokens(logits, np.arange(len(batch)))
        else:
            firsts = np.zeros(len(batch), np.int64)
            for i, (req, slot, row) in enumerate(batch):
                logits, rcache = self._prefill_fn(
                    params, jnp.asarray(req.prompt[None]),
                    jnp.full((1,), lens[i] - 1, jnp.int32))
                st.prefill_calls += 1
                st.caches = self._insert_fn(
                    st.caches, rcache, row[None],
                    np.full(1, slot, np.int32),
                    np.full(1, lens[i], np.int32))
                firsts[i] = int(first_tokens(logits, np.array([i]))[0])

        for i, (req, slot, row) in enumerate(batch):
            first = int(firsts[i])
            req.tokens.append(first)
            req.admit_tick = self.tick
            req.t_first = time.perf_counter() - self._t0
            st.block_tables[slot] = row
            st.tok[slot], st.pos[slot] = first, lens[i]
            st.active[slot], st.req[slot] = True, req
            st.keys[slot] = keys[i]
            st.steps[slot] = 1
            st.temp[slot], st.topk[slot], st.topp[slot] = \
                temps[i], topks[i], topps[i]
            done = req.max_new_tokens == 1 or first in req.stop_tokens
            self.last_deltas.append(TokenDelta(
                request=req, token=first, index=0, done=done, tick=self.tick))
            if done:
                self._finish(st, slot, completed)

    def _finish(self, st: _Expert, slot: int, completed: list[Request]) -> None:
        """Retire a lane: stats, then free its KV blocks and slot NOW —
        the same tick — so the next admission can hand them out."""
        req = st.req[slot]
        req.finish_tick = self.tick
        req.finish_reason = ("stop_token" if req.tokens
                             and req.tokens[-1] in req.stop_tokens
                             else "length")
        req.t_done = time.perf_counter() - self._t0
        st.active[slot] = False
        st.req[slot] = None
        st.tok[slot] = st.pos[slot] = 0
        st.block_tables[slot] = -1
        st.keys[slot] = 0
        st.steps[slot] = 0
        st.temp[slot], st.topk[slot], st.topp[slot] = 0.0, 0, 1.0
        st.balloc.free_n(st.blocks[slot])
        st.blocks[slot] = []
        st.alloc.free(slot)
        st.n_served += 1
        completed.append(req)

    def _decode(self, e: int, st: _Expert, completed: list[Request]) -> None:
        if not st.active.any():
            return
        # inactive lanes decode at position -1: every KV slot is masked for
        # them and their writes are clamped to the pool scratch block (or
        # land as -1 markers in lane buffers), so a free lane can ride
        # along in the fixed-shape batch at zero correctness cost (its
        # sampler params sit at greedy defaults, so no RNG runs for it)
        pos = np.where(st.active, st.pos, -1).astype(np.int32)
        if (st.temp > 0.0).any():
            nxt, st.caches = self._decode_fn(
                self.expert_params[e], jnp.asarray(st.tok[:, None]),
                jnp.asarray(pos[:, None]), jnp.asarray(pos),
                jnp.asarray(st.block_tables), st.caches,
                st.keys, st.steps, st.temp, st.topk, st.topp)
        else:
            nxt, st.caches = self._decode_greedy_fn(
                self.expert_params[e], jnp.asarray(st.tok[:, None]),
                jnp.asarray(pos[:, None]), jnp.asarray(pos),
                jnp.asarray(st.block_tables), st.caches)
        st.decode_calls += 1
        st.occupied_lane_steps += int(st.active.sum())
        if self.has_pool:
            # bytes the paged kernel reads this tick (each active lane's
            # reserved blocks) vs what the old gathered (lanes, max_len)
            # view always read — the bench's measurable win
            live = sum(len(st.blocks[s]) for s in np.nonzero(st.active)[0])
            per_layer = self._block_read_bytes * self._pool_layers
            st.paged_read_bytes += live * per_layer
            st.gathered_read_bytes += \
                self.eng.lanes_per_expert * self.lane_blocks * per_layer
        nxt = np.asarray(nxt).astype(np.int32)
        for slot in np.nonzero(st.active)[0]:
            req = st.req[slot]
            tok = int(nxt[slot])
            req.tokens.append(tok)
            st.tok[slot] = tok
            st.pos[slot] += 1
            st.steps[slot] += 1
            done = (len(req.tokens) >= req.max_new_tokens
                    or tok in req.stop_tokens)
            self.last_deltas.append(TokenDelta(
                request=req, token=tok, index=len(req.tokens) - 1,
                done=done, tick=self.tick))
            if done:
                self._finish(st, int(slot), completed)

    # -- main loop ---------------------------------------------------------
    def step(self) -> list[Request]:
        """One scheduler tick: route arrivals, admit, decode every expert.

        Returns the requests that finished this tick; the individual
        tokens it emitted (one :class:`TokenDelta` per token, in emission
        order) are left in :attr:`last_deltas` until the next step.
        """
        if self._t0 is None:
            self._t0 = time.perf_counter()
        self.last_deltas = []
        arrived = self.queue.pop_arrived(self.tick)
        if arrived:
            self._route(arrived)
        completed: list[Request] = []
        for e, st in enumerate(self._experts):
            self._admit(e, st, completed)
            self._decode(e, st, completed)
        self.tick += 1
        return completed

    def _skip_idle_gap(self) -> None:
        """Fast-forward the tick counter over an empty simulated gap."""
        nxt = self.queue.next_arrival()
        if nxt is not None and nxt > self.tick and not any(
                st.pending or st.active.any() for st in self._experts):
            self.tick = nxt

    def stream(self):
        """Drive the engine, yielding one :class:`TokenDelta` per token.

        Deltas arrive in emission order (tick by tick, admissions before
        decodes); a request's final delta has ``done=True``, after which
        its lane and KV blocks are already recycled.  New requests may be
        submitted between deltas; the generator runs until the engine
        fully drains.
        """
        if self._t0 is None:
            self._t0 = time.perf_counter()
        while self.busy:
            self._skip_idle_gap()
            self.step()
            yield from self.last_deltas
        self._t0 = None               # fresh clock origin for a later run

    @property
    def busy(self) -> bool:
        return bool(len(self.queue)) or any(
            st.pending or st.active.any() for st in self._experts)

    def kv_bytes_per_expert(self) -> int:
        """Device bytes held by one expert's decode caches."""
        return cachelib.kv_cache_bytes(self._experts[0].caches)

    def run(self) -> dict:
        """Drive ticks until drained; returns requests + aggregate stats.

        Stats cover this run only (a warmup run on the same instance — which
        shares the jit caches — does not pollute a later timed run).  When
        some step() calls already ran, their time origin is kept so request
        timestamps stay on one clock; a fresh run() restarts the origin."""
        for st in self._experts:
            st.n_served = st.decode_calls = st.prefill_calls = 0
            st.occupied_lane_steps = 0
            st.paged_read_bytes = st.gathered_read_bytes = 0
            st.balloc.peak_in_use = st.balloc.n_in_use
        tick0 = self.tick
        t_start = time.perf_counter()
        if self._t0 is None:
            self._t0 = t_start
        completed: list[Request] = []
        n_steps = 0
        while self.busy:
            self._skip_idle_gap()     # jump empty gaps to the next arrival
            completed += self.step()
            n_steps += 1
        jax.block_until_ready([st.caches for st in self._experts])
        wall = time.perf_counter() - t_start
        self._t0 = None
        useful = sum(len(r.tokens) for r in completed)
        decode_calls = sum(st.decode_calls for st in self._experts)
        lane_steps = sum(st.occupied_lane_steps for st in self._experts)
        paged_rd = sum(st.paged_read_bytes for st in self._experts)
        gathered_rd = sum(st.gathered_read_bytes for st in self._experts)
        return {
            "requests": sorted(completed, key=lambda r: r.uid),
            "ticks": self.tick - tick0,    # simulated span (incl. skipped gaps)
            "steps": n_steps,              # scheduler iterations actually run
            "wall_s": wall,
            "useful_tokens": useful,
            "early_stops": sum(r.finish_reason == "stop_token"
                               for r in completed),
            "tokens_per_s": useful / max(wall, 1e-9),
            "mean_ttft_s": float(np.mean([r.t_first for r in completed]))
            if completed else 0.0,
            "occupancy": lane_steps / max(
                decode_calls * self.eng.lanes_per_expert, 1),
            "prefill_calls": sum(st.prefill_calls for st in self._experts),
            "kv_bytes_per_lane": self.kv_bytes_per_expert()
            // self.eng.lanes_per_expert,
            "decode_impl": self.decode_impl,
            "decode_read_bytes": {
                "paged": paged_rd,
                "gathered": gathered_rd,
                "paged_per_tick": paged_rd // max(decode_calls, 1),
                "gathered_per_tick": gathered_rd // max(decode_calls, 1),
            },
            "per_expert": {
                e: {"served": st.n_served, "decode_calls": st.decode_calls,
                    "prefills": st.prefill_calls,
                    "peak_blocks": st.balloc.peak_in_use}
                for e, st in enumerate(self._experts)},
        }
