"""Continuous-batching serving engine — thin facade over the layered stack.

The engine the rest of the repo talks to is a three-layer system (see
``src/repro/serving/README.md``), mirroring the paper's premise that the
router's prefix scores are the only cross-expert traffic (§1, App. A.4):

  * :mod:`repro.serving.frontend`      — router scoring, uid assignment,
    delta reassembly, ``stream()``/``run()`` aggregation; drives experts
    without a barrier (each ticks on its own clock whenever it has work);
  * :mod:`repro.serving.expert_server` — one self-contained
    :class:`~repro.serving.expert_server.ExpertServer` per expert:
    admission, batched prefill, the jitted decode+sample step, the paged
    block-pool KV cache, early-stop lane recycling;
  * :mod:`repro.serving.transport`     — the serializable message
    boundary between them: in-process loopback (default) or one spawned
    OS process per expert (``EngineConfig(transport="process")``), the
    local-machine proof of the multi-host deployment story.

:class:`MixtureServeEngine` keeps the historical API —
``submit`` / ``step`` / ``stream`` / ``run`` / ``warmup`` plus the
``_experts`` introspection the tests use — while the implementation
lives in the layers above.  The bitwise contract survives the split by
construction: tokens are keyed by
``fold_in(fold_in(PRNGKey(seed), uid), step)`` and lane-placement-
invariant, so per-expert async ticking cannot change any request's
stream vs :mod:`repro.serving.baseline`, greedy or sampled — the fuzz
oracles in ``tests/test_serving.py`` hold on every transport.
"""
from __future__ import annotations

from repro.serving.expert_server import (EngineConfig, ExpertServer,
                                         PAD_SAFE_KINDS, bucket_len,
                                         resolve_shapes)
from repro.serving.frontend import ServeFrontend, TokenDelta
from repro.serving.transport import (LoopbackTransport, ProcessTransport,
                                     RequestMsg, StatsMsg, TokenDeltaMsg,
                                     Transport)

__all__ = ["EngineConfig", "ExpertServer", "LoopbackTransport",
           "MixtureServeEngine", "PAD_SAFE_KINDS", "ProcessTransport",
           "RequestMsg", "ServeFrontend", "StatsMsg", "TokenDelta",
           "TokenDeltaMsg", "Transport", "bucket_len", "resolve_shapes"]


class MixtureServeEngine(ServeFrontend):
    """Queue + router + per-expert continuous decode batches.

    A pure facade: everything is inherited from
    :class:`repro.serving.frontend.ServeFrontend` — this class only
    pins the historical name and import path.
    """
