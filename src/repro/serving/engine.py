"""Continuous-batching serving engine — thin facade over the layered stack.

The engine the rest of the repo talks to is a three-layer system (see
``src/repro/serving/README.md``), mirroring the paper's premise that the
router's prefix scores are the only cross-expert traffic (§1, App. A.4):

  * :mod:`repro.serving.frontend`      — router scoring, uid assignment,
    delta reassembly, ``stream()``/``run()`` aggregation; drives experts
    without a barrier (each ticks on its own clock whenever it has work);
  * :mod:`repro.serving.expert_server` — one self-contained
    :class:`~repro.serving.expert_server.ExpertServer` per expert:
    admission, batched prefill, the jitted decode+sample step, the paged
    block-pool KV cache, early-stop lane recycling;
  * :mod:`repro.serving.transport`     — the serializable message
    boundary between them: in-process loopback (default) or one spawned
    OS process per expert (``EngineConfig(transport="process")``), the
    local-machine proof of the multi-host deployment story.

:class:`MixtureServeEngine` is the **deprecated** historical name for
:class:`repro.serving.frontend.ServeFrontend` — constructing it emits a
``DeprecationWarning`` and everything else is inherited unchanged.  New
code imports ``ServeFrontend`` (plus ``EngineConfig``, ``Request``,
``SamplingParams``) straight from :mod:`repro.serving`; this module
only keeps the old import paths alive.  The bitwise contract survives
by construction: tokens are keyed by
``fold_in(fold_in(PRNGKey(seed), uid), step)`` and lane-placement-
invariant, so per-expert async ticking cannot change any request's
stream vs :mod:`repro.serving.baseline`, greedy or sampled — the fuzz
oracles in ``tests/test_serving.py`` hold on every transport.
"""
from __future__ import annotations

import warnings

from repro.serving.expert_server import (EngineConfig, ExpertServer,
                                         PAD_SAFE_KINDS, bucket_len,
                                         resolve_shapes)
from repro.serving.frontend import ServeFrontend, TokenDelta
from repro.serving.transport import (LoopbackTransport, ProcessTransport,
                                     RequestMsg, StatsMsg, TokenDeltaMsg,
                                     Transport)

__all__ = ["EngineConfig", "ExpertServer", "LoopbackTransport",
           "MixtureServeEngine", "PAD_SAFE_KINDS", "ProcessTransport",
           "RequestMsg", "ServeFrontend", "StatsMsg", "TokenDelta",
           "TokenDeltaMsg", "Transport", "bucket_len", "resolve_shapes"]


class MixtureServeEngine(ServeFrontend):
    """Deprecated alias of :class:`repro.serving.frontend.ServeFrontend`.

    A pure facade: everything is inherited — this class only pins the
    historical name and import path, and warns once per construction so
    downstream callers migrate to ``ServeFrontend``.
    """

    def __init__(self, *args, **kwargs):
        warnings.warn(
            "MixtureServeEngine is deprecated; construct "
            "repro.serving.ServeFrontend instead (same signature — it "
            "also accepts the replicas= map)",
            DeprecationWarning, stacklevel=2)
        super().__init__(*args, **kwargs)
