"""Slotted lane caches for continuous batching.

A *lane* is one row of a fixed-shape decode cache pytree (leading axes
``(rep, lanes, ...)`` — the same layout :func:`repro.models.model.cache_specs`
describes, with ``lanes`` as the batch axis).  The serving engine keeps one
lane pytree per expert and mutates it with three jit-stable operations:

  * :func:`init_lane_caches` — allocate empty lanes (``pos`` leaves = -1,
    i.e. every KV slot is masked);
  * :func:`insert_request`  — copy a freshly prefilled single-request cache
    into one lane, masking any prompt-padding slots back to empty;
  * :func:`release_slots`   — evict finished lanes by marking their ``pos``
    rows empty so the slots can be reused by the free list.

All three are shape-stable in ``lanes``/``max_len`` so the per-expert
``decode_step`` jit-compiles exactly once and keeps serving as requests
come and go mid-decode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import model as modellib


def _is_pos_leaf(path) -> bool:
    """True for attention-cache ``pos`` leaves (slot-position bookkeeping)."""
    last = path[-1]
    return isinstance(last, jax.tree_util.DictKey) and last.key == "pos"


def init_lane_caches(cfg, lanes: int, max_len: int):
    """Empty decode caches for ``lanes`` slots of budget ``max_len`` tokens."""
    specs = modellib.cache_specs(cfg, lanes, max_len)

    def alloc(path, s):
        if _is_pos_leaf(path):
            return jnp.full(s.shape, -1, s.dtype)
        return jnp.zeros(s.shape, s.dtype)

    return jax.tree_util.tree_map_with_path(alloc, specs)


def insert_request(lane_caches, request_cache, slot, true_len):
    """Copy a prefilled batch-of-1 cache into lane ``slot``.

    ``request_cache`` leaves are ``(rep, 1, ...)`` from a prefill with
    ``cache_len`` equal to the lane budget, so shapes line up with one lane
    row.  ``true_len`` is the un-padded prompt length: any KV slot the
    padded prefill wrote with position >= true_len is masked back to -1 so
    bucketed (padded) prompts never leak pad keys into decode attention.

    ``slot``/``true_len`` are traced, so admission never recompiles.
    """
    def ins(path, lane, req):
        row = req[:, 0]
        if _is_pos_leaf(path):
            row = jnp.where((row >= 0) & (row < true_len), row, -1)
        return lane.at[:, slot].set(row)

    return jax.tree_util.tree_map_with_path(ins, lane_caches, request_cache)


def release_slots(lane_caches, freed_mask):
    """Evict lanes where ``freed_mask`` (bool (lanes,)) is True.

    Only position bookkeeping needs clearing — k/v payloads of a freed lane
    are unreachable once every ``pos`` entry is -1 (decode attention masks
    them), and :func:`insert_request` fully overwrites the lane on reuse.
    Recurrent-state leaves are left untouched for the same reason: the
    next admission replaces them wholesale.
    """
    def rel(path, lane):
        if _is_pos_leaf(path):
            return jnp.where(freed_mask[None, :, None], -1, lane)
        return lane

    return jax.tree_util.tree_map_with_path(rel, lane_caches)
