"""Paged block-pool KV caches for continuous batching.

Device-side layout for the serving engine.  Per expert, each
full-attention layer owns a shared *block pool* — k/v leaves shaped
``(rep, n_blocks + 1, block_size, Hkv, hd)`` and a ``pos`` leaf
``(rep, n_blocks + 1, block_size)`` — instead of one dense
``(lanes, max_len)`` slab per lane.  A lane's KV lives in whatever pool
blocks the host-side :class:`repro.serving.scheduler.BlockAllocator`
reserved for it; the per-lane *block table* (``(lanes, max_len //
block_size)`` int32, -1 = unreserved) maps position range
``[i*block_size, (i+1)*block_size)`` to pool block ``table[i]``.  Row
``n_blocks`` of every pool is a scratch block: writes whose table entry
is -1 (inactive lanes, unreserved rows) are clamped there and reads mask
its positions back to -1, so every gather/scatter stays shape-stable and
the per-expert jitted ``decode_step`` compiles exactly once.

Sliding-window layers keep their per-lane rotating buffer (already
O(window) — paging it saves nothing) and recurrent (SSM/xLSTM) layers
their O(1) per-lane state; only full-attention KV is paged.

Three operations mutate the tree:

  * :func:`init_paged_caches` — allocate empty pools/lanes (``pos``
    leaves = -1, i.e. every KV slot is masked);
  * :func:`insert_requests`  — one jitted scatter copying a *batch* of k
    freshly prefilled caches into their reserved blocks (full-attention
    leaves) and lane rows (everything else), masking prompt-padding
    positions back to -1.  Rows padded up to the fixed batch width point
    at the scratch block / an out-of-range lane slot, so admission of
    1..lanes requests reuses one compiled scatter;
  * eviction is free: a finished lane's blocks are simply returned to the
    host free list.  No pool block is reachable except through a live
    block table, and an insert overwrites a reused block's every slot
    (the prefill cache spans the full ``max_len``), so no device-side
    release scatter is needed.

The paged read path gathers a lane's blocks back into dense-slab slot
order (position p lands at gathered slot p), so engine decode stays
bit-identical to the dense baseline — the fuzz suite in
``tests/test_serving.py`` locks that down.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import base as cfglib
from repro.models import model as modellib

POOL_KINDS = (cfglib.ATTN, cfglib.ATTN_SHARED)


def _is_pos_leaf(path) -> bool:
    """True for attention ``pos`` leaves (slot-position bookkeeping)."""
    last = path[-1]
    return isinstance(last, jax.tree_util.DictKey) and last.key == "pos"


def _kind_of(cfg, path) -> str:
    """Block kind owning a cache leaf, recovered from its tree path.

    Cache trees are ``tuple(stages) -> tuple(unit positions) -> dict``,
    so ``path = (SequenceKey(stage), SequenceKey(unit_pos), DictKey(...))``
    indexes straight into ``cfg.resolved_stages``.
    """
    return cfg.resolved_stages[path[0].idx][0][path[1].idx]


def _is_pool_leaf(cfg, path) -> bool:
    return _kind_of(cfg, path) in POOL_KINDS


def init_paged_caches(cfg, lanes: int, n_blocks: int, block_size: int,
                      max_len: int):
    """Empty paged caches: full-attn block pools + per-lane other state."""
    specs = modellib.paged_cache_specs(cfg, lanes, n_blocks, block_size,
                                       max_len)

    def alloc(path, s):
        if _is_pos_leaf(path):
            return jnp.full(s.shape, -1, s.dtype)
        return jnp.zeros(s.shape, s.dtype)

    return jax.tree_util.tree_map_with_path(alloc, specs)


def insert_requests(cfg, caches, request_caches, block_rows, slots,
                    true_lens):
    """Scatter a prefilled batch of K requests into pools and lanes.

    ``request_caches`` leaves are ``(rep, K, ...)`` from one prefill with
    ``cache_len == max_len``; K is a fixed batch width, so rows beyond
    the really-admitted requests are padding.  ``block_rows`` is
    ``(K, max_len // block_size)`` int32 — each request's reserved pool
    blocks, -1 where unreserved (trailing rows past its reservation, and
    every entry of a padding row).  ``slots`` is ``(K,)`` int32 lane ids,
    with out-of-range values (>= lanes) on padding rows.  ``true_lens``
    ``(K,)`` are un-padded prompt lengths.

    Full-attention leaves: the request cache spans the whole ``max_len``
    budget (data at positions < true_len, -1 markers beyond), so writing
    all its ``max_len/block_size`` block-sized pieces through the block
    row both installs the prompt KV and clears any stale positions a
    previous tenant left in the reserved growth blocks; unreserved pieces
    land in the scratch block.  Everything else scatters into lane rows,
    with out-of-range padding slots dropped.

    All index operands are traced, so admission never recompiles.
    """
    block_rows = jnp.asarray(block_rows, jnp.int32)
    slots = jnp.asarray(slots, jnp.int32)
    true_lens = jnp.asarray(true_lens, jnp.int32)

    def ins(path, pool, req):
        if _is_pool_leaf(cfg, path):
            rep, K, M = req.shape[:3]
            bs = pool.shape[2]
            scratch = pool.shape[1] - 1
            if _is_pos_leaf(path):
                req = jnp.where((req >= 0) & (req < true_lens[None, :, None]),
                                req, -1)
            vals = req.reshape((rep, K * (M // bs), bs) + req.shape[3:])
            ids = jnp.where(block_rows >= 0, block_rows,
                            scratch).reshape(-1)
            return pool.at[:, ids].set(vals)
        row = req
        if _is_pos_leaf(path):
            row = jnp.where((row >= 0) & (row < true_lens[None, :, None]),
                            row, -1)
        return pool.at[:, slots].set(row, mode="drop")

    return jax.tree_util.tree_map_with_path(ins, caches, request_caches)


def clear_block_pos(cfg, caches, block_ids):
    """Reset the ``pos`` rows of the given pool blocks to -1 (masked).

    Used by the prefix-sharing hit path: a hit lane's *novel* blocks are
    filled through the decode scatter (one position per step) rather
    than :func:`insert_requests` (which overwrites a reused block's
    every slot), so a previous tenant's stale positions must be masked
    out before the first read.  ``block_ids`` is a fixed-width int32
    vector; pad unused entries with the scratch row index (``n_blocks``)
    — scratch positions are -1 already, so re-clearing them is a no-op.
    Only ``pos`` leaves change; k/v payloads are left as garbage behind
    the mask, exactly like a fresh pool.
    """
    block_ids = jnp.asarray(block_ids, jnp.int32)

    def clr(path, leaf):
        if _is_pool_leaf(cfg, path) and _is_pos_leaf(path):
            return leaf.at[:, block_ids].set(-1)
        return leaf

    return jax.tree_util.tree_map_with_path(clr, caches)


def kv_cache_bytes(caches) -> int:
    """Total bytes held by a cache pytree (pools + lane state)."""
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(caches))
