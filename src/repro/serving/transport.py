"""Message protocol + transports between the router frontend and experts.

The paper's serving story (App. A.4) is that experts never share state:
the router's prefix scores pick ONE expert per request and everything
after that is private to it.  This module is that boundary made
explicit.  Three serializable message types are the ONLY things that
cross it:

  * :class:`RequestMsg`   — frontend -> expert: one routed request;
  * :class:`TokenDeltaMsg` — expert -> frontend: one emitted token
    (with admission / finish metadata riding on the first / last one);
  * :class:`StatsMsg`     — expert -> frontend: a counter snapshot.

A :class:`Transport` carries them to E expert servers and knows nothing
about models, caches, or routing:

  * :class:`LoopbackTransport` (default) holds the
    :class:`repro.serving.expert_server.ExpertServer` objects in
    process — messages pass by reference, zero copies, and the jitted
    programs are shared across servers through the config-keyed compile
    cache;
  * :class:`ProcessTransport` spawns ONE OS process per expert, each
    holding its own params and KV pool; pickled messages over pipes are
    the only cross-process traffic.  This is the local-machine proof of
    the multi-host deployment: replace the pipes with RPC and each
    expert's lanes can live on its own pod, the router score matrix
    being the only thing on the wire.

Both transports tick experts independently — ``tick(e)`` steps exactly
one server on its own clock, and ``tick_many`` lets the process backend
overlap expert compute across processes (send every tick, then collect),
so a hot expert never waits on an idle one.
"""
from __future__ import annotations

import dataclasses
import multiprocessing as mp
import traceback

import numpy as np

from repro.serving.sampling import SamplingParams


@dataclasses.dataclass(frozen=True)
class RequestMsg:
    """Everything an expert server needs to serve one routed request.

    ``enqueue_tick`` is the sender's clock when the request was handed
    over; the receiving server pulls its own clock forward to it (never
    backward) so queue-wait accounting stays on one timeline.
    """
    uid: int
    prompt: np.ndarray            # (L,) int32
    max_new_tokens: int
    sampling: SamplingParams
    stop_tokens: frozenset
    enqueue_tick: int


@dataclasses.dataclass(frozen=True)
class TokenDeltaMsg:
    """One emitted token, in this expert's local clock.

    ``admit_tick`` is set on a request's first delta (index 0) and
    ``finish_reason`` on its last (``done=True``); the frontend
    reassembles these into the live ``Request`` record it handed the
    caller.
    """
    uid: int
    token: int
    index: int                    # position within the request's tokens
    done: bool                    # True on the request's final token
    tick: int                     # expert-local tick that emitted it
    admit_tick: int = -1          # set when index == 0
    finish_reason: str = ""       # "stop_token" | "length" when done


@dataclasses.dataclass(frozen=True)
class StatsMsg:
    """Counter snapshot of one expert server (see ExpertServer.stats)."""
    n_served: int
    decode_calls: int
    prefill_calls: int
    occupied_lane_steps: int
    queue_wait_ticks: int
    paged_read_bytes: int
    gathered_read_bytes: int
    peak_blocks: int


@dataclasses.dataclass(frozen=True)
class _RemoteError:
    """A worker's exception, shipped back instead of a reply."""
    trace: str


class Transport:
    """Carries messages between the frontend and ``n_experts`` servers."""

    n_experts: int

    def enqueue(self, e: int, msg: RequestMsg) -> None:
        raise NotImplementedError

    def tick(self, e: int) -> list[TokenDeltaMsg]:
        """Step expert ``e`` once on its own clock."""
        raise NotImplementedError

    def tick_many(self, experts) -> list[tuple[int, list[TokenDeltaMsg]]]:
        """Tick several experts; results in the given expert order.

        Base implementation steps them one after another; backends with
        real parallelism (one process per expert) overlap the work.
        """
        return [(e, self.tick(e)) for e in experts]

    def busy(self, e: int) -> bool:
        raise NotImplementedError

    @property
    def any_busy(self) -> bool:
        return any(self.busy(e) for e in range(self.n_experts))

    def stats(self, e: int) -> StatsMsg:
        raise NotImplementedError

    def reset_stats(self) -> None:
        raise NotImplementedError

    def warmup(self, prompt_len, sampled: bool) -> None:
        raise NotImplementedError

    def sync(self) -> None:
        """Block until every expert's queued device work has landed."""
        raise NotImplementedError

    def close(self) -> None:
        """Release backend resources (processes/pipes); idempotent."""


class LoopbackTransport(Transport):
    """In-process transport: the default, zero-copy path.

    Holds the ``ExpertServer`` objects directly; messages pass by
    reference (nothing is pickled) and ``busy`` reuses the server's own
    idle predicate.
    """

    def __init__(self, servers):
        self.servers = list(servers)
        self.n_experts = len(self.servers)

    def enqueue(self, e, msg):
        self.servers[e].enqueue(msg)

    def tick(self, e):
        return self.servers[e].tick()

    def busy(self, e):
        return self.servers[e].busy

    def stats(self, e):
        return self.servers[e].stats()

    def reset_stats(self):
        for s in self.servers:
            s.reset_stats()

    def warmup(self, prompt_len, sampled):
        # the jitted programs are shared across in-process servers via the
        # config-keyed compile cache: one server's shapes warm them all
        self.servers[0].warmup(prompt_len, sampled=sampled)

    def sync(self):
        for s in self.servers:
            s.sync()


def _serve_expert(conn, ecfg, eng, host_params) -> None:
    """Worker loop: one ExpertServer in its own process.

    Runs until a ``close`` op (or EOF).  Imports live inside the
    function: under the ``spawn`` start method this module is re-imported
    in a fresh interpreter, and jax must initialize per process.
    """
    import jax

    from repro.serving.expert_server import ExpertServer

    try:
        params = jax.device_put(host_params)   # once, not per jit call
        server = ExpertServer(ecfg, params, eng)
        while True:
            try:
                op, args = conn.recv()
            except EOFError:
                return                          # parent went away
            if op == "enqueue":
                server.enqueue(args)            # pipe order == FIFO order
            elif op == "tick":
                conn.send(server.tick())
            elif op == "warmup":
                server.warmup(args[0], sampled=args[1])
                conn.send(None)
            elif op == "stats":
                conn.send(server.stats())
            elif op == "reset_stats":
                server.reset_stats()
            elif op == "sync":
                server.sync()
                conn.send(None)
            elif op == "close":
                return
            else:
                raise ValueError(f"unknown transport op {op!r}")
    except Exception:                           # ship the traceback home
        try:
            conn.send(_RemoteError(traceback.format_exc()))
        except OSError:
            pass
        raise


class ProcessTransport(Transport):
    """One spawned OS process per expert: params + KV pool live there.

    The local-machine proof of the multi-host story — the only bytes
    that ever cross a process boundary are pickled ``RequestMsg`` /
    ``TokenDeltaMsg`` / ``StatsMsg`` records (and the one-time param
    shipment at spawn).  ``busy`` is tracked parent-side from the
    message flow itself (enqueues minus ``done`` deltas), so the
    scheduler never round-trips just to ask who has work.

    Ops that expect a reply are pipelined by ``tick_many`` / ``warmup``
    / ``sync``: send to every expert first, then collect — E experts
    really do compute concurrently.

    The usual ``multiprocessing`` spawn rule applies: the parent's main
    module must be importable by path (a script piped via stdin cannot
    spawn workers — they die at startup, surfaced here as
    ``RuntimeError: expert e worker exited``).  A worker that dies for
    any reason (OOM kill, segfault) is reported the same way, with its
    exit code; Python-level worker exceptions additionally ship their
    traceback home.
    """

    def __init__(self, ecfg, eng, expert_params):
        import jax                               # parent-side host transfer

        self.n_experts = len(expert_params)
        self._outstanding = [0] * self.n_experts
        self._broken = False
        self._closed = False
        ctx = mp.get_context("spawn")            # never fork a live jax
        self._procs, self._conns = [], []
        for p in expert_params:
            host = jax.tree_util.tree_map(np.asarray, p)
            parent, child = ctx.Pipe()
            proc = ctx.Process(target=_serve_expert,
                               args=(child, ecfg, eng, host), daemon=True)
            proc.start()
            child.close()
            self._procs.append(proc)
            self._conns.append(parent)

    def _dead(self, e) -> RuntimeError:
        """A worker vanished without a Python traceback (OOM kill,
        segfault): name the expert and its exit code, not just EOF."""
        self._procs[e].join(timeout=1)
        return RuntimeError(
            f"expert {e} worker exited "
            f"(exitcode={self._procs[e].exitcode})")

    def _check(self):
        if self._closed:
            raise RuntimeError("ProcessTransport is closed; build a fresh "
                               "engine to serve again")
        # after any worker failure the pipes may hold replies belonging
        # to an aborted batched op — fail every later op loudly instead
        # of handing a stale reply to the wrong caller
        if self._broken:
            raise RuntimeError("ProcessTransport is broken after a worker "
                               "failure; build a fresh engine")

    def _send(self, e, op, args):
        self._check()
        try:
            self._conns[e].send((op, args))
        except (BrokenPipeError, OSError):
            self._broken = True
            raise self._dead(e) from None

    def _recv(self, e):
        self._check()
        try:
            out = self._conns[e].recv()
        except EOFError:
            self._broken = True
            raise self._dead(e) from None
        if isinstance(out, _RemoteError):
            self._broken = True
            raise RuntimeError(f"expert {e} worker failed:\n{out.trace}")
        return out

    def enqueue(self, e, msg):
        self._outstanding[e] += 1
        self._send(e, "enqueue", msg)            # fire-and-forget

    def _absorb(self, e, deltas):
        self._outstanding[e] -= sum(d.done for d in deltas)
        return deltas

    def tick(self, e):
        self._send(e, "tick", None)
        return self._absorb(e, self._recv(e))

    def tick_many(self, experts):
        experts = list(experts)
        for e in experts:                        # overlap expert compute
            self._send(e, "tick", None)
        return [(e, self._absorb(e, self._recv(e))) for e in experts]

    def busy(self, e):
        # a request is outstanding exactly from enqueue until its done
        # delta — equivalent to the server's pending-or-active predicate,
        # but known parent-side without an RPC
        return self._outstanding[e] > 0

    def stats(self, e):
        self._send(e, "stats", None)
        return self._recv(e)

    def reset_stats(self):
        for e in range(self.n_experts):
            self._send(e, "reset_stats", None)

    def warmup(self, prompt_len, sampled):
        # per-process jit caches: every expert warms itself, concurrently
        for e in range(self.n_experts):
            self._send(e, "warmup", (prompt_len, sampled))
        for e in range(self.n_experts):
            self._recv(e)

    def sync(self):
        for e in range(self.n_experts):
            self._send(e, "sync", None)
        for e in range(self.n_experts):
            self._recv(e)

    def close(self):
        self._closed = True
        for c in self._conns:
            try:
                c.send(("close", None))
                c.close()
            except OSError:
                pass
        self._conns = []
        for p in self._procs:
            p.join(timeout=30)
            if p.is_alive():
                p.terminate()
                p.join(timeout=5)    # reap: no zombie per stuck worker
        self._procs = []

    def __del__(self):
        try:
            self.close()
        except Exception:
            pass
