"""Message protocol + transports between the router frontend and experts.

The paper's serving story (App. A.4) is that experts never share state:
the router's prefix scores pick ONE expert per request and everything
after that is private to it.  This module is that boundary made
explicit.  Three serializable message types are the ONLY things that
cross it:

  * :class:`RequestMsg`   — frontend -> expert: one routed request;
  * :class:`TokenDeltaMsg` — expert -> frontend: one emitted token
    (with admission / finish metadata riding on the first / last one);
  * :class:`StatsMsg`     — expert -> frontend: a counter snapshot.

Every message carries the wire protocol ``version`` (module constant
:data:`WIRE_VERSION`) for wire compat, but the build pairing is
validated **once per connection**, never per message: transports
``check_version`` each caller-built :class:`RequestMsg` at ``enqueue``
(the boundary where a foreign object can enter), and a worker proves
its build exactly once — the process backend ships a one-time ``hello``
at boot, the TCP backend (:mod:`repro.serving.net`) runs a handshake at
connect.  The per-delta hot path carries no checks: two ends that
passed the handshake cannot emit mismatched deltas.

A :class:`Transport` carries them to N expert *servers* and knows
nothing about models, caches, or routing.  A server slot is just an
index — the frontend may map several slots to replicas of one hot
expert (the paper's no-talk premise makes replication free: replicas
share nothing and never know about each other), so transports speak
slots, not experts.  **Slot membership is dynamic**: the autoscaler
(:mod:`repro.serving.autoscale`) grows the table with ``add_slot`` and
retires members with ``remove_slot`` mid-serve.  Slot indices grow
monotonically and are never reused — a removed slot leaves a permanent
hole, so a stale index can never silently address a new replica;
``slots()`` enumerates the live members.

  * :class:`LoopbackTransport` (default) holds the
    :class:`repro.serving.expert_server.ExpertServer` objects in
    process — messages pass by reference, zero copies, and the jitted
    programs are shared across servers through the config-keyed compile
    cache (which is also why ``add_slot`` is instant here: a new
    replica reuses the compiled programs);
  * :class:`ProcessTransport` spawns ONE OS process per slot, each
    holding its own params and KV pool; pickled messages over pipes are
    the only cross-process traffic.  ``add_slot`` spawns cold — the new
    worker imports jax and compiles off-path while serving continues;
    ``warmup_slot``/``slot_ready`` let the frontend admit it only once
    its programs are warm.  This is the local-machine proof of the
    multi-host deployment: replace the pipes with RPC and each expert's
    lanes can live on its own pod, the router score matrix being the
    only thing on the wire.

Both transports tick experts independently — ``tick(s)`` steps exactly
one server on its own clock, and ``tick_many`` lets the process backend
overlap expert compute across processes (send every tick, then collect),
so a hot expert never waits on an idle one.

Scale-down quiesce rides on one extra op: ``recall(s)`` drains server
``s``'s queued-but-unadmitted requests and hands their uids back, so
the frontend can re-route them to surviving replicas.  The sender-side
``load`` tracker decrements by the recalled count — without that, a
retired replica's queued requests would leak load forever and skew
least-loaded admission (regression-tested in
``tests/test_serving_autoscale.py``).
"""
from __future__ import annotations

import dataclasses
import multiprocessing as mp
import threading
import traceback

import numpy as np

from repro.serving.sampling import SamplingParams

# Bump on ANY change to the message dataclasses below.  Each message
# carries it, transports check it at enqueue, and every connection-time
# handshake (TCP hello, process boot hello) pins it — two serving builds
# must be upgraded together, never mixed silently.
# v2: StatsMsg grew prefix_hit_blocks / prefill_tokens_saved /
# cached_blocks (prefix-sharing KV cache).
# (Autoscaling added the `recall` op but no dataclass change — ops are
# covered by the handshake's build pairing, so v2 stands.)
# v3: StatsMsg grew prefill_write_fused_bytes / prefill_write_slab_bytes /
# epilogue_logits_bytes (fused paged prefill + sampling epilogue).
WIRE_VERSION = 3


def check_version(msg):
    """Reject a wire message from a different protocol build, loudly."""
    v = getattr(msg, "version", None)
    if v != WIRE_VERSION:
        raise RuntimeError(
            f"wire protocol mismatch: {type(msg).__name__} carries "
            f"version {v!r} but this build speaks v{WIRE_VERSION} — "
            f"frontend and expert servers must run the same serving build")
    return msg


@dataclasses.dataclass(frozen=True)
class RequestMsg:
    """Everything an expert server needs to serve one routed request.

    ``enqueue_tick`` is the sender's clock when the request was handed
    over; the receiving server pulls its own clock forward to it (never
    backward) so queue-wait accounting stays on one timeline.
    """
    uid: int
    prompt: np.ndarray            # (L,) int32
    max_new_tokens: int
    sampling: SamplingParams
    stop_tokens: frozenset
    enqueue_tick: int
    version: int = WIRE_VERSION


@dataclasses.dataclass(frozen=True)
class TokenDeltaMsg:
    """One emitted token, in this expert's local clock.

    ``admit_tick`` is set on a request's first delta (index 0) and
    ``finish_reason`` on its last (``done=True``); the frontend
    reassembles these into the live ``Request`` record it handed the
    caller.
    """
    uid: int
    token: int
    index: int                    # position within the request's tokens
    done: bool                    # True on the request's final token
    tick: int                     # expert-local tick that emitted it
    admit_tick: int = -1          # set when index == 0
    finish_reason: str = ""       # "stop_token" | "length" when done
    version: int = WIRE_VERSION


@dataclasses.dataclass(frozen=True)
class StatsMsg:
    """Counter snapshot of one expert server (see ExpertServer.stats).

    ``pending`` + ``active_lanes`` are the server's instantaneous load —
    queued requests plus occupied decode lanes — the quantity the
    frontend's least-loaded replica admission minimizes (it tracks the
    same number sender-side from the message flow; ``StatsMsg`` is the
    ground truth the tests check that tracker against).
    """
    n_served: int
    decode_calls: int
    prefill_calls: int
    occupied_lane_steps: int
    queue_wait_ticks: int
    paged_read_bytes: int
    gathered_read_bytes: int
    peak_blocks: int
    pending: int = 0              # queued, not yet in a lane
    active_lanes: int = 0         # lanes holding a request (decoding or
                                  # still replaying a novel prompt suffix)
    prefix_hit_blocks: int = 0    # KV blocks served from the prefix cache
    prefill_tokens_saved: int = 0  # prompt tokens never (re)prefilled
    cached_blocks: int = 0        # blocks the prefix cache holds right now
    prefill_write_fused_bytes: int = 0   # admission KV write traffic priced
    prefill_write_slab_bytes: int = 0    # both ways (fused vs slab+scatter)
    epilogue_logits_bytes: int = 0  # (lanes, vocab) logits round-trips the
                                    # unfused decode epilogue materialized
    version: int = WIRE_VERSION


@dataclasses.dataclass(frozen=True)
class _RemoteError:
    """A worker's exception, shipped back instead of a reply."""
    trace: str


class Transport:
    """Carries messages between the frontend and its server slots.

    Servers are addressed by a flat slot index; the frontend owns the
    (expert, replica) -> slot mapping (a
    :class:`repro.serving.placement.PlacementMap`).  ``labels`` name
    each slot for error reports (e.g. ``"expert 1 replica 0"``) so a
    dead worker is surfaced with its identity, not a bare index.

    ``slots()`` is the live membership; ``add_slot``/``remove_slot``
    change it mid-serve (indices are never reused).  ``n_servers``
    counts the live members.
    """

    labels: list

    def slots(self) -> list[int]:
        """Live slot indices, ascending (holes from removals excluded)."""
        raise NotImplementedError

    @property
    def n_servers(self) -> int:
        return len(self.slots())

    @property
    def n_experts(self) -> int:
        """Historical alias from before replication: slots, not experts."""
        return self.n_servers

    def add_slot(self, target, label: str) -> int:
        """Grow the table with one server; returns its (new) slot index.

        ``target`` is backend-specific: an ``ExpertServer`` (loopback),
        a param tree to spawn with (process), or a ``(host, port)``
        address (tcp).  The slot is live immediately for wire purposes;
        use ``warmup_slot``/``slot_ready`` before routing latency-
        sensitive traffic at a cold backend.
        """
        raise NotImplementedError

    def remove_slot(self, s: int) -> None:
        """Retire slot ``s`` for good: release its backend resources and
        leave a permanent hole at the index.  The caller must have
        drained it first (``recall`` + let its lanes finish); idempotent.
        """
        raise NotImplementedError

    def recall(self, s: int) -> list[int]:
        """Drain slot ``s``'s queued-but-unadmitted requests; returns
        their uids for the frontend to re-route.  Requests already in a
        decode lane are NOT recalled — they finish where they are (their
        token streams are position-independent anyway).  Sender-side
        ``load`` tracking decrements by the recalled count."""
        raise NotImplementedError

    def warmup_slot(self, s: int, prompt_len, sampled: bool) -> None:
        """Start warming one slot without blocking on the compile; poll
        ``slot_ready`` for completion.  In-process backends are warm by
        construction (shared jit cache) — only the process backend has
        a real async window."""
        self.slot_ready(s)

    def slot_ready(self, s: int) -> bool:
        """True once slot ``s`` has finished any ``warmup_slot`` work
        (always True on backends with nothing to warm)."""
        return True

    def enqueue(self, s: int, msg: RequestMsg) -> None:
        raise NotImplementedError

    def tick(self, s: int) -> list[TokenDeltaMsg]:
        """Step server ``s`` once on its own clock."""
        raise NotImplementedError

    def tick_many(self, servers) -> list[tuple[int, list[TokenDeltaMsg]]]:
        """Tick several servers; results in the given slot order.

        Base implementation steps them one after another; backends with
        real parallelism (one process per server) overlap the work.
        """
        return [(s, self.tick(s)) for s in servers]

    def busy(self, s: int) -> bool:
        raise NotImplementedError

    @property
    def any_busy(self) -> bool:
        return any(self.busy(s) for s in self.slots())

    def load(self, s: int) -> int:
        """Server ``s``'s instantaneous load: queued requests + occupied
        decode lanes — the quantity least-loaded admission minimizes.
        Known sender-side (no round-trip): a request contributes from
        enqueue until its ``done`` delta, and it is in exactly one of
        the two states for that whole span."""
        raise NotImplementedError

    def stats(self, s: int) -> StatsMsg:
        raise NotImplementedError

    def reset_stats(self) -> None:
        raise NotImplementedError

    def warmup(self, prompt_len, sampled: bool) -> None:
        raise NotImplementedError

    def sync(self) -> None:
        """Block until every expert's queued device work has landed."""
        raise NotImplementedError

    def close(self) -> None:
        """Release backend resources (processes/pipes); idempotent."""


class LoopbackTransport(Transport):
    """In-process transport: the default, zero-copy path.

    Holds the ``ExpertServer`` objects directly; messages pass by
    reference (nothing is pickled) and ``busy`` reuses the server's own
    idle predicate.  A removed slot leaves ``None`` in the table.
    """

    def __init__(self, servers, labels=None):
        self.servers = list(servers)
        self.labels = list(labels) if labels is not None else \
            [f"expert {s}" for s in range(len(self.servers))]

    def slots(self):
        return [s for s, srv in enumerate(self.servers) if srv is not None]

    def _srv(self, s):
        srv = self.servers[s]
        if srv is None:
            raise RuntimeError(f"{self.labels[s]} slot was retired")
        return srv

    def add_slot(self, target, label):
        # instant: the new server's jitted programs come from the shared
        # config-keyed compile cache — no cold-compile window in process
        self.servers.append(target)
        self.labels.append(label)
        return len(self.servers) - 1

    def remove_slot(self, s):
        if self.servers[s] is not None:
            self.servers[s] = None

    def recall(self, s):
        return self._srv(s).recall_pending()

    def enqueue(self, s, msg):
        self._srv(s).enqueue(check_version(msg))

    def tick(self, s):
        # no per-delta check_version: the server is this build's own
        # object, and the handshake rule (see module docstring) keeps
        # the emit path check-free on every transport
        return self._srv(s).tick()

    def busy(self, s):
        return self._srv(s).busy

    def load(self, s):
        srv = self._srv(s)
        return (len(srv.pending) + int(srv.active.sum())
                + int(srv.filling.sum()))

    def stats(self, s):
        return self._srv(s).stats()

    def reset_stats(self):
        for s in self.slots():
            self.servers[s].reset_stats()

    def warmup(self, prompt_len, sampled):
        # the jitted programs are shared across in-process servers via the
        # config-keyed compile cache: one server's shapes warm them all
        self.servers[self.slots()[0]].warmup(prompt_len, sampled=sampled)

    def sync(self):
        for s in self.slots():
            self.servers[s].sync()


def _serve_expert(conn, ecfg, eng, host_params) -> None:
    """Worker loop: one ExpertServer in its own process.

    Runs until a ``close`` op (or EOF).  Imports live inside the
    function: under the ``spawn`` start method this module is re-imported
    in a fresh interpreter, and jax must initialize per process.
    """
    import jax

    from repro.serving.expert_server import ExpertServer

    try:
        params = jax.device_put(host_params)   # once, not per jit call
        server = ExpertServer(ecfg, params, eng)
        # one-time build proof: the parent validates this hello on its
        # first reply read instead of re-checking every delta's version
        try:
            conn.send(("hello", WIRE_VERSION))
        except (BrokenPipeError, OSError):
            return   # parent closed before ever adopting this worker
        while True:
            try:
                op, args = conn.recv()
            except EOFError:
                return                          # parent went away
            if op == "enqueue":
                server.enqueue(args)            # pipe order == FIFO order
            elif op == "tick":
                conn.send(server.tick())
            elif op == "warmup":
                server.warmup(args[0], sampled=args[1])
                conn.send(None)
            elif op == "recall":
                conn.send(server.recall_pending())
            elif op == "stats":
                conn.send(server.stats())
            elif op == "reset_stats":
                server.reset_stats()
            elif op == "sync":
                server.sync()
                conn.send(None)
            elif op == "close":
                return
            else:
                raise ValueError(f"unknown transport op {op!r}")
    except Exception:                           # ship the traceback home
        try:
            conn.send(_RemoteError(traceback.format_exc()))
        except OSError:
            pass
        raise


class ProcessTransport(Transport):
    """One spawned OS process per server slot: params + KV pool live there.

    The local-machine proof of the multi-host story — the only bytes
    that ever cross a process boundary are pickled ``RequestMsg`` /
    ``TokenDeltaMsg`` / ``StatsMsg`` records (and the one-time param
    shipment at spawn).  ``busy``/``load`` are tracked parent-side from
    the message flow itself (enqueues minus ``done`` deltas), so the
    scheduler never round-trips just to ask who has work.  Replicas of a
    hot expert are just slots whose spawn params happen to be equal —
    the workers never know.

    Ops that expect a reply are pipelined by ``tick_many`` / ``warmup``
    / ``sync``: send to every server first, then collect — N servers
    really do compute concurrently (this is what makes replication a
    wall-clock win: a hot expert's replicas decode in parallel).

    ``add_slot`` spawns a fresh worker process mid-serve without
    stalling serving: ``Process.start()`` blocks until the booting
    child drains the (bigger-than-pipe-buffer) param pickle, so it runs
    on a background thread while ops queue in the already-open pipe —
    ``warmup_slot`` queues the compile and ``slot_ready`` polls for its
    completion without ever blocking the parent, which is how the
    autoscaler warms a new replica off-path before admitting it.

    The usual ``multiprocessing`` spawn rule applies: the parent's main
    module must be importable by path (a script piped via stdin cannot
    spawn workers — they die at startup, surfaced here with the slot's
    label, e.g. ``RuntimeError: expert 1 replica 0 worker exited``).  A
    worker that dies for any reason (OOM kill, segfault) is reported the
    same way, with its exit code; Python-level worker exceptions
    additionally ship their traceback home.
    """

    def __init__(self, ecfg, eng, server_params, labels=None):
        self._ecfg, self._eng = ecfg, eng        # add_slot re-spawn recipe
        self.labels = []
        self._outstanding = []
        self._hello = []
        self._warming = []
        self._starting: dict[int, threading.Thread] = {}
        self._broken = False
        self._closed = False
        self._ctx = mp.get_context("spawn")      # never fork a live jax
        self._procs, self._conns = [], []
        given = list(labels) if labels is not None else \
            [f"expert {s}" for s in range(len(server_params))]
        for p, lab in zip(server_params, given):
            self._spawn(p, lab)

    def _spawn(self, params, label, *, background=False) -> int:
        import jax                               # parent-side host transfer

        host = jax.tree_util.tree_map(np.asarray, params)
        parent, child = self._ctx.Pipe()
        proc = self._ctx.Process(target=_serve_expert,
                                 args=(child, self._ecfg, self._eng, host),
                                 daemon=True)
        self._procs.append(proc)
        self._conns.append(parent)
        self.labels.append(label)
        self._outstanding.append(0)
        self._hello.append(False)
        self._warming.append(False)
        s = len(self._procs) - 1
        if background:
            # Process.start() under spawn blocks until the child has
            # booted far enough to drain the >pipe-buffer param pickle —
            # hundreds of ms the serve path must not pay mid-tick.  The
            # pipe already exists, so ops sent meanwhile just queue;
            # slot_ready() stays False until the worker's warmup reply.
            t = threading.Thread(target=self._start_child,
                                 args=(proc, child), daemon=True)
            t.start()
            self._starting[s] = t
        else:
            self._start_child(proc, child)
        return s

    @staticmethod
    def _start_child(proc, child) -> None:
        proc.start()
        child.close()

    def _started(self, s) -> None:
        """Join slot ``s``'s background starter (no-op once it has run):
        join/exitcode on a not-yet-started Process would raise."""
        t = self._starting.pop(s, None)
        if t is not None:
            t.join()

    def slots(self):
        return [s for s, c in enumerate(self._conns) if c is not None]

    def add_slot(self, target, label):
        self._check()
        return self._spawn(target, label, background=True)

    def remove_slot(self, s):
        conn = self._conns[s]
        if conn is None:
            return
        self._started(s)
        self._conns[s] = None
        try:
            conn.send(("close", None))
        except (BrokenPipeError, OSError):
            pass
        try:
            conn.close()
        except OSError:
            pass
        p = self._procs[s]
        p.join(timeout=30)
        if p.is_alive():
            p.terminate()
            p.join(timeout=5)

    def recall(self, s):
        self._send(s, "recall", None)
        uids = self._recv(s)
        # the recalled requests leave this slot's queue for good — drop
        # them from the sender-side load or the slot leaks load forever
        self._outstanding[s] -= len(uids)
        return list(uids)

    def warmup_slot(self, s, prompt_len, sampled):
        # fire-and-forget: the compile happens in the worker while the
        # parent keeps serving; slot_ready() consumes the reply later
        self._send(s, "warmup", (prompt_len, sampled))
        self._warming[s] = True

    def slot_ready(self, s):
        if not self._warming[s]:
            return True
        self._check()
        conn = self._conn(s)
        while conn.poll(0):                     # never block the parent
            if not self._hello[s]:
                self._consume_hello(s)
                continue
            out = self._pipe_recv(s)
            if isinstance(out, _RemoteError):
                self._broken = True
                raise RuntimeError(f"{self.labels[s]} worker failed:\n"
                                   f"{out.trace}")
            self._warming[s] = False            # the warmup's None reply
            return True
        return False

    def _conn(self, s):
        c = self._conns[s]
        if c is None:
            raise RuntimeError(f"{self.labels[s]} slot was retired")
        return c

    def _dead(self, s) -> RuntimeError:
        """A worker vanished without a Python traceback (OOM kill,
        segfault): name the expert+replica and its exit code, not just
        a bare EOF."""
        self._started(s)
        self._procs[s].join(timeout=1)
        return RuntimeError(
            f"{self.labels[s]} worker exited "
            f"(exitcode={self._procs[s].exitcode})")

    def _check(self):
        if self._closed:
            raise RuntimeError("ProcessTransport is closed; build a fresh "
                               "engine to serve again")
        # after any worker failure the pipes may hold replies belonging
        # to an aborted batched op — fail every later op loudly instead
        # of handing a stale reply to the wrong caller
        if self._broken:
            raise RuntimeError("ProcessTransport is broken after a worker "
                               "failure; build a fresh engine")

    def _send(self, s, op, args):
        self._check()
        try:
            self._conn(s).send((op, args))
        except (BrokenPipeError, OSError):
            self._broken = True
            raise self._dead(s) from None

    def _pipe_recv(self, s):
        try:
            return self._conn(s).recv()
        except EOFError:
            self._broken = True
            raise self._dead(s) from None

    def _consume_hello(self, s):
        """The worker's first message is its boot hello: validate the
        build pairing once per process, so deltas need no per-message
        version checks afterwards."""
        first = self._pipe_recv(s)
        if isinstance(first, _RemoteError):
            self._broken = True
            raise RuntimeError(f"{self.labels[s]} worker failed:\n"
                               f"{first.trace}")
        if first != ("hello", WIRE_VERSION):
            self._broken = True
            got = first[1] if (isinstance(first, tuple)
                               and len(first) == 2
                               and first[0] == "hello") else first
            raise RuntimeError(
                f"wire protocol mismatch: {self.labels[s]} worker "
                f"speaks {got!r} but this build speaks "
                f"v{WIRE_VERSION} — frontend and expert servers "
                f"must run the same serving build")
        self._hello[s] = True

    def _recv(self, s):
        self._check()
        if not self._hello[s]:
            self._consume_hello(s)
        out = self._pipe_recv(s)
        if isinstance(out, _RemoteError):
            self._broken = True
            raise RuntimeError(f"{self.labels[s]} worker failed:\n"
                               f"{out.trace}")
        return out

    def enqueue(self, s, msg):
        self._outstanding[s] += 1
        self._send(s, "enqueue", check_version(msg))  # fire-and-forget

    def _absorb(self, s, deltas):
        # deltas carry `version` for wire compat but are not re-checked
        # here: the boot hello already proved the worker's build
        self._outstanding[s] -= sum(d.done for d in deltas)
        return deltas

    def tick(self, s):
        self._send(s, "tick", None)
        return self._absorb(s, self._recv(s))

    def tick_many(self, servers):
        servers = list(servers)
        for s in servers:                        # overlap server compute
            self._send(s, "tick", None)
        return [(s, self._absorb(s, self._recv(s))) for s in servers]

    def busy(self, s):
        # a request is outstanding exactly from enqueue until its done
        # delta — equivalent to the server's pending-or-active predicate,
        # but known parent-side without an RPC
        return self._outstanding[s] > 0

    def load(self, s):
        # outstanding == pending + active lanes: every unfinished request
        # is in exactly one of the two states (checked against StatsMsg
        # ground truth in the tests)
        return self._outstanding[s]

    def stats(self, s):
        self._send(s, "stats", None)
        return self._recv(s)

    def reset_stats(self):
        for s in self.slots():
            self._send(s, "reset_stats", None)

    def warmup(self, prompt_len, sampled):
        # per-process jit caches: every server warms itself, concurrently
        live = self.slots()
        for s in live:
            self._send(s, "warmup", (prompt_len, sampled))
        for s in live:
            self._recv(s)

    def sync(self):
        live = self.slots()
        for s in live:
            self._send(s, "sync", None)
        for s in live:
            self._recv(s)

    def close(self):
        self._closed = True
        for s in list(self._starting):
            self._started(s)
        for c in self._conns:
            if c is None:
                continue
            try:
                c.send(("close", None))
                c.close()
            except OSError:
                pass
        self._conns = []
        for p in self._procs:
            p.join(timeout=30)
            if p.is_alive():
                p.terminate()
                p.join(timeout=5)    # reap: no zombie per stuck worker
        self._procs = []

    def __del__(self):
        try:
            self.close()
        except Exception:
            pass
