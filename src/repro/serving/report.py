"""Typed run report: what ``ServeFrontend.run()`` returns.

The report used to be a nested dict whose keys were tribal knowledge
spread across ``serve_bench.py`` and the tests.  :class:`RunReport`
names every field with a docstring, while :meth:`RunReport.to_dict`
reproduces the **exact** historical JSON shape (key names, nesting, and
order) so ``benchmarks/compare_bench.py`` and the checked-in bench
baselines are untouched.  ``report[key]`` / ``report.get(key)`` /
``key in report`` keep working for existing dict-style callers.

No jax imports — the report is plain data.
"""
from __future__ import annotations

import dataclasses

from repro.serving.autoscale import ScaleEvent


@dataclasses.dataclass
class PrefixSharingStats:
    """The prefix-sharing KV cache's run counters (PR 7).

    ``enabled``              — was the radix cache on for this run.
    ``hit_blocks``           — KV blocks served from the cache instead
                               of being re-prefilled.
    ``prefill_tokens_saved`` — prompt tokens never (re)computed because
                               their blocks were cached.
    ``cached_blocks``        — blocks the cache holds at run end.
    """
    enabled: bool = True
    hit_blocks: int = 0
    prefill_tokens_saved: int = 0
    cached_blocks: int = 0

    def to_dict(self) -> dict:
        return {"enabled": self.enabled, "hit_blocks": self.hit_blocks,
                "prefill_tokens_saved": self.prefill_tokens_saved,
                "cached_blocks": self.cached_blocks}


@dataclasses.dataclass
class AutoscaleStats:
    """What the autoscaler did during the run (absent when disabled).

    ``scale_ups``/``scale_downs`` — replicas that entered admission /
                               were drained and released this run.
    ``peak_replicas``        — expert -> max simultaneous live replicas.
    ``final_replicas``       — expert -> live replicas at run end.
    ``events``               — every :class:`ScaleEvent` in tick order.
    """
    scale_ups: int = 0
    scale_downs: int = 0
    peak_replicas: dict = dataclasses.field(default_factory=dict)
    final_replicas: dict = dataclasses.field(default_factory=dict)
    events: list = dataclasses.field(default_factory=list)

    def to_dict(self) -> dict:
        return {"scale_ups": self.scale_ups,
                "scale_downs": self.scale_downs,
                "peak_replicas": dict(self.peak_replicas),
                "final_replicas": dict(self.final_replicas),
                "events": [e.to_dict() if isinstance(e, ScaleEvent) else e
                           for e in self.events]}


@dataclasses.dataclass
class RunReport:
    """One drained ``run()``: requests plus aggregate statistics.

    ``requests``          — the completed Request records, uid order.
    ``ticks``             — simulated tick span (idle gaps included).
    ``steps``             — scheduler iterations actually executed.
    ``wall_s``            — wall-clock seconds for the drain.
    ``useful_tokens``     — tokens delivered to callers (no padding, no
                            warmup).
    ``early_stops``       — requests that ended on a stop token.
    ``n_unadmitted``      — live requests that never reached a lane
                            (kept out of queue-wait aggregates).
    ``missing_replicas``  — labels of slots whose StatsMsg never arrived
                            (worker died); their counters are absent
                            from every aggregate below.
    ``prefix_sharing``    — :class:`PrefixSharingStats`.
    ``tokens_per_s``      — useful_tokens / wall_s.
    ``mean_ttft_s``       — mean wall seconds from submit to first token.
    ``occupancy``         — occupied lane-steps / (decode calls * lanes).
    ``prefill_calls``     — batched prefill invocations, all servers.
    ``kv_bytes_per_lane`` — device KV bytes one decode lane holds.
    ``decode_impl``       — resolved decode attention path (jnp/pallas).
    ``prefill_impl``      — resolved admission prefill path
                            (slab/jnp/pallas; jnp and pallas are the
                            fused paged prefill).
    ``transport``         — loopback / process / tcp.
    ``decode_read_bytes`` — paged vs gathered decode-read accounting.
    ``prefill_write_bytes`` — fused vs slab+scatter admission KV write
                            accounting (both priced on every prefill).
    ``epilogue_logits_bytes`` — (lanes, vocab) logits buffers the decode
                            epilogue materialized in HBM (0 on the fused
                            Pallas epilogue).
    ``per_expert``        — expert -> counters summed over its replicas
                            (retired replicas' counters fold in; the
                            ``per_replica`` breakdown lists live ones).
    ``autoscale``         — :class:`AutoscaleStats`, or None when no
                            ScalePolicy was installed (the legacy dict
                            shape then carries no "autoscale" key).
    """
    requests: list
    ticks: int
    steps: int
    wall_s: float
    useful_tokens: int
    early_stops: int
    n_unadmitted: int
    missing_replicas: list
    prefix_sharing: PrefixSharingStats
    tokens_per_s: float
    mean_ttft_s: float
    occupancy: float
    prefill_calls: int
    kv_bytes_per_lane: int
    decode_impl: str
    transport: str
    decode_read_bytes: dict
    per_expert: dict
    autoscale: AutoscaleStats | None = None
    prefill_impl: str = "jnp"
    prefill_write_bytes: dict = dataclasses.field(default_factory=dict)
    epilogue_logits_bytes: int = 0

    def to_dict(self) -> dict:
        """The exact historical ``run()`` dict (compare_bench's wire
        shape); ``autoscale`` appears only when the policy was on."""
        out = {
            "requests": self.requests,
            "ticks": self.ticks,
            "steps": self.steps,
            "wall_s": self.wall_s,
            "useful_tokens": self.useful_tokens,
            "early_stops": self.early_stops,
            "n_unadmitted": self.n_unadmitted,
            "missing_replicas": self.missing_replicas,
            "prefix_sharing": self.prefix_sharing.to_dict(),
            "tokens_per_s": self.tokens_per_s,
            "mean_ttft_s": self.mean_ttft_s,
            "occupancy": self.occupancy,
            "prefill_calls": self.prefill_calls,
            "kv_bytes_per_lane": self.kv_bytes_per_lane,
            "decode_impl": self.decode_impl,
            "prefill_impl": self.prefill_impl,
            "transport": self.transport,
            "decode_read_bytes": self.decode_read_bytes,
            "prefill_write_bytes": self.prefill_write_bytes,
            "epilogue_logits_bytes": self.epilogue_logits_bytes,
            "per_expert": self.per_expert,
        }
        if self.autoscale is not None:
            out["autoscale"] = self.autoscale.to_dict()
        return out

    # dict-compat shims: the report was a plain dict for eight PRs and
    # the bench/tests index it — keep ``res["tokens_per_s"]`` working
    def __getitem__(self, key):
        return self.to_dict()[key]

    def get(self, key, default=None):
        return self.to_dict().get(key, default)

    def __contains__(self, key) -> bool:
        return key in self.to_dict()

    def keys(self):
        return self.to_dict().keys()
