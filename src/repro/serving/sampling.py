"""Sampling contract shared by the serving engine and its oracle.

:class:`SamplingParams` is the per-request generation recipe
(``temperature`` / ``top_k`` / ``top_p`` / ``seed``; ``temperature=0.0``
is exact greedy argmax).  Both decode paths — the continuous-batching
engine's jitted per-expert ``decode_step`` and the one-shot
:mod:`repro.serving.baseline` oracle — draw tokens through the *same*
row-wise :func:`sample_tokens`, so sampled decoding stays bit-identical
between them exactly like greedy always has been.

Randomness is counter-based, never stateful: token ``t`` of request
``uid`` is sampled with ``fold_in(fold_in(PRNGKey(seed), uid), t)``.
That makes the stream a pure function of ``(seed, uid, t)`` — which lane
a request lands in, how many other lanes are active, or how often it got
evicted/re-bucketed cannot change its tokens, and the per-lane key/step
arrays are plain traced operands so lane churn never recompiles the
decode step.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

# temperature==0 selects the argmax branch; the clamp only keeps the
# discarded sampled branch finite inside the jitted `where`
_MIN_TEMP = 1e-6


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Frozen per-request sampling recipe.

    ``temperature=0.0`` (the default) is exact greedy decoding — raw
    argmax, bit-identical to the historical greedy path.  ``top_k=0``
    disables top-k filtering, ``top_p=1.0`` disables nucleus filtering;
    ties at either threshold are kept (deterministically, on both decode
    paths).  ``seed`` roots the counter-based RNG stream; two requests
    with equal ``(seed, uid)`` draw identical noise.
    """
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0

    def __post_init__(self):
        if self.temperature < 0.0:
            raise ValueError(f"temperature must be >= 0, got {self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0 (0 disables), got {self.top_k}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")
        if self.seed < 0:
            raise ValueError(f"seed must be >= 0, got {self.seed}")

    @property
    def greedy(self) -> bool:
        return self.temperature == 0.0


@functools.lru_cache(maxsize=512)     # seeds are client-supplied: keep bounded
def _seed_key(seed: int) -> np.ndarray:
    return np.asarray(jax.random.PRNGKey(seed))


def request_key(seed: int, uid: int) -> np.ndarray:
    """The per-request RNG root ``fold_in(PRNGKey(seed), uid)``.

    Host-side (uint32 ``(2,)``); the engine stores one per lane and the
    baseline one per batch row, so both fold in the same step counter.
    """
    return np.asarray(jax.random.fold_in(_seed_key(seed), max(int(uid), 0)))


def _sample_row(logits, key, step, temp, top_k, top_p):
    """Draw one token from one row of logits; greedy when ``temp == 0``.

    Filtering order matches the common convention: scale by temperature,
    mask to the top-k logits, then to the top-p (nucleus) mass; ties at
    either threshold are kept.  All params are traced scalars, so one
    compiled program serves every (greedy or sampled) lane mix.
    """
    v = logits.shape[-1]
    greedy_tok = jnp.argmax(logits, -1).astype(jnp.int32)
    scaled = (logits / jnp.maximum(temp, _MIN_TEMP)).astype(jnp.float32)
    desc = -jnp.sort(-scaled)                       # descending
    k_eff = jnp.where((top_k <= 0) | (top_k > v), v, top_k)
    kth = desc[jnp.clip(k_eff - 1, 0, v - 1)]
    kept = jnp.where(scaled >= kth, scaled, -jnp.inf)
    probs = jax.nn.softmax(kept)
    pdesc = -jnp.sort(-probs)
    cum = jnp.cumsum(pdesc)
    # the nucleus: smallest prefix with mass >= top_p (crossing token kept)
    in_nucleus = (cum - pdesc) < top_p
    thr = jnp.min(jnp.where(in_nucleus, pdesc, jnp.inf))
    final = jnp.where(probs >= thr, kept, -jnp.inf)
    tok = jax.random.categorical(jax.random.fold_in(key, step), final)
    return jnp.where(temp > 0.0, tok.astype(jnp.int32), greedy_tok)


def sample_tokens(logits, keys, steps, temps, top_ks, top_ps):
    """Vectorized row-wise sampler: ``(B, V)`` logits -> ``(B,)`` int32.

    ``keys`` are per-row uint32 ``(B, 2)`` request roots (see
    :func:`request_key`), ``steps`` the per-row token counters; rows are
    independent, so the same request samples identical tokens at any
    batch width or lane position.
    """
    return jax.vmap(_sample_row)(logits, keys, steps, temps, top_ks, top_ps)


# one jitted sampler shared by the engine and the baseline oracle: its
# trace depends only on array shapes, so separate per-config caches would
# just duplicate compiles
sample_tokens_jit = jax.jit(sample_tokens)


def truncate_at_stop(tokens, stop_tokens) -> np.ndarray:
    """Cut a token array after the first stop token (which is kept).

    The oracle decodes a request's full budget; the engine stops at the
    stop token — this maps the former onto the latter for comparison.
    """
    tokens = np.asarray(tokens)
    if not stop_tokens:
        return tokens
    hits = np.nonzero(np.isin(tokens, list(stop_tokens)))[0]
    return tokens[:hits[0] + 1] if hits.size else tokens
