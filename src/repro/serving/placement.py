"""First-class placements: who serves (expert, replica), and where.

A :class:`Placement` is the typed record behind every "expert E
replica R" in the serving stack — the registry advertises them, the
transports label slots with them, and the frontend's admission map is a
:class:`PlacementMap` over them.  Before this module the same triple
lived as ad-hoc ``(e, r)`` tuples in the frontend, ``(e, r, host,
port)`` tuples on the registry wire, and f-string labels derived in
three places; the label now derives in exactly one (:attr:`Placement.label`).

Slots are **transport addresses**: a flat index into the transport's
slot table.  With live autoscaling (:mod:`repro.serving.autoscale`)
slot indices grow monotonically and are never reused — a retired slot
leaves a hole, so a stale index can never silently address a new
replica.  ``slot == -1`` means "not bound to a transport yet" (e.g. a
placement fresh off the registry wire).

This module is importable without jax (pure dataclass + dict logic), so
the control plane — registry, CLI parsing, policy code — stays light.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Placement:
    """One (expert, replica) server and where it lives.

    ``expert``/``replica`` identify the server; ``slot`` is its
    transport address (-1 = unbound); ``host``/``port`` are set on the
    tcp transport (empty/0 locally).  Iterating yields the legacy
    registry-wire tuple ``(expert, replica, host, port)`` so existing
    ``for e, r, host, port in placements`` call sites keep working.
    """
    expert: int
    replica: int
    slot: int = -1
    host: str = ""
    port: int = 0

    @property
    def label(self) -> str:
        """THE human name for this server — every transport error and
        ``missing_replicas`` entry derives from here."""
        return f"expert {self.expert} replica {self.replica}"

    @property
    def addr(self) -> tuple[str, int]:
        return (self.host, self.port)

    @property
    def key(self):
        """Transport-independent identity (slot excluded): what the
        frontend uses to recognize a worker across registry re-derivations."""
        return (self.expert, self.replica, self.host, self.port)

    def bind(self, slot: int) -> "Placement":
        """A copy bound to a transport slot."""
        return dataclasses.replace(self, slot=int(slot))

    def __iter__(self):
        return iter((self.expert, self.replica, self.host, self.port))


class PlacementMap:
    """The frontend's admission map: live slot -> :class:`Placement`.

    Supports add/remove/lookup by slot or by (expert, replica), and
    iteration in slot order.  Exactly the placements in this map are
    admissible — a warming or draining replica lives outside it, which
    is what makes scale-up/scale-down atomic from the router's point of
    view (a replica either takes new requests or it does not).
    """

    def __init__(self, placements=()):
        self._by_slot: dict[int, Placement] = {}
        self._by_id: dict[tuple[int, int], Placement] = {}
        for p in placements:
            self.add(p)

    def add(self, p: Placement) -> Placement:
        if p.slot < 0:
            raise ValueError(f"{p.label} is not bound to a slot")
        if p.slot in self._by_slot:
            raise ValueError(f"slot {p.slot} already maps to "
                             f"{self._by_slot[p.slot].label}")
        if (p.expert, p.replica) in self._by_id:
            raise ValueError(f"{p.label} is already placed "
                             f"(slot {self._by_id[(p.expert, p.replica)].slot})")
        self._by_slot[p.slot] = p
        self._by_id[(p.expert, p.replica)] = p
        return p

    def remove(self, slot: int) -> Placement:
        p = self._by_slot.pop(slot)
        del self._by_id[(p.expert, p.replica)]
        return p

    def get(self, slot: int) -> Placement | None:
        return self._by_slot.get(slot)

    def __getitem__(self, slot: int) -> Placement:
        return self._by_slot[slot]

    def __contains__(self, slot: int) -> bool:
        return slot in self._by_slot

    def find(self, expert: int, replica: int) -> Placement | None:
        return self._by_id.get((expert, replica))

    def slots(self) -> list[int]:
        return sorted(self._by_slot)

    def slots_of(self, expert: int) -> list[int]:
        return sorted(p.slot for p in self._by_id.values()
                      if p.expert == expert)

    def replicas_of(self, expert: int) -> list[Placement]:
        return sorted((p for p in self._by_id.values()
                       if p.expert == expert), key=lambda p: p.replica)

    def n_replicas(self, expert: int) -> int:
        return sum(p.expert == expert for p in self._by_id.values())

    def next_replica(self, expert: int, taken=()) -> int:
        """Smallest replica index not live and not in ``taken`` (the
        registry's auto-assignment rule, applied frontend-side for the
        local transports)."""
        used = {p.replica for p in self._by_id.values()
                if p.expert == expert} | set(taken)
        return next(i for i in range(len(used) + 1) if i not in used)

    def __iter__(self):
        return iter(sorted(self._by_slot.values(), key=lambda p: p.slot))

    def __len__(self) -> int:
        return len(self._by_slot)

    def __repr__(self) -> str:
        return (f"PlacementMap({[f'{p.label}@{p.slot}' for p in self]})")
