"""The original one-shot serving path (serial per-expert groups).

This is the pre-engine demo loop kept as (a) the numerical oracle the
continuous-batching engine must match token-for-token and (b) the
baseline ``benchmarks/serve_bench.py`` measures against: route the whole
batch up front, then for each expert group run one prefill + a fixed
number of decode steps — every request in a group decodes to the group
maximum even if it asked for fewer tokens, and groups run one after
another, so lanes sit idle exactly the way continuous batching avoids.
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import assignment as asg
from repro.core import router as routerlib
from repro.models import model as modellib


@functools.lru_cache(maxsize=None)
def _decode_step(cfg):
    """One jitted decode step per config — NOT per generate() call, so a
    warmup run genuinely removes compiles from later timed runs."""
    return jax.jit(lambda p, b, c: modellib.decode_step(p, cfg, b, c))


def generate(cfg, params, prompts: jnp.ndarray, n_new: int,
             cache_len: int | None = None) -> np.ndarray:
    """Batched greedy prefill + decode loop for one expert.

    ``cache_len`` pads the KV budget beyond the required ``S + n_new``
    (extra slots are position-masked, so logits are unchanged); the bench
    uses it to hold cache shapes identical to the engine's lanes.
    """
    B, S = prompts.shape
    cache_len = cache_len if cache_len else S + n_new
    assert cache_len >= S + n_new, (cache_len, S, n_new)
    logits, caches = modellib.prefill(params, cfg, {"tokens": prompts},
                                      cache_len=cache_len)
    outs = []
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    step = _decode_step(cfg)
    for t in range(n_new):
        outs.append(np.asarray(tok[:, 0]))
        if t == n_new - 1:
            break                                 # last logits would be unused
        lg, caches = step(params, {
            "tokens": tok,
            "positions": jnp.full((B, 1), S + t, jnp.int32),
            "cache_index": jnp.int32(S + t)}, caches)
        tok = jnp.argmax(lg[:, 0], -1)[:, None].astype(jnp.int32)
    return np.stack(outs, 1)                      # (B, n_new)


def route(rcfg, router_params, prompts: np.ndarray, prefix_len: int) -> np.ndarray:
    """Prefix-likelihood routing: argmax over the router ensemble (§2.2)."""
    scores = routerlib.ensemble_scores(router_params, rcfg,
                                       jnp.asarray(prompts[:, :prefix_len]))
    return np.asarray(asg.argmax_assignment(scores))


def serve_batch(ecfg, rcfg, expert_params: list, router_params,
                prompts: np.ndarray, *, prefix_len: int, n_new: int,
                cache_len: int | None = None) -> dict:
    """Route a request batch and generate per expert group, serially."""
    t0 = time.time()
    eids = route(rcfg, router_params, prompts, prefix_len)
    t_route = time.time() - t0
    out = np.zeros((prompts.shape[0], n_new), np.int32)
    per_expert = {}
    for e in np.unique(eids):
        sel = np.nonzero(eids == e)[0]
        t1 = time.time()
        out[sel] = generate(ecfg, expert_params[int(e)],
                            jnp.asarray(prompts[sel]), n_new,
                            cache_len=cache_len)
        per_expert[int(e)] = {"n": len(sel), "s": round(time.time() - t1, 2)}
    return {"tokens": out, "routes": eids, "route_s": round(t_route, 3),
            "per_expert": per_expert}


def serve_serial(ecfg, rcfg, expert_params: list, router_params,
                 prompts: np.ndarray, n_new: np.ndarray, *,
                 prefix_len: int, cache_len: int | None = None) -> dict:
    """The old path on a mixed-completion-length workload.

    Per-request token budgets are honoured the only way the one-shot loop
    can: each expert group decodes to its *maximum* requested length and
    the surplus is thrown away.  Returns per-request ragged token lists
    plus the wasted-token count (the quantity continuous batching
    reclaims).  Prompts must share one length — the old path re-pads
    whole groups and cannot mix prompt lengths.
    """
    n_new = np.asarray(n_new, np.int64)
    t0 = time.time()
    eids = route(rcfg, router_params, prompts, prefix_len)
    tokens: list[np.ndarray | None] = [None] * len(prompts)
    wasted = 0
    for e in np.unique(eids):
        sel = np.nonzero(eids == e)[0]
        n_max = int(n_new[sel].max())
        outs = generate(ecfg, expert_params[int(e)], jnp.asarray(prompts[sel]),
                        n_max, cache_len=cache_len)
        for row, i in enumerate(sel):
            tokens[i] = outs[row, :n_new[i]]
            wasted += n_max - int(n_new[i])
    wall = time.time() - t0
    useful = int(n_new.sum())
    return {"tokens": tokens, "routes": eids, "wall_s": wall,
            "useful_tokens": useful, "wasted_tokens": wasted,
            "tokens_per_s": useful / max(wall, 1e-9)}
