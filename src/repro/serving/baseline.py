"""The original one-shot serving path (serial per-expert groups).

This is the pre-engine demo loop kept as (a) the numerical oracle the
continuous-batching engine must match token-for-token — greedy AND
sampled: :func:`generate` draws non-greedy tokens through the same
row-wise :mod:`repro.serving.sampling` sampler, keyed by the same
``(seed, uid, step)`` counters as the engine's lanes — and (b) the
baseline ``benchmarks/serve_bench.py`` measures against: route the whole
batch up front, then for each expert group run one prefill + a fixed
number of decode steps — every request in a group decodes to the group
maximum even if it asked for fewer tokens (stop-token surplus is
truncated after the fact), and groups run one after another, so lanes
sit idle exactly the way continuous batching avoids.
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import assignment as asg
from repro.core import router as routerlib
from repro.models import model as modellib
from repro.serving import sampling as samplib
from repro.serving.sampling import SamplingParams, truncate_at_stop


@functools.lru_cache(maxsize=None)
def _decode_step(cfg):
    """One jitted decode step per config — NOT per generate() call, so a
    warmup run genuinely removes compiles from later timed runs."""
    return jax.jit(lambda p, b, c: modellib.decode_step(p, cfg, b, c))


def generate(cfg, params, prompts: jnp.ndarray, n_new: int,
             cache_len: int | None = None, *,
             sampling: SamplingParams | None = None,
             uids=None) -> np.ndarray:
    """Batched prefill + decode loop for one expert.

    Greedy by default (``sampling=None`` or ``temperature=0`` keep the
    historical raw-argmax path, bit for bit).  With a non-greedy
    ``sampling``, every row draws token ``t`` through the shared
    counter-based sampler with key ``fold_in(PRNGKey(seed), uids[row])``
    — pass the engine's request uids to reproduce its tokens exactly
    (``uids`` defaults to ``0..B-1``).

    ``cache_len`` pads the KV budget beyond the required ``S + n_new``
    (extra slots are position-masked, so logits are unchanged); the bench
    uses it to hold cache shapes identical to the engine's lanes.
    """
    B, S = prompts.shape
    cache_len = cache_len if cache_len else S + n_new
    assert cache_len >= S + n_new, (cache_len, S, n_new)
    greedy = sampling is None or sampling.greedy
    if not greedy:
        uids = np.arange(B) if uids is None else np.asarray(uids)
        assert uids.shape == (B,), (uids.shape, B)
        keys = np.stack([samplib.request_key(sampling.seed, int(u))
                         for u in uids])
        temps = np.full(B, sampling.temperature, np.float32)
        topks = np.full(B, sampling.top_k, np.int32)
        topps = np.full(B, sampling.top_p, np.float32)
        sample = samplib.sample_tokens_jit

        def draw(lg, t):                          # token counter t, all rows
            return sample(lg, keys, np.full(B, t, np.int32),
                          temps, topks, topps)[:, None]
    else:
        def draw(lg, t):
            return jnp.argmax(lg, -1)[:, None].astype(jnp.int32)

    logits, caches = modellib.prefill(params, cfg, {"tokens": prompts},
                                      cache_len=cache_len)
    outs = []
    tok = draw(logits, 0)
    step = _decode_step(cfg)
    for t in range(n_new):
        outs.append(np.asarray(tok[:, 0]))
        if t == n_new - 1:
            break                                 # last logits would be unused
        lg, caches = step(params, {
            "tokens": tok,
            "positions": jnp.full((B, 1), S + t, jnp.int32),
            "cache_index": jnp.int32(S + t)}, caches)
        tok = draw(lg[:, 0], t + 1)
    return np.stack(outs, 1)                      # (B, n_new)


def generate_request(cfg, params, prompt, n_new: int, *,
                     sampling: SamplingParams | None = None, uid: int = 0,
                     stop_tokens=(), cache_len: int | None = None) -> np.ndarray:
    """One-request oracle for an engine Request: decode ``n_new`` tokens
    with the request's sampling recipe and uid, then truncate at the
    first stop token (kept) — exactly the ragged sequence the engine's
    early-stop path emits."""
    prompt = np.asarray(prompt, np.int32).reshape(-1)
    toks = generate(cfg, params, jnp.asarray(prompt[None]), n_new, cache_len,
                    sampling=sampling, uids=np.array([uid]))[0]
    return truncate_at_stop(toks, stop_tokens)


def route(rcfg, router_params, prompts: np.ndarray, prefix_len: int) -> np.ndarray:
    """Prefix-likelihood routing: argmax over the router ensemble (§2.2)."""
    scores = routerlib.ensemble_scores(router_params, rcfg,
                                       jnp.asarray(prompts[:, :prefix_len]))
    return np.asarray(asg.argmax_assignment(scores))


def serve_batch(ecfg, rcfg, expert_params: list, router_params,
                prompts: np.ndarray, *, prefix_len: int, n_new: int,
                cache_len: int | None = None) -> dict:
    """Route a request batch and generate per expert group, serially."""
    t0 = time.time()
    eids = route(rcfg, router_params, prompts, prefix_len)
    t_route = time.time() - t0
    out = np.zeros((prompts.shape[0], n_new), np.int32)
    per_expert = {}
    for e in np.unique(eids):
        sel = np.nonzero(eids == e)[0]
        t1 = time.time()
        out[sel] = generate(ecfg, expert_params[int(e)],
                            jnp.asarray(prompts[sel]), n_new,
                            cache_len=cache_len)
        per_expert[int(e)] = {"n": len(sel), "s": round(time.time() - t1, 2)}
    return {"tokens": out, "routes": eids, "route_s": round(t_route, 3),
            "per_expert": per_expert}


def serve_serial(ecfg, rcfg, expert_params: list, router_params,
                 prompts: np.ndarray, n_new: np.ndarray, *,
                 prefix_len: int, cache_len: int | None = None,
                 sampling: SamplingParams | None = None,
                 stop_tokens=(), uids=None) -> dict:
    """The old path on a mixed-completion-length workload.

    Per-request token budgets and stop conditions are honoured the only
    way the one-shot loop can: each expert group decodes to its *maximum*
    requested length and the surplus — budget spread and everything past
    a stop token — is thrown away.  Returns per-request ragged token
    lists plus the wasted-token count (the quantity continuous batching
    reclaims).  ``sampling``/``uids`` apply the shared counter-based
    sampler per row (pass the engine's uids for token-identical output);
    prompts must share one length — the old path re-pads whole groups and
    cannot mix prompt lengths.
    """
    n_new = np.asarray(n_new, np.int64)
    uids = np.arange(len(prompts)) if uids is None else np.asarray(uids)
    t0 = time.time()
    eids = route(rcfg, router_params, prompts, prefix_len)
    tokens: list[np.ndarray | None] = [None] * len(prompts)
    wasted = 0
    for e in np.unique(eids):
        sel = np.nonzero(eids == e)[0]
        n_max = int(n_new[sel].max())
        outs = generate(ecfg, expert_params[int(e)], jnp.asarray(prompts[sel]),
                        n_max, cache_len=cache_len,
                        sampling=sampling, uids=uids[sel])
        for row, i in enumerate(sel):
            tokens[i] = truncate_at_stop(outs[row, :n_new[i]], stop_tokens)
            wasted += n_max - len(tokens[i])
    wall = time.time() - t0
    useful = sum(len(t) for t in tokens)
    return {"tokens": tokens, "routes": eids, "wall_s": wall,
            "useful_tokens": useful, "wasted_tokens": wasted,
            "tokens_per_s": useful / max(wall, 1e-9)}
