"""Request bookkeeping for the continuous-batching engine.

Host-side, pure-python: a :class:`Request` record per served sequence, a
FIFO :class:`RequestQueue` with (simulated or wall-clock) arrival ticks,
a :class:`SlotAllocator` free list handing out decode-lane slots, a
refcounting :class:`BlockAllocator` over the paged KV block pool (see
:mod:`repro.serving.cache` for the device-side layout it indexes), and
the :class:`PrefixCache` radix tree that lets requests with a common
prompt prefix share full KV blocks copy-on-write.
"""
from __future__ import annotations

import bisect
import dataclasses

import numpy as np

from repro.serving.sampling import SamplingParams


@dataclasses.dataclass
class Request:
    """One generation request and its lifecycle stats.

    ``sampling`` is the frozen per-request recipe (default: greedy) and
    ``stop_tokens`` the set of token ids that end the sequence early (the
    stop token itself is kept as the final token).  The engine fills in
    everything below ``arrival_tick``: the routed expert, the decoded
    tokens (the first one comes from the prefill logits, like the
    one-shot ``generate`` path), the finish reason (``"stop_token"`` or
    ``"length"``), and tick/wall timestamps for latency accounting.
    """
    uid: int
    prompt: np.ndarray                  # (L,) int32
    max_new_tokens: int
    sampling: SamplingParams = SamplingParams()
    stop_tokens: frozenset = frozenset()
    arrival_tick: int = 0

    expert: int = -1
    replica: int = 0                    # which replica of the expert served it
    tokens: list = dataclasses.field(default_factory=list)
    finish_reason: str = ""             # "stop_token" | "length" once done
    route_tick: int = -1                # tick the router scored the prefix
    admit_tick: int = -1                # tick a decode lane was acquired
    finish_tick: int = -1
    t_first: float = -1.0               # seconds from run start to first token
    t_done: float = -1.0

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        self.stop_tokens = frozenset(int(t) for t in self.stop_tokens)

    @property
    def done(self) -> bool:
        return self.finish_tick >= 0

    @property
    def queue_ticks(self) -> int:
        """Ticks spent waiting between arrival and lane admission.

        0 until a lane is actually acquired — ``admit_tick`` is still the
        -1 sentinel before then and the difference would be garbage.
        """
        if self.admit_tick < 0:
            return 0
        return self.admit_tick - self.arrival_tick


class RequestQueue:
    """Arrival-ordered queue; requests become visible at their tick.

    Kept sorted by ``arrival_tick`` on push (stable for equal ticks), so
    submission order does not have to match simulated arrival order — a
    late-submitted early arrival cannot head-of-line-block."""

    def __init__(self):
        self._q: list[Request] = []

    def push(self, req: Request) -> None:
        bisect.insort(self._q, req, key=lambda r: r.arrival_tick)

    def pop_arrived(self, tick: int) -> list[Request]:
        n = bisect.bisect_right(self._q, tick, key=lambda r: r.arrival_tick)
        out, self._q = self._q[:n], self._q[n:]
        return out

    def next_arrival(self) -> int | None:
        return self._q[0].arrival_tick if self._q else None

    def __len__(self) -> int:
        return len(self._q)


class SlotAllocator:
    """LIFO free list over ``n`` decode-lane slots.

    ``_owned`` (currently-held slots) makes :meth:`free` an O(1) check
    and lets a bad free say *which* bug it is: freeing a slot that was
    handed out and already returned is a double free; freeing one that
    was never handed out is a phantom free.
    """

    def __init__(self, n: int):
        self.n = n
        self._free = list(range(n - 1, -1, -1))   # pop() hands out slot 0 first
        self._owned: set[int] = set()
        self._ever: set[int] = set()              # ever handed out

    def alloc(self) -> int | None:
        if not self._free:
            return None
        slot = self._free.pop()
        self._owned.add(slot)
        self._ever.add(slot)
        return slot

    def free(self, slot: int) -> None:
        if slot in self._owned:
            self._owned.remove(slot)
            self._free.append(slot)
            return
        if slot in self._ever:
            raise ValueError(f"double free of slot {slot}")
        raise ValueError(f"free of never-allocated slot {slot}")

    @property
    def n_free(self) -> int:
        return len(self._free)


class BlockAllocator:
    """Refcounting LIFO free list over ``n`` KV-pool blocks.

    A lane's whole block reservation is taken with :meth:`alloc_n` (all
    or nothing — a partially admitted request could deadlock the pool)
    at refcount 1.  Prefix sharing adds references with :meth:`ref_n`
    (the cache holds one ref per cached block, each lane reading a
    shared block holds another); :meth:`free_n` drops references and a
    block only returns to the free list at refcount 0.  Both ``free_n``
    and ``ref_n`` validate the *whole* batch before mutating anything,
    so a bad id mid-list raises without leaving the allocator half
    updated.  ``free`` of a block that is not currently allocated
    raises, so scheduler bugs surface as exceptions instead of silent
    cache corruption.
    """

    def __init__(self, n: int):
        self.n = n
        self._free = list(range(n - 1, -1, -1))   # pop() hands out block 0 first
        self._refs: dict[int, int] = {}
        self.peak_in_use = 0

    def alloc(self) -> int | None:
        got = self.alloc_n(1)
        return got[0] if got else None

    def alloc_n(self, k: int) -> list[int] | None:
        """Take ``k`` blocks atomically; None (and no change) if short."""
        if k < 0:
            raise ValueError(f"alloc_n({k})")
        if len(self._free) < k:
            return None
        got = [self._free.pop() for _ in range(k)]
        for b in got:
            self._refs[b] = 1
        self.peak_in_use = max(self.peak_in_use, len(self._refs))
        return got

    def ref_n(self, blocks) -> None:
        """Add one reference to each listed block (atomic: validates the
        whole batch, then increments; a repeated id counts twice)."""
        blocks = [int(b) for b in blocks]
        for b in blocks:
            if b not in self._refs:
                raise ValueError(f"ref of unallocated block {b}")
        for b in blocks:
            self._refs[b] += 1

    def free(self, block: int) -> None:
        self.free_n([block])

    def free_n(self, blocks) -> None:
        """Drop one reference per listed block; refcount 0 returns the
        block to the free list.  Atomic: the whole batch is validated
        first (including repeated ids exceeding a block's refcount), so
        a bad id leaves ``n_free``/``n_in_use`` untouched."""
        blocks = [int(b) for b in blocks]
        drops: dict[int, int] = {}
        for b in blocks:
            drops[b] = drops.get(b, 0) + 1
        for b, k in drops.items():
            have = self._refs.get(b, 0)
            if k > have:
                raise ValueError(f"bad free of block {b}")
        for b in blocks:                  # preserve LIFO order of the batch
            self._refs[b] -= 1
            if self._refs[b] == 0:
                del self._refs[b]
                self._free.append(b)

    def refcount(self, block: int) -> int:
        return self._refs.get(int(block), 0)

    @property
    def _owned(self) -> set[int]:
        return set(self._refs)

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_in_use(self) -> int:
        return len(self._refs)


class _PrefixNode:
    """One full prompt block in the prefix trie."""
    __slots__ = ("chunk", "block", "children", "parent", "stamp")

    def __init__(self, chunk, block, parent):
        self.chunk = chunk                  # block_size prompt tokens (tuple)
        self.block = block                  # pool block holding their KV
        self.children: dict[tuple, _PrefixNode] = {}
        self.parent = parent
        self.stamp = 0                      # LRU clock, bumped on touch


class PrefixCache:
    """Per-expert radix tree mapping full prompt-prefix blocks to pool
    blocks, enabling copy-on-write block reuse across requests.

    Keys are exact ``block_size``-token tuples (no hash collisions), one
    trie level per full block.  The cache holds its own reference on
    every registered block (via :meth:`BlockAllocator.ref_n`), so a
    cached block survives its writer lane retiring; each lane that
    acquires a prefix holds one more ref per shared block.  A block with
    refcount 1 is *cached-but-unreferenced* — reclaimable.  Eviction is
    LRU over childless such nodes (interior nodes become eligible once
    their children are evicted), triggered only under pool pressure.
    """

    def __init__(self, balloc: BlockAllocator, block_size: int):
        self.balloc = balloc
        self.block_size = int(block_size)
        self._root = _PrefixNode(None, -1, None)
        self._clock = 0
        self.hits = 0                       # lifetime acquired blocks
        self.evictions = 0

    def _touch(self, node: _PrefixNode) -> None:
        self._clock += 1
        node.stamp = self._clock

    def _walk(self, prompt) -> list[_PrefixNode]:
        """Longest cached path for ``prompt``, capped so the prompt's
        final position is never inside a hit (its logits must always be
        computed to emit the first token)."""
        bs = self.block_size
        cap = (len(prompt) - 1) // bs
        path, node = [], self._root
        for i in range(cap):
            chunk = tuple(int(t) for t in prompt[i * bs:(i + 1) * bs])
            nxt = node.children.get(chunk)
            if nxt is None:
                break
            path.append(nxt)
            node = nxt
        return path

    def match_blocks(self, prompt) -> int:
        """How many leading full blocks of ``prompt`` are cached."""
        return len(self._walk(prompt))

    def acquire(self, prompt) -> list[int]:
        """Take one reference on each block of the longest cached prefix
        and return the pool block ids (possibly empty).  The caller owns
        the refs: pass them to ``balloc.free_n`` on lane retirement (or
        on admission rollback)."""
        path = self._walk(prompt)
        if not path:
            return []
        blocks = [n.block for n in path]
        self.balloc.ref_n(blocks)
        for n in path:
            self._touch(n)
        self.hits += len(blocks)
        return blocks

    def register(self, prompt, blocks) -> None:
        """Record ``prompt``'s full blocks (KV fully written) as cached.

        ``blocks`` is the lane's block-table prefix covering the prompt;
        only the first ``len(prompt) // block_size`` entries (full
        blocks) are eligible.  Existing trie nodes win — the lane's own
        block for an already-cached chunk is NOT swapped in (both hold
        identical tokens' KV); new chunks take a cache-owned reference
        on the lane's block."""
        bs = self.block_size
        n_full = len(prompt) // bs
        node = self._root
        for i in range(n_full):
            chunk = tuple(int(t) for t in prompt[i * bs:(i + 1) * bs])
            nxt = node.children.get(chunk)
            if nxt is None:
                nxt = _PrefixNode(chunk, int(blocks[i]), node)
                self.balloc.ref_n([nxt.block])
                node.children[chunk] = nxt
            self._touch(nxt)
            node = nxt

    def evict(self, want_free: int) -> bool:
        """Drop LRU cached-but-unreferenced blocks until the allocator
        has ``want_free`` free blocks.  Returns True on success, False
        if no evictable block remains (all cached blocks still shared
        with live lanes)."""
        while self.balloc.n_free < want_free:
            victim = None
            stack = list(self._root.children.values())
            while stack:
                n = stack.pop()
                if n.children:
                    stack.extend(n.children.values())
                elif self.balloc.refcount(n.block) == 1:
                    if victim is None or n.stamp < victim.stamp:
                        victim = n
            if victim is None:
                return False
            self.balloc.free_n([victim.block])
            del victim.parent.children[victim.chunk]
            self.evictions += 1
        return True

    @property
    def n_blocks(self) -> int:
        """Blocks currently held by the cache (one ref each)."""
        count, stack = 0, list(self._root.children.values())
        while stack:
            n = stack.pop()
            count += 1
            stack.extend(n.children.values())
        return count
