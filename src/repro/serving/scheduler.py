"""Request bookkeeping for the continuous-batching engine.

Host-side, pure-python: a :class:`Request` record per served sequence, a
FIFO :class:`RequestQueue` with (simulated or wall-clock) arrival ticks,
a :class:`SlotAllocator` free list handing out decode-lane slots, and a
:class:`BlockAllocator` free list over the paged KV block pool (see
:mod:`repro.serving.cache` for the device-side layout it indexes).
"""
from __future__ import annotations

import bisect
import dataclasses

import numpy as np

from repro.serving.sampling import SamplingParams


@dataclasses.dataclass
class Request:
    """One generation request and its lifecycle stats.

    ``sampling`` is the frozen per-request recipe (default: greedy) and
    ``stop_tokens`` the set of token ids that end the sequence early (the
    stop token itself is kept as the final token).  The engine fills in
    everything below ``arrival_tick``: the routed expert, the decoded
    tokens (the first one comes from the prefill logits, like the
    one-shot ``generate`` path), the finish reason (``"stop_token"`` or
    ``"length"``), and tick/wall timestamps for latency accounting.
    """
    uid: int
    prompt: np.ndarray                  # (L,) int32
    max_new_tokens: int
    sampling: SamplingParams = SamplingParams()
    stop_tokens: frozenset = frozenset()
    arrival_tick: int = 0

    expert: int = -1
    replica: int = 0                    # which replica of the expert served it
    tokens: list = dataclasses.field(default_factory=list)
    finish_reason: str = ""             # "stop_token" | "length" once done
    route_tick: int = -1                # tick the router scored the prefix
    admit_tick: int = -1                # tick a decode lane was acquired
    finish_tick: int = -1
    t_first: float = -1.0               # seconds from run start to first token
    t_done: float = -1.0

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        self.stop_tokens = frozenset(int(t) for t in self.stop_tokens)

    @property
    def done(self) -> bool:
        return self.finish_tick >= 0

    @property
    def queue_ticks(self) -> int:
        """Ticks spent waiting between arrival and lane admission.

        0 until a lane is actually acquired — ``admit_tick`` is still the
        -1 sentinel before then and the difference would be garbage.
        """
        if self.admit_tick < 0:
            return 0
        return self.admit_tick - self.arrival_tick


class RequestQueue:
    """Arrival-ordered queue; requests become visible at their tick.

    Kept sorted by ``arrival_tick`` on push (stable for equal ticks), so
    submission order does not have to match simulated arrival order — a
    late-submitted early arrival cannot head-of-line-block."""

    def __init__(self):
        self._q: list[Request] = []

    def push(self, req: Request) -> None:
        bisect.insort(self._q, req, key=lambda r: r.arrival_tick)

    def pop_arrived(self, tick: int) -> list[Request]:
        n = bisect.bisect_right(self._q, tick, key=lambda r: r.arrival_tick)
        out, self._q = self._q[:n], self._q[n:]
        return out

    def next_arrival(self) -> int | None:
        return self._q[0].arrival_tick if self._q else None

    def __len__(self) -> int:
        return len(self._q)


class SlotAllocator:
    """LIFO free list over ``n`` decode-lane slots."""

    def __init__(self, n: int):
        self.n = n
        self._free = list(range(n - 1, -1, -1))   # pop() hands out slot 0 first

    def alloc(self) -> int | None:
        return self._free.pop() if self._free else None

    def free(self, slot: int) -> None:
        if not 0 <= slot < self.n or slot in self._free:
            raise ValueError(f"bad free of slot {slot}")
        self._free.append(slot)

    @property
    def n_free(self) -> int:
        return len(self._free)


class BlockAllocator:
    """LIFO free list over ``n`` KV-pool blocks with atomic group alloc.

    A lane's whole block reservation is taken with :meth:`alloc_n` (all
    or nothing — a partially admitted request could deadlock the pool)
    and returned with :meth:`free_n` when the lane finishes.  ``free`` of
    a block that is not currently allocated raises, so scheduler bugs
    surface as exceptions instead of silent cache corruption.
    """

    def __init__(self, n: int):
        self.n = n
        self._free = list(range(n - 1, -1, -1))   # pop() hands out block 0 first
        self._owned: set[int] = set()
        self.peak_in_use = 0

    def alloc(self) -> int | None:
        got = self.alloc_n(1)
        return got[0] if got else None

    def alloc_n(self, k: int) -> list[int] | None:
        """Take ``k`` blocks atomically; None (and no change) if short."""
        if k < 0:
            raise ValueError(f"alloc_n({k})")
        if len(self._free) < k:
            return None
        got = [self._free.pop() for _ in range(k)]
        self._owned.update(got)
        self.peak_in_use = max(self.peak_in_use, len(self._owned))
        return got

    def free(self, block: int) -> None:
        if block not in self._owned:
            raise ValueError(f"bad free of block {block}")
        self._owned.remove(block)
        self._free.append(block)

    def free_n(self, blocks) -> None:
        for b in blocks:
            self.free(int(b))

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_in_use(self) -> int:
        return len(self._owned)
