"""Live replica autoscaling: policy + deterministic decision logic.

PR 6 proved the paper's no-talk premise makes hot-expert replication
free — replicas share nothing, tokens are placement-invariant — but
left replica counts operator-chosen.  This module closes that gap: a
:class:`ScalePolicy` describes *when* capacity should track the routing
distribution, and :class:`Autoscaler` turns per-slot load observations
into scale decisions the frontend applies between ticks.

Everything here is **deterministic and side-effect free**: the
autoscaler sees only ``(tick, loads)`` and returns actions; the
frontend owns the actual spawn/quiesce machinery (see
``ServeFrontend._autoscale`` and the "Autoscaling" section of
``serving/README.md``).  That split keeps the policy unit-testable
without a transport and keeps token identity trivially safe — tokens
are a pure function of ``(seed, uid, step)``, so *when* replicas come
and go cannot change a single token (the fuzz oracles in
``tests/test_serving_autoscale.py`` extend the placement-invariance
invariant to time-varying placements).

The signal is **pressure**: an expert's total in-flight load minus its
lane capacity, i.e. requests that are queued behind a full decode
batch.  Sustained positive pressure means TTFT is queue-bound and a
replica would help; a replica at zero load for a sustained stretch is
pure capacity waste.  Hysteresis (consecutive-evaluation counts) and a
per-expert cooldown keep the loop from flapping.

No jax imports — the control plane stays light.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ScalePolicy:
    """When to spawn or retire replicas.  All units are frontend ticks.

    ``up_pressure``      — queued-beyond-capacity requests that count an
                           expert as overloaded this evaluation.
    ``up_ticks``         — consecutive overloaded evaluations before a
                           scale-up fires (hysteresis against bursts).
    ``down_idle_ticks``  — consecutive zero-load evaluations of one
                           replica before it is retired.
    ``cooldown_ticks``   — minimum ticks between scale operations on the
                           same expert (lets the last action take effect
                           before the next is judged).
    ``min_replicas``     — never retire below this many live replicas.
    ``max_replicas``     — never grow past this many (live + warming).
    ``every``            — evaluate every N frontend ticks (decisions
                           and idle/pressure streaks advance only on
                           evaluation ticks, so behaviour is a pure
                           function of the tick sequence — deterministic
                           for tests).
    """
    up_pressure: int = 1
    up_ticks: int = 2
    down_idle_ticks: int = 8
    cooldown_ticks: int = 16
    min_replicas: int = 1
    max_replicas: int = 4
    every: int = 1

    def validate(self) -> "ScalePolicy":
        if self.up_pressure < 1:
            raise ValueError(f"up_pressure must be >= 1, got "
                             f"{self.up_pressure}")
        if self.up_ticks < 1 or self.down_idle_ticks < 1:
            raise ValueError("up_ticks and down_idle_ticks must be >= 1")
        if self.cooldown_ticks < 0:
            raise ValueError(f"cooldown_ticks must be >= 0, got "
                             f"{self.cooldown_ticks}")
        if self.min_replicas < 1:
            raise ValueError(f"min_replicas must be >= 1, got "
                             f"{self.min_replicas}")
        if self.max_replicas < self.min_replicas:
            raise ValueError(f"max_replicas {self.max_replicas} < "
                             f"min_replicas {self.min_replicas}")
        if self.every < 1:
            raise ValueError(f"every must be >= 1, got {self.every}")
        return self


@dataclasses.dataclass(frozen=True)
class ScaleEvent:
    """One applied scale operation, as reported in ``run()``'s
    ``autoscale.events``: ``action`` is ``"up"`` (replica entered
    admission) or ``"down"`` (replica fully drained and released);
    ``tick`` is the frontend tick it took effect."""
    tick: int
    action: str
    expert: int
    replica: int
    reason: str = ""

    def to_dict(self) -> dict:
        return {"tick": self.tick, "action": self.action,
                "expert": self.expert, "replica": self.replica,
                "reason": self.reason}


@dataclasses.dataclass(frozen=True)
class SlotLoad:
    """One admissible replica's instantaneous load, as the frontend's
    sender-side tracker sees it (queued + occupied lanes)."""
    slot: int
    load: int


class Autoscaler:
    """Pure decision logic: feed it ``observe`` once per evaluation
    tick, apply the actions it returns.

    The frontend reports, per expert: the live (admissible) slots with
    their loads, plus how many replicas are *warming* (spawned, not yet
    admissible — they count toward capacity and toward ``max_replicas``
    so the loop never double-fires while a spawn is in flight).
    Actions are ``("up", expert)`` and ``("down", expert, slot)`` — at
    most one per expert per evaluation.
    """

    def __init__(self, policy: ScalePolicy, n_experts: int,
                 lanes_per_replica: int):
        self.policy = policy.validate()
        self.n_experts = int(n_experts)
        self.lanes = int(lanes_per_replica)
        self._hot = [0] * self.n_experts         # consecutive overloads
        self._idle: dict[int, int] = {}          # slot -> consecutive idles
        self._last_op = [None] * self.n_experts  # tick of last action

    def _cooled(self, e: int, tick: int) -> bool:
        last = self._last_op[e]
        return last is None or tick - last >= self.policy.cooldown_ticks

    def note_adopted(self, expert: int, slot: int, tick: int) -> None:
        """The frontend adopted a warmed replica into admission.

        Re-stamps the expert's cooldown at the tick the capacity
        actually *arrived* (the ``up`` decision may be many ticks old —
        a process spawn warms for seconds) and starts the new member
        with a clean idle streak.  Without this, a slot that spent its
        own cooldown warming could be idle-retired moments after it
        joins, before any admission has had a chance to route to it.
        """
        self._last_op[expert] = tick
        self._idle[slot] = 0

    def observe(self, tick: int, loads_by_expert: dict,
                warming_by_expert: dict) -> list:
        """One evaluation: returns the actions to apply now.

        ``loads_by_expert``   — expert -> list[SlotLoad] (live slots).
        ``warming_by_expert`` — expert -> count of in-flight spawns.
        Call only on evaluation ticks (``tick % policy.every == 0`` is
        the frontend's job); streak counters advance per call.
        """
        pol = self.policy
        actions: list = []
        for e in range(self.n_experts):
            live = loads_by_expert.get(e, [])
            warming = int(warming_by_expert.get(e, 0))
            capacity = (len(live) + warming) * self.lanes
            pressure = sum(s.load for s in live) - capacity
            self._hot[e] = self._hot[e] + 1 if pressure >= pol.up_pressure \
                else 0
            # idle streaks per live slot; a slot that disappeared
            # (retired/dead) drops out of the dict next sweep
            for s in live:
                self._idle[s.slot] = self._idle.get(s.slot, 0) + 1 \
                    if s.load == 0 else 0
            if self._hot[e] >= pol.up_ticks and self._cooled(e, tick) \
                    and len(live) + warming < pol.max_replicas:
                actions.append(("up", e))
                self._last_op[e] = tick
                self._hot[e] = 0
                continue                    # one action per expert per eval
            if len(live) > pol.min_replicas and self._cooled(e, tick):
                ripe = [s.slot for s in live
                        if self._idle.get(s.slot, 0) >= pol.down_idle_ticks]
                if ripe:
                    # retire the highest slot: lowest replica indices are
                    # the "base" capacity, so growth and shrink are LIFO
                    victim = max(ripe)
                    actions.append(("down", e, victim))
                    self._last_op[e] = tick
                    self._idle.pop(victim, None)
        live_slots = {s.slot for live in loads_by_expert.values()
                      for s in live}
        self._idle = {s: n for s, n in self._idle.items() if s in live_slots}
        return actions
