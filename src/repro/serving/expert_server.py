"""Self-contained per-expert serving loop (the paper's no-talk premise).

The paper's architecture (§1, App. A.4) never requires experts to talk
to each other: the router's prefix scores are the only cross-expert
traffic, at training time and at inference.  :class:`ExpertServer` is
that property made structural on the serving side — ONE expert's
continuous-batching decode loop with **its own tick clock** and a narrow
message API:

  * :meth:`ExpertServer.enqueue` takes a serializable
    :class:`repro.serving.transport.RequestMsg`;
  * :meth:`ExpertServer.tick` runs one admission + decode pass and
    returns the :class:`repro.serving.transport.TokenDeltaMsg` records
    it emitted;
  * :attr:`ExpertServer.busy` is THE idle predicate (pending work or an
    active lane) — the frontend and the transports reuse it instead of
    re-deriving it;
  * :meth:`ExpertServer.stats` snapshots counters as a
    :class:`repro.serving.transport.StatsMsg`.

No reference to the router, to other experts, or to a global tick
barrier exists here: a hot expert can be ticked a thousand times while
an idle one is never ticked at all, and tokens cannot change — the
sampler is counter-based (``fold_in(fold_in(PRNGKey(seed), uid), step)``,
see :mod:`repro.serving.sampling`), so a request's stream is a pure
function of ``(seed, uid, step)`` plus its own prompt.  The clock is
synchronized forward to the sender's tick on :meth:`enqueue` (never
backward), so queue-wait accounting stays on one timeline even though
every server ticks independently.

The per-expert device state is exactly what the old engine kept per
expert: a paged block-pool KV cache (:mod:`repro.serving.cache`), host
free lists over lanes and pool blocks (:mod:`repro.serving.scheduler`),
per-lane sampling operand arrays, and the jitted prefill / decode /
insert programs (shared across in-process servers through an lru cache
keyed on the frozen configs).
"""
from __future__ import annotations

import dataclasses
import functools
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import base as cfglib
from repro.models import model as modellib
from repro.serving import cache as cachelib
from repro.serving import sampling as samplib
from repro.serving.sampling import SamplingParams
from repro.serving.scheduler import (BlockAllocator, PrefixCache, Request,
                                     SlotAllocator)
from repro.serving.transport import RequestMsg, StatsMsg, TokenDeltaMsg

PAD_SAFE_KINDS = (cfglib.ATTN, cfglib.ATTN_SHARED)
TRANSPORTS = ("loopback", "process", "tcp")


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Shape/scheduling knobs (all static: they define the compiled shapes)."""
    lanes_per_expert: int = 4     # fixed decode-batch width per expert
    max_len: int = 128            # per-lane KV budget (prompt + new tokens)
    prefix_len: int = 32          # router scoring prefix M
    route_batch: int = 8          # router calls are padded to this many rows
    min_prefill_bucket: int = 16  # smallest power-of-2 prompt bucket
    block_size: int = 16          # tokens per paged KV block
    pool_blocks: int = 0          # KV blocks per expert; 0 -> lanes*max_len/bs
    decode_impl: str = "auto"     # paged decode kernel: auto|jnp|pallas
                                  # (auto follows the expert cfg's use_pallas)
    prefill_impl: str = "auto"    # admission prefill: auto|jnp|pallas select
                                  # the fused paged prefill (attention + in-
                                  # place pool KV landing, no slab/insert);
                                  # auto follows the expert cfg's use_pallas
                                  # on fused-capable (pure full-attention)
                                  # archs and falls back to the dense slab +
                                  # insert scatter elsewhere
    transport: str = "loopback"   # expert backend: loopback|process|tcp
    registry: str = ""            # tcp only: HOST:PORT of the discovery
                                  # registry the worker fleet registered with
    net_timeout_s: float = 60.0   # tcp only: connect/read timeout per op
    net_poll_ms: int = 20         # tcp only: long-poll wait per tick
    prefix_cache: bool = True     # share full prompt-prefix KV blocks
    prefill_chunk_tokens: int = 0  # per-tick suffix-prefill token budget on
                                   # the shared-prefix path (0 = unlimited)


def bucket_len(n: int, min_bucket: int, max_len: int) -> int:
    """Prompt-length bucket: ``min_bucket`` doubled until >= n, capped at
    ``max_len``.  Monotone in ``n``, so admission batches can pad to the
    largest bucket among their members."""
    if min_bucket < 1:
        raise ValueError(f"min_bucket must be >= 1, got {min_bucket}")
    b = min_bucket
    while b < n:
        b *= 2
    return min(b, max_len)


@dataclasses.dataclass(frozen=True)
class ServingShapes:
    """Derived facts an (expert cfg, engine cfg) pair pins down."""
    pad_safe: bool                # right-padded bucketed prefill is exact
    has_pool: bool                # any full-attention layer -> paged KV pool
    lane_blocks: int              # block-table width (max_len / block_size)
    pool_blocks: int              # resolved pool size per expert
    dcfg: object                  # decode-side expert config (use_pallas flip)
    decode_impl: str              # "jnp" | "pallas" after `auto` resolution
    pcfg: object                  # prefill-side expert config (use_pallas flip)
    prefill_impl: str             # "slab" | "jnp" | "pallas" after resolution
    prefix_ok: bool               # prefix-sharing KV cache is usable


def resolve_shapes(ecfg, eng: EngineConfig) -> ServingShapes:
    """Validate the config pair and derive the serving shapes.

    Called by :class:`ExpertServer` and by the frontend — the frontend
    runs it eagerly so a bad config raises at construction time even
    when the expert servers live in other processes.
    """
    if not ecfg.causal:
        raise ValueError("serving needs a causal (decoder) expert config")
    if eng.min_prefill_bucket < 1:
        raise ValueError(f"min_prefill_bucket must be >= 1, "
                         f"got {eng.min_prefill_bucket}")
    if eng.decode_impl not in ("auto", "jnp", "pallas"):
        raise ValueError(f"decode_impl must be 'auto', 'jnp' or "
                         f"'pallas', got {eng.decode_impl!r}")
    if eng.prefill_impl not in ("auto", "jnp", "pallas"):
        raise ValueError(f"prefill_impl must be 'auto', 'jnp' or "
                         f"'pallas', got {eng.prefill_impl!r}")
    if eng.transport not in TRANSPORTS:
        raise ValueError(f"transport must be one of {TRANSPORTS}, "
                         f"got {eng.transport!r}")
    if eng.transport == "tcp" and not eng.registry:
        raise ValueError(
            "transport='tcp' needs EngineConfig.registry='host:port' — "
            "the address of the repro.serving.net.registry the expert "
            "workers registered with")
    if eng.net_timeout_s <= 0:
        raise ValueError(f"net_timeout_s must be positive, "
                         f"got {eng.net_timeout_s}")
    if eng.net_poll_ms < 1:
        raise ValueError(f"net_poll_ms must be >= 1, "
                         f"got {eng.net_poll_ms}")
    if eng.prefill_chunk_tokens < 0:
        raise ValueError(f"prefill_chunk_tokens must be >= 0, "
                         f"got {eng.prefill_chunk_tokens}")
    # prompt-length bucketing pads on the right; that is exact for full
    # attention (causal mask hides the future) but would pollute rotating-
    # window KV buffers and recurrent (SSM/xLSTM) states, so those archs
    # fall back to exact-length prefill compiles
    pad_safe = all(k in PAD_SAFE_KINDS for k in ecfg.layer_pattern)
    # only full-attention layers hold paged KV; pure-recurrent /
    # sliding-window experts never touch the block pool
    has_pool = any(k in cachelib.POOL_KINDS for k in ecfg.layer_pattern)
    L, M, bs = eng.lanes_per_expert, eng.max_len, eng.block_size
    if has_pool and M % bs:
        raise ValueError(f"max_len {M} not a multiple of block_size {bs}")
    lane_blocks = -(-M // bs)
    pool = eng.pool_blocks or L * lane_blocks
    if has_pool and pool < lane_blocks:
        raise ValueError(
            f"pool_blocks {pool} cannot hold one max-size request "
            f"({lane_blocks} blocks) — the queue would deadlock")
    # decode_impl overrides use_pallas for the jitted decode programs only
    # (paged-attention read + fused sampling epilogue); prefill has its own
    # override below
    dcfg = ecfg if eng.decode_impl == "auto" else \
        ecfg.replace(use_pallas=eng.decode_impl == "pallas")
    # fused paged prefill (attention + in-place pool landing in one
    # program, insert_requests dead) needs every layer's prefill KV to
    # live in the paged pool AND right-padded bucketing to be exact —
    # i.e. a pure full-attention pattern.  `auto` silently keeps the
    # legacy slab + scatter elsewhere; an explicit jnp/pallas ask on a
    # non-capable arch is a configuration error, not a fallback.
    fused_capable = pad_safe and has_pool
    if eng.prefill_impl == "auto":
        prefill_impl = ("pallas" if ecfg.use_pallas else "jnp") \
            if fused_capable else "slab"
    elif not fused_capable:
        raise ValueError(
            f"prefill_impl={eng.prefill_impl!r} needs a fused-capable "
            f"expert arch (every layer full-attention so all prefill KV "
            f"is paged and bucket padding is exact); "
            f"layer_pattern={ecfg.layer_pattern!r} is not — use "
            f"prefill_impl='auto' for the dense slab + insert fallback")
    else:
        prefill_impl = eng.prefill_impl
    pcfg = ecfg if prefill_impl == "slab" else \
        ecfg.replace(use_pallas=prefill_impl == "pallas")
    # the hit path skips prefill for cached blocks and replays only the
    # suffix through the decode scatter — sound only when every layer's
    # prefix state lives in the paged pool (pure full-attention archs);
    # sliding-window / recurrent layers would lack their prefix state
    prefix_ok = bool(eng.prefix_cache and pad_safe and has_pool
                     and all(k in cachelib.POOL_KINDS
                             for k in ecfg.layer_pattern))
    return ServingShapes(pad_safe=pad_safe, has_pool=has_pool,
                         lane_blocks=lane_blocks, pool_blocks=pool,
                         dcfg=dcfg,
                         decode_impl="pallas" if dcfg.use_pallas else "jnp",
                         pcfg=pcfg, prefill_impl=prefill_impl,
                         prefix_ok=prefix_ok)


@functools.lru_cache(maxsize=None)
def _jit_fns(ecfg, dcfg, pcfg, max_len: int, prefill_impl: str):
    """Jitted expert-side serving kernels, shared across server instances.

    Keyed on the (hashable, frozen) configs so fuzz suites building many
    servers reuse one compile cache instead of re-jitting per instance.
    ``dcfg`` / ``pcfg`` are the decode- and prefill-side expert configs —
    identical to ``ecfg`` except possibly ``use_pallas``, so
    ``EngineConfig.decode_impl`` / ``prefill_impl`` flip each side's
    kernels independently.  The decode programs fuse the sampling
    epilogue (:mod:`repro.kernels.sample_epilogue`): tokens come straight
    out of the jitted step and the ``(lanes, vocab)`` logits stay an
    internal intermediate — on the Pallas dispatch they never leave VMEM.
    (Router scoring lives with the frontend — an expert server never sees
    the router.)
    """
    ep_impl = "pallas" if dcfg.use_pallas else "jnp"

    def decode_and_sample(p, toks, pos, ci, bt, c, keys, steps, temps,
                          top_ks, top_ps):
        return modellib.decode_and_sample(
            p, dcfg, {"tokens": toks, "positions": pos, "cache_index": ci,
                      "block_tables": bt}, c,
            keys=keys, steps=steps, temps=temps, top_ks=top_ks,
            top_ps=top_ps, epilogue_impl=ep_impl)

    def decode_greedy(p, toks, pos, ci, bt, c):
        # all-greedy ticks skip the sampler entirely (its sort/softmax
        # work per lane per token is pure waste when every temp is 0);
        # both programs compile once, so mode flips never recompile
        return modellib.decode_greedy(
            p, dcfg, {"tokens": toks, "positions": pos, "cache_index": ci,
                      "block_tables": bt}, c, epilogue_impl=ep_impl)

    decode = jax.jit(decode_and_sample)
    decode_g = jax.jit(decode_greedy)
    prefill = jax.jit(
        lambda p, toks, last: modellib.prefill(
            p, ecfg, {"tokens": toks}, cache_len=max_len, last_index=last))
    if prefill_impl == "slab":
        prefill_fused = None
    else:
        # fused paged prefill: attention + in-place pool KV landing in one
        # program; the caches go in and come back with the bucket written
        prefill_fused = jax.jit(
            lambda p, toks, last, c, bt, tl: modellib.prefill_paged(
                p, pcfg, {"tokens": toks}, c, block_tables=bt,
                true_lens=tl, last_index=last))
    insert = jax.jit(functools.partial(cachelib.insert_requests, ecfg))
    clear = jax.jit(functools.partial(cachelib.clear_block_pos, ecfg))
    return (decode, decode_g, prefill, prefill_fused, insert,
            samplib.sample_tokens_jit, clear)


class ExpertServer:
    """One expert's continuous-batching loop behind a message API.

    ``enqueue(RequestMsg)`` / ``tick() -> list[TokenDeltaMsg]`` /
    ``busy`` / ``stats()`` — everything else (device caches, free lists,
    per-lane sampling operands, the tick clock) is private to this
    server.  See the module docstring for the asynchrony contract.
    """

    def __init__(self, ecfg, params, eng: EngineConfig = EngineConfig()):
        shapes = resolve_shapes(ecfg, eng)
        self.ecfg, self.eng, self.params = ecfg, eng, params
        self.pad_safe = shapes.pad_safe
        self.has_pool = shapes.has_pool
        self.lane_blocks = shapes.lane_blocks
        self.pool_blocks = shapes.pool_blocks
        self.decode_impl = shapes.decode_impl
        self.prefill_impl = shapes.prefill_impl
        L, M, bs = eng.lanes_per_expert, eng.max_len, eng.block_size
        # per-(block, layer) decode read traffic: k + v + slot positions
        self._pool_layers = sum(k in cachelib.POOL_KINDS
                                for k in ecfg.layer_pattern)
        self._block_read_bytes = bs * (
            2 * ecfg.n_kv_heads * ecfg.resolved_head_dim
            * np.dtype(ecfg.compute_dtype).itemsize
            + np.dtype(np.int32).itemsize)
        # per-(token, layer) prefill KV write traffic (k + v, pos separate)
        self._tok_write_bytes = (2 * ecfg.n_kv_heads * ecfg.resolved_head_dim
                                 * np.dtype(ecfg.compute_dtype).itemsize)
        # per-(lane, tick) epilogue logits row the unfused path round-trips
        self._logit_row_bytes = (ecfg.vocab_size
                                 * np.dtype(ecfg.logit_dtype).itemsize)
        self.caches = cachelib.init_paged_caches(ecfg, L, self.pool_blocks,
                                                 bs, M)
        self.alloc = SlotAllocator(L)
        self.balloc = BlockAllocator(self.pool_blocks)
        self.prefix = PrefixCache(self.balloc, bs) if shapes.prefix_ok \
            else None
        self._prefix_bypass = False   # warmup: synthetic prompts stay uncached
        self.pending: deque = deque()
        self.tok = np.zeros(L, np.int32)     # last emitted token per lane
        self.pos = np.zeros(L, np.int32)     # next decode position per lane
        self.active = np.zeros(L, bool)
        # prefix-sharing hit lanes: admitted but still replaying their novel
        # prompt suffix through the decode scatter, one position per fill
        # call (multi-tick when EngineConfig.prefill_chunk_tokens caps the
        # per-tick budget); promoted to active when the last prompt
        # position's logits produce the first token
        self.filling = np.zeros(L, bool)
        self.fill_pos = np.zeros(L, np.int32)  # next prompt position to feed
        self.req: list = [None] * L          # slot -> local Request | None
        self.block_tables = np.full((L, self.lane_blocks), -1, np.int32)
        self.blocks: list = [[] for _ in range(L)]  # slot -> reserved blocks
        # per-lane sampling state, fed straight into the jitted decode+sample
        self.keys = np.zeros((L, 2), np.uint32)     # request RNG roots
        self.steps = np.zeros(L, np.int32)          # next token counter
        self.temp = np.zeros(L, np.float32)         # 0 = greedy
        self.topk = np.zeros(L, np.int32)           # 0 = disabled
        self.topp = np.ones(L, np.float32)          # 1 = disabled
        # this server's own clock: advanced by tick(), pulled forward
        # (never back) by enqueue() to the sender's timestamp
        self.clock = 0
        self.n_served = 0
        self.decode_calls = 0
        self.prefill_calls = 0
        self.occupied_lane_steps = 0  # sum of active lanes over decode calls
        self.queue_wait_ticks = 0     # sum over admissions of admit - enqueue
        # KV read traffic of the paged decode path vs the gathered view it
        # replaced (bookkeeping from reserved-block counts, impl-independent)
        self.paged_read_bytes = 0
        self.gathered_read_bytes = 0
        self.prefix_hit_blocks = 0    # blocks acquired from the prefix cache
        self.prefill_tokens_saved = 0  # prompt tokens never (re)prefilled
        # admission KV write traffic, both paths priced on every prefill
        # (bookkeeping like the read counters, impl-independent): fused =
        # bucket KV + full-span pos once; slab = dense (K, max_len) slab
        # materialization + the insert scatter's full-span overwrite
        self.prefill_write_fused_bytes = 0
        self.prefill_write_slab_bytes = 0
        # (lanes, vocab) logits HBM round-trip between decode and sampler;
        # zero on the fused-Pallas epilogue where the row stays in VMEM
        self.epilogue_logits_bytes = 0
        (self._decode_fn, self._decode_greedy_fn, self._prefill_fn,
         self._prefill_fused_fn, self._insert_fn, self._sample_fn,
         self._clear_fn) = \
            _jit_fns(ecfg, shapes.dcfg, shapes.pcfg, M, shapes.prefill_impl)

    # -- the narrow API ----------------------------------------------------
    @property
    def busy(self) -> bool:
        """THE idle predicate: queued work, an active decode lane, or a
        hit lane still replaying its prompt suffix."""
        return (bool(self.pending) or bool(self.active.any())
                or bool(self.filling.any()))

    @property
    def cached_blocks(self) -> int:
        """Pool blocks currently held by the prefix cache."""
        return self.prefix.n_blocks if self.prefix is not None else 0

    def enqueue(self, msg: RequestMsg) -> None:
        """Accept one request; FIFO behind whatever is already queued."""
        self.clock = max(self.clock, msg.enqueue_tick)
        self.pending.append(Request(
            uid=msg.uid, prompt=msg.prompt,
            max_new_tokens=msg.max_new_tokens, sampling=msg.sampling,
            stop_tokens=msg.stop_tokens, arrival_tick=msg.enqueue_tick))

    def recall_pending(self, only=None) -> list[int]:
        """Scale-down quiesce: hand queued-but-unadmitted requests back.

        Drains ``pending`` (restricted to the uids in ``only`` when
        given — a shared network worker recalls one frontend's requests
        without touching another's) and returns the drained uids for
        the caller to re-route.  Requests already in a lane are NOT
        touched: they have emitted tokens, so they finish here; a
        pending request has emitted nothing, so re-routing it elsewhere
        is invisible to its token stream (counter-based sampling keys on
        ``(seed, uid, step)``, never on placement).
        """
        keep, out = deque(), []
        for req in self.pending:
            if only is None or req.uid in only:
                out.append(req.uid)
            else:
                keep.append(req)
        self.pending = keep
        return out

    def tick(self) -> list[TokenDeltaMsg]:
        """One pass of this server's clock: admit, then decode.

        Independent of every other server — callers may tick a busy
        server as often as they like and never tick an idle one; ticking
        with no work is a harmless no-op (the clock still advances).
        """
        out: list[TokenDeltaMsg] = []
        self._admit(out)
        self._fill(out)
        self._decode(out)
        self.clock += 1
        return out

    def stats(self) -> StatsMsg:
        return StatsMsg(
            n_served=self.n_served, decode_calls=self.decode_calls,
            prefill_calls=self.prefill_calls,
            occupied_lane_steps=self.occupied_lane_steps,
            queue_wait_ticks=self.queue_wait_ticks,
            paged_read_bytes=self.paged_read_bytes,
            gathered_read_bytes=self.gathered_read_bytes,
            peak_blocks=self.balloc.peak_in_use,
            pending=len(self.pending),
            active_lanes=int(self.active.sum()) + int(self.filling.sum()),
            prefix_hit_blocks=self.prefix_hit_blocks,
            prefill_tokens_saved=self.prefill_tokens_saved,
            cached_blocks=self.cached_blocks,
            prefill_write_fused_bytes=self.prefill_write_fused_bytes,
            prefill_write_slab_bytes=self.prefill_write_slab_bytes,
            epilogue_logits_bytes=self.epilogue_logits_bytes)

    def reset_stats(self) -> None:
        """Zero the run counters (a warmup must not pollute a timed run)."""
        self.n_served = self.decode_calls = self.prefill_calls = 0
        self.occupied_lane_steps = self.queue_wait_ticks = 0
        self.paged_read_bytes = self.gathered_read_bytes = 0
        self.prefix_hit_blocks = self.prefill_tokens_saved = 0
        self.prefill_write_fused_bytes = self.prefill_write_slab_bytes = 0
        self.epilogue_logits_bytes = 0
        self.balloc.peak_in_use = self.balloc.n_in_use

    def sync(self) -> None:
        """Block until every queued device mutation has landed."""
        jax.block_until_ready(self.caches)

    def kv_bytes(self) -> int:
        """Device bytes held by this server's decode caches."""
        return cachelib.kv_cache_bytes(self.caches)

    # -- warmup ------------------------------------------------------------
    def warmup(self, prompt_len: int | None = None, *,
               sampled: bool = True) -> None:
        """Compile every serving shape up front, off the timed path.

        Drives admission/decode with synthetic requests at every
        power-of-two admission width.  ``prompt_len`` selects which
        prefill bucket to warm (defaults to the routing prefix length);
        call again for other buckets.  ``sampled=False`` skips the
        second, sampled warmup pass — a greedy-only deployment then
        never compiles the sampler programs.  The clock and stats are
        restored: synthetic ticks don't advance serving time.
        """
        pl = min(prompt_len or self.eng.prefix_len, self.eng.max_len - 2)
        L = self.eng.lanes_per_expert
        clock0 = self.clock
        # synthetic zero prompts must neither hit nor seed the prefix
        # cache — warmup KV is real data but the repeated prompt would
        # make later identical-prompt traffic read warmup-written blocks
        # the timed run never accounted for
        self._prefix_bypass = True
        try:
            # one greedy pass (argmax-only decode program) and one sampled
            # pass (mixed decode program + per-width sampler) so a live mix
            # of recipes hits only warm compiles
            for temp in (0.0, 1.0) if sampled else (0.0,):
                for k in sorted({min(1 << (b - 1).bit_length(), L)
                                 for b in range(1, L + 1)}):
                    for _ in range(k):
                        self.pending.append(Request(
                            uid=-1, prompt=np.zeros(pl, np.int32),
                            max_new_tokens=2,
                            sampling=SamplingParams(temperature=temp)))
                    while self.busy:
                        self.tick()   # synthetic deltas dropped on the floor
        finally:
            self._prefix_bypass = False
        if self.prefix is not None:
            # compile the novel-block pos-clear scatter (all-scratch = no-op)
            self.caches = self._clear_fn(
                self.caches,
                jnp.full(self.lane_blocks, self.pool_blocks, jnp.int32))
        self.clock = clock0
        self.reset_stats()

    # -- lane lifecycle ----------------------------------------------------
    def _bucket(self, n: int) -> int:
        if not self.pad_safe:
            return n
        return bucket_len(n, self.eng.min_prefill_bucket, self.eng.max_len)

    def _blocks_needed(self, req: Request) -> int:
        """Pool blocks covering every KV write the request will make.

        Positions written: 0..len(prompt)-1 by prefill, then one per fed-
        back token — the final emitted token is never written, so the
        highest position is len(prompt) + max_new - 2.
        """
        if not self.has_pool:
            return 0
        used = len(req.prompt) + req.max_new_tokens - 1
        return -(-used // self.eng.block_size)

    def _count_prefill_write(self, K: int, bucket: int) -> None:
        """Price one admission prefill's pool write traffic, both ways.

        Analytic bookkeeping like the decode read counters (computed from
        shapes, accumulated on every prefill regardless of the dispatched
        path, so any config can report the delta): the fused path writes
        the ``(K, bucket)`` KV once plus the full-span ``pos`` rewrite;
        the slab path materializes a dense ``(K, max_len)`` KV+pos slab
        and then ``insert_requests`` overwrites every reserved slot —
        two full-span writes per admitted group.
        """
        if not self.has_pool:
            return
        M = self.eng.max_len
        pos_b = np.dtype(np.int32).itemsize
        fused = K * (bucket * self._tok_write_bytes + M * pos_b)
        slab = 2 * K * M * (self._tok_write_bytes + pos_b)
        self.prefill_write_fused_bytes += fused * self._pool_layers
        self.prefill_write_slab_bytes += slab * self._pool_layers

    def _count_epilogue(self) -> None:
        """Price one decode call's logits round-trip: the unfused / jnp
        epilogue materializes the ``(lanes, vocab)`` logits buffer in HBM
        for the sampler; the fused Pallas epilogue keeps each row in VMEM
        and writes back ``(lanes,)`` tokens only."""
        if self.decode_impl != "pallas":
            self.epilogue_logits_bytes += \
                self.eng.lanes_per_expert * self._logit_row_bytes

    def _alloc_evicting(self, k: int) -> list[int] | None:
        """``alloc_n`` with LRU eviction of cached-but-unreferenced
        prefix blocks as the fallback under pool pressure."""
        got = self.balloc.alloc_n(k)
        if got is None and self.prefix is not None \
                and self.prefix.evict(k):
            got = self.balloc.alloc_n(k)
        return got

    def _admit(self, out: list[TokenDeltaMsg]) -> None:
        """Drain pending requests into free lanes with one batched prefill.

        FIFO admission: take from the queue head while a decode lane and
        (full-attention archs) enough pool blocks are available.  All
        drained requests share one prefill call padded to the fixed lane
        width and the largest prompt bucket among them (non-pad-safe archs
        prefill one request at a time at exact length), then land in the
        caches via one jitted scatter.

        With the prefix cache on, a request whose leading full blocks are
        cached takes a reference on those pool blocks, reserves only the
        novel remainder, and becomes a *filling* lane: its prompt suffix
        is replayed through the decode scatter by :meth:`_fill` instead
        of joining the batched prefill.  Under pool pressure, LRU
        cached-but-unreferenced blocks are evicted before admission gives
        up.
        """
        batch: list[tuple[Request, int, np.ndarray]] = []
        hits: list[tuple[Request, int, int, list[int]]] = []
        while self.pending and self.alloc.n_free:
            req = self.pending[0]
            shared: list[int] = []
            if self.prefix is not None and not self._prefix_bypass:
                shared = self.prefix.acquire(req.prompt)
            blocks = self._alloc_evicting(self._blocks_needed(req)
                                          - len(shared))
            if blocks is None:
                if shared:                  # roll back the acquired refs
                    self.balloc.free_n(shared)
                break                       # pool full: wait, keep FIFO order
            self.pending.popleft()
            slot = self.alloc.alloc()
            row = np.full(self.lane_blocks, -1, np.int32)
            row[:len(shared)] = shared
            row[len(shared):len(shared) + len(blocks)] = blocks
            self.blocks[slot] = shared + blocks
            if shared:
                self.block_tables[slot] = row
                hits.append((req, slot, len(shared), blocks))
            else:
                batch.append((req, slot, row))

        bs = self.eng.block_size
        for req, slot, n_hit, novel in hits:
            # lane acquired now — admit/queue-wait accounting is the time
            # to a lane, not to the (possibly chunked) first token
            req.admit_tick = self.clock
            self.queue_wait_ticks += self.clock - req.arrival_tick
            self.req[slot] = req
            self.filling[slot] = True
            self.fill_pos[slot] = n_hit * bs
            self.tok[slot] = self.pos[slot] = 0
            # real sampler operands at counter 0: the final fill call's
            # in-program sample IS the request's first token
            self.keys[slot] = (np.zeros(2, np.uint32) if req.sampling.greedy
                               else samplib.request_key(req.sampling.seed,
                                                        req.uid))
            self.steps[slot] = 0
            self.temp[slot], self.topk[slot], self.topp[slot] = \
                req.sampling.temperature, req.sampling.top_k, \
                req.sampling.top_p
            # the novel blocks skip insert_requests' full-span overwrite,
            # so a previous tenant's stale positions must be masked before
            # the first read through this lane's table
            ids = np.full(self.lane_blocks, self.pool_blocks, np.int32)
            ids[:len(novel)] = novel
            self.caches = self._clear_fn(self.caches, jnp.asarray(ids))
            self.prefix_hit_blocks += n_hit
            self.prefill_tokens_saved += n_hit * bs
        if not batch:
            return

        L = self.eng.lanes_per_expert
        lens = np.array([len(r.prompt) for r, _, _ in batch])
        # per-request sampling operands for the first token (counter 0);
        # greedy requests keep a zero key and never touch the RNG
        keys = np.stack([np.zeros(2, np.uint32) if r.sampling.greedy
                         else samplib.request_key(r.sampling.seed, r.uid)
                         for r, _, _ in batch])
        temps = np.array([r.sampling.temperature for r, _, _ in batch],
                         np.float32)
        topks = np.array([r.sampling.top_k for r, _, _ in batch], np.int32)
        topps = np.array([r.sampling.top_p for r, _, _ in batch], np.float32)

        def first_tokens(logits, idx):
            """Sample token 0 for batch members ``idx`` from their prefill
            logits rows (padding rows ride along as greedy no-ops)."""
            n = len(idx)
            if not (temps[idx] > 0.0).any():          # all greedy: plain argmax
                return np.asarray(jnp.argmax(logits[:n], -1))
            pad = logits.shape[0] - n
            return np.asarray(self._sample_fn(
                logits,
                np.concatenate([keys[idx], np.zeros((pad, 2), np.uint32)]),
                np.zeros(n + pad, np.int32),
                np.concatenate([temps[idx], np.zeros(pad, np.float32)]),
                np.concatenate([topks[idx], np.zeros(pad, np.int32)]),
                np.concatenate([topps[idx], np.ones(pad, np.float32)])))[:n]

        def run_prefill(group: np.ndarray) -> np.ndarray:
            """One prefill call for batch members ``group``: build the
            padded operands, dispatch slab+insert or the fused paged
            program, account the write traffic, sample first tokens.
            Shared by the bucketed drain and the exact-length fallback so
            dispatch / ``prefill_calls`` / byte accounting cannot drift
            between them."""
            if self.pad_safe:
                # K is the group width padded to the next power of two
                # (bounded compile count, no full-lane-width compute for
                # single admissions), bucket = the largest prompt bucket
                K = min(1 << (len(group) - 1).bit_length(), L)
                bucket = max(self._bucket(int(lens[i])) for i in group)
            else:
                K, bucket = 1, int(lens[group[0]])
            toks = np.zeros((K, bucket), np.int32)
            last = np.zeros(K, np.int32)
            rows = np.full((K, self.lane_blocks), -1, np.int32)
            slots = np.full(K, L, np.int32)       # out-of-range -> dropped
            true = np.zeros(K, np.int32)
            for j, i in enumerate(group):
                req, slot, row = batch[i]
                toks[j, :lens[i]] = req.prompt
                last[j] = lens[i] - 1
                rows[j], slots[j], true[j] = row, slot, lens[i]
            if self.prefill_impl == "slab":
                logits, rcache = self._prefill_fn(
                    self.params, jnp.asarray(toks), jnp.asarray(last))
                self.caches = self._insert_fn(self.caches, rcache, rows,
                                              slots, true)
            else:
                logits, self.caches = self._prefill_fused_fn(
                    self.params, jnp.asarray(toks), jnp.asarray(last),
                    self.caches, jnp.asarray(rows), jnp.asarray(true))
            self.prefill_calls += 1
            self._count_prefill_write(K, bucket)
            return first_tokens(logits, group)

        if self.pad_safe:
            firsts = run_prefill(np.arange(len(batch)))
        else:
            # recurrent / sliding-window states can't take right-padding:
            # exact-length compiles, one request per call, same helper
            firsts = np.concatenate(
                [run_prefill(np.array([i])) for i in range(len(batch))])

        for i, (req, slot, row) in enumerate(batch):
            first = int(firsts[i])
            req.tokens.append(first)
            req.admit_tick = self.clock
            self.queue_wait_ticks += self.clock - req.arrival_tick
            self.block_tables[slot] = row
            self.tok[slot], self.pos[slot] = first, lens[i]
            self.active[slot], self.req[slot] = True, req
            self.keys[slot] = keys[i]
            self.steps[slot] = 1
            self.temp[slot], self.topk[slot], self.topp[slot] = \
                temps[i], topks[i], topps[i]
            if self.prefix is not None and not self._prefix_bypass:
                # prompt KV is fully written (insert overwrites every slot
                # of the reserved blocks): the full prompt blocks are now
                # shareable; decode writes start past them
                self.prefix.register(req.prompt, row)
            done = req.max_new_tokens == 1 or first in req.stop_tokens
            reason = self._retire(slot) if done else ""
            out.append(TokenDeltaMsg(
                uid=req.uid, token=first, index=0, done=done,
                tick=self.clock, admit_tick=self.clock,
                finish_reason=reason))

    def _retire(self, slot: int) -> str:
        """Retire a lane: stats, then free its KV blocks and slot NOW —
        the same tick — so the next admission can hand them out.
        Returns the finish reason for the final delta."""
        req = self.req[slot]
        req.finish_tick = self.clock
        req.finish_reason = ("stop_token" if req.tokens
                             and req.tokens[-1] in req.stop_tokens
                             else "length")
        self.active[slot] = False
        self.filling[slot] = False
        self.fill_pos[slot] = 0
        self.req[slot] = None
        self.tok[slot] = self.pos[slot] = 0
        self.block_tables[slot] = -1
        self.keys[slot] = 0
        self.steps[slot] = 0
        self.temp[slot], self.topk[slot], self.topp[slot] = 0.0, 0, 1.0
        self.balloc.free_n(self.blocks[slot])
        self.blocks[slot] = []
        self.alloc.free(slot)
        self.n_served += 1
        return req.finish_reason

    def _fill(self, out: list[TokenDeltaMsg]) -> None:
        """Replay hit lanes' novel prompt suffixes through the decode
        scatter, one position per lane per call.

        Each call feeds every filling lane its next prompt token at its
        next position (active and free lanes ride along masked at -1), so
        the KV lands in the lane's novel blocks while the shared prefix
        blocks are only ever read — copy-on-write by construction.  The
        call that feeds a lane's final prompt position produces the
        request's first token (in-program sample at counter 0, same
        computation the batched-prefill path runs on its logits row) and
        promotes the lane to active decode in the same tick, matching the
        no-hit admission cadence.

        ``EngineConfig.prefill_chunk_tokens`` caps the prompt tokens fed
        per tick (0 = unlimited): a long novel suffix then spreads over
        multiple ticks instead of stalling this tick's decode behind an
        unbounded replay.  At least one call always runs, so progress is
        guaranteed even with a budget below the filling-lane count.
        Chunking cannot change tokens — the sampler is counter-based and
        KV writes are position-addressed.
        """
        if not self.filling.any():
            return
        L = self.eng.lanes_per_expert
        budget = self.eng.prefill_chunk_tokens
        fed = 0
        while self.filling.any():
            lanes = np.nonzero(self.filling)[0]
            pos = np.full(L, -1, np.int32)
            toks = np.zeros(L, np.int32)
            for slot in lanes:
                p = int(self.fill_pos[slot])
                pos[slot] = p
                toks[slot] = int(self.req[slot].prompt[p])
            if (self.temp > 0.0).any():
                nxt, self.caches = self._decode_fn(
                    self.params, jnp.asarray(toks[:, None]),
                    jnp.asarray(pos[:, None]), jnp.asarray(pos),
                    jnp.asarray(self.block_tables), self.caches,
                    self.keys, self.steps, self.temp, self.topk, self.topp)
            else:
                nxt, self.caches = self._decode_greedy_fn(
                    self.params, jnp.asarray(toks[:, None]),
                    jnp.asarray(pos[:, None]), jnp.asarray(pos),
                    jnp.asarray(self.block_tables), self.caches)
            self.decode_calls += 1
            self.occupied_lane_steps += len(lanes)
            self._count_epilogue()
            if self.has_pool:
                live = sum(len(self.blocks[s]) for s in lanes)
                per_layer = self._block_read_bytes * self._pool_layers
                self.paged_read_bytes += live * per_layer
                self.gathered_read_bytes += L * self.lane_blocks * per_layer
            nxt = np.asarray(nxt).astype(np.int32)
            fed += len(lanes)
            for slot in lanes:
                req = self.req[slot]
                p = int(self.fill_pos[slot])
                if p + 1 < len(req.prompt):
                    self.fill_pos[slot] = p + 1
                    continue
                first = int(nxt[slot])
                req.tokens.append(first)
                self.filling[slot] = False
                self.fill_pos[slot] = 0
                self.active[slot] = True
                self.tok[slot], self.pos[slot] = first, len(req.prompt)
                self.steps[slot] = 1
                if self.prefix is not None and not self._prefix_bypass:
                    # every prompt position of this lane is now written
                    # (shared blocks were, novel ones just got filled)
                    self.prefix.register(req.prompt, self.blocks[slot])
                done = req.max_new_tokens == 1 or first in req.stop_tokens
                reason = self._retire(int(slot)) if done else ""
                out.append(TokenDeltaMsg(
                    uid=req.uid, token=first, index=0, done=done,
                    tick=self.clock, admit_tick=req.admit_tick,
                    finish_reason=reason))
            if budget > 0 and fed >= budget:
                break

    def _decode(self, out: list[TokenDeltaMsg]) -> None:
        if not self.active.any():
            return
        # inactive lanes decode at position -1: every KV slot is masked for
        # them and their writes are clamped to the pool scratch block (or
        # land as -1 markers in lane buffers), so a free lane can ride
        # along in the fixed-shape batch at zero correctness cost (its
        # sampler params sit at greedy defaults, so no RNG runs for it)
        pos = np.where(self.active, self.pos, -1).astype(np.int32)
        if (self.temp > 0.0).any():
            nxt, self.caches = self._decode_fn(
                self.params, jnp.asarray(self.tok[:, None]),
                jnp.asarray(pos[:, None]), jnp.asarray(pos),
                jnp.asarray(self.block_tables), self.caches,
                self.keys, self.steps, self.temp, self.topk, self.topp)
        else:
            nxt, self.caches = self._decode_greedy_fn(
                self.params, jnp.asarray(self.tok[:, None]),
                jnp.asarray(pos[:, None]), jnp.asarray(pos),
                jnp.asarray(self.block_tables), self.caches)
        self.decode_calls += 1
        self.occupied_lane_steps += int(self.active.sum())
        self._count_epilogue()
        if self.has_pool:
            # bytes the paged kernel reads this tick (each active lane's
            # reserved blocks) vs what the old gathered (lanes, max_len)
            # view always read — the bench's measurable win
            live = sum(len(self.blocks[s]) for s in np.nonzero(self.active)[0])
            per_layer = self._block_read_bytes * self._pool_layers
            self.paged_read_bytes += live * per_layer
            self.gathered_read_bytes += \
                self.eng.lanes_per_expert * self.lane_blocks * per_layer
        nxt = np.asarray(nxt).astype(np.int32)
        for slot in np.nonzero(self.active)[0]:
            req = self.req[slot]
            tok = int(nxt[slot])
            req.tokens.append(tok)
            self.tok[slot] = tok
            self.pos[slot] += 1
            self.steps[slot] += 1
            done = (len(req.tokens) >= req.max_new_tokens
                    or tok in req.stop_tokens)
            reason = self._retire(int(slot)) if done else ""
            out.append(TokenDeltaMsg(
                uid=req.uid, token=tok, index=len(req.tokens) - 1,
                done=done, tick=self.clock, finish_reason=reason))
