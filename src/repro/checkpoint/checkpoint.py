"""Pytree checkpointing (npz-based, per-expert / per-router files).

SmallTalk's checkpoint layout is naturally sharded: each expert (and each
router) checkpoints independently on its own node group — there is no
global barrier, matching the paper's no-communication training story.
"""
from __future__ import annotations

import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

SEP = "::"


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(_path_str(p) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:
            flat["BF16" + SEP + key] = arr.view(np.uint16)
        else:
            flat[key] = arr
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def save(path: str, tree: Any) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez(path if path.endswith(".npz") else path + ".npz",
             **_flatten(tree))


def restore(path: str, template: Any) -> Any:
    """Restore into the structure of ``template`` (shapes must match)."""
    if not path.endswith(".npz"):
        path += ".npz"
    with np.load(path) as data:
        arrays = {}
        for k in data.files:
            if k.startswith("BF16" + SEP):
                arrays[k[len("BF16" + SEP):]] = data[k].view(jnp.bfloat16)
            else:
                arrays[k] = data[k]
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path_keys, leaf in paths:
        key = SEP.join(_path_str(p) for p in path_keys)
        if key not in arrays:
            raise KeyError(f"checkpoint missing {key!r}")
        arr = arrays[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
        leaves.append(jnp.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)
