"""ShapeDtypeStruct input stand-ins + shardings for every
(architecture x input-shape x step) combination — the shannon/kernels
pattern: weak-type-correct, shardable, zero device allocation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import INPUT_SHAPES
from repro.configs.base import InputShape
from repro.models import common, model as modellib
from repro.parallel import sharding as shlib

I32 = jnp.int32
F32 = jnp.float32


def _sd(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def step_kind(cfg, shape: InputShape) -> str:
    """train | prefill | decode — with encoder archs mapping decode->skip."""
    if shape.kind == "train":
        return "train"
    if shape.kind == "decode" and not cfg.has_decode:
        return "skip"
    if shape.name == "long_500k" and not cfg.subquadratic:
        return "skip"
    return shape.kind


def batch_struct(cfg, shape: InputShape, kind: str) -> dict:
    """ShapeDtypeStruct batch for one step kind."""
    B, S = shape.global_batch, shape.seq_len
    cdt = common.dt(cfg.compute_dtype)
    if kind == "decode":
        b: dict = {"tokens": _sd((B, 1), I32), "cache_index": _sd((), I32)}
        if cfg.rope_variant == "mrope":
            b["positions"] = _sd((B, 1, 3), I32)
        else:
            b["positions"] = _sd((B, 1), I32)
        return b
    # train / prefill consume the full sequence
    if cfg.input_mode == "tokens":
        b = {"tokens": _sd((B, S), I32)}
    elif cfg.input_mode == "embeddings":
        b = {"embeds": _sd((B, S, cfg.input_embed_dim), cdt),
             "frame_mask": _sd((B, S), jnp.bool_)}
    else:  # multimodal
        b = {"tokens": _sd((B, S), I32),
             "image_embeds": _sd((B, cfg.n_image_tokens,
                                  cfg.input_embed_dim), cdt),
             "image_positions": _sd((B, cfg.n_image_tokens), I32),
             "positions": _sd((B, S, 3), I32)}
    if kind == "train":
        b["labels"] = _sd((B, S), I32)
    return b


def param_struct(cfg) -> dict:
    """Param tree as ShapeDtypeStructs via eval_shape (no allocation)."""
    return jax.eval_shape(
        lambda k: modellib.init_params(k, cfg), jax.random.PRNGKey(0))


def opt_struct(params_struct, opt_cfg) -> dict:
    from repro.optim import adamw
    return jax.eval_shape(lambda p: adamw.init_state(p, opt_cfg),
                          params_struct)


def input_specs(cfg, shape_name: str, kind: str | None = None,
                opt_cfg=None):
    """Returns (kind, args: dict of structs) for the step to lower."""
    shape = INPUT_SHAPES[shape_name]
    kind = kind or step_kind(cfg, shape)
    if kind == "skip":
        return kind, {}
    out = {"batch": batch_struct(cfg, shape, kind),
           "params": param_struct(cfg)}
    if kind == "train":
        assert opt_cfg is not None
        out["opt_state"] = opt_struct(out["params"], opt_cfg)
    if kind == "decode":
        out["caches"] = modellib.cache_specs(cfg, shape.global_batch,
                                             shape.seq_len)
    return kind, out


def shardings_for(cfg, kind: str, args: dict, mesh, *, fsdp: bool,
                  batch_axis="data", mode: str = "tp"):
    """PartitionSpec trees matching ``args``.

    mode="tp": Megatron tensor parallelism over 'model' (+ optional ZeRO).
    mode="dp": model axis joins data (small archs); weights ZeRO-sharded
    over (data x model), batch over both axes.
    """
    if mode == "dp":
        ba = (("pod",) if "pod" in mesh.axis_names and
              isinstance(batch_axis, tuple) else ()) + ("data", "model")
        sh: dict = {"params": shlib.param_specs_dp(args["params"], mesh),
                    "batch": shlib.batch_specs(args["batch"], mesh, ba)}
        if "opt_state" in args:
            sh["opt_state"] = shlib.opt_state_specs(
                sh["params"], mesh, fsdp=True, params_shape=args["params"],
                axes=("data", "model"))
        if "caches" in args:
            sh["caches"] = shlib.cache_tree_specs(args["caches"], mesh)
        return sh
    sh = {"params": shlib.param_specs(args["params"], mesh, fsdp=fsdp),
          "batch": shlib.batch_specs(args["batch"], mesh, batch_axis)}
    if "opt_state" in args:
        sh["opt_state"] = shlib.opt_state_specs(
            sh["params"], mesh, fsdp=fsdp,
            params_shape=args["params"])
    if "caches" in args:
        sh["caches"] = shlib.cache_tree_specs(args["caches"], mesh)
    return sh
