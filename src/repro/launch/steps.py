"""Step functions lowered by the dry-run and used by train.py/serve.py."""
from __future__ import annotations

import jax

from repro.models import model as modellib
from repro.optim import AdamWConfig, adamw


def default_opt_cfg(cfg) -> AdamWConfig:
    return AdamWConfig(peak_lr=5e-4, warmup_steps=3000, total_steps=256_000,
                       opt_dtype=cfg.opt_dtype)


def build_train_step(cfg, opt_cfg: AdamWConfig):
    def loss_fn(params, batch):
        return modellib.loss_and_metrics(params, cfg, batch)
    return adamw.make_train_step(loss_fn, opt_cfg)


def build_prefill_step(cfg):
    def prefill_step(params, batch):
        return modellib.prefill(params, cfg, batch)
    return prefill_step


def build_decode_step(cfg):
    def decode_step(params, batch, caches):
        return modellib.decode_step(params, cfg, batch, caches)
    return decode_step


def build_mixture_train_step(cfg, opt_cfg: AdamWConfig):
    """Stacked-expert step: vmap over leading expert axis (sharded 'pod').

    spmd_axis_name pins every internal sharding constraint / shard_map to
    the pod axis so manual-SPMD regions (xLSTM cells, MoE buffers) do not
    force pod replication."""
    step = build_train_step(cfg, opt_cfg)
    return jax.vmap(step, spmd_axis_name="pod")
