import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (arch x input-shape) step on the
production mesh, with zero real allocation (ShapeDtypeStruct stand-ins).

Proves the distribution config is coherent and extracts the roofline
inputs: cost_analysis FLOPs/bytes, per-device collective bytes (parsed from
the partitioned HLO), and memory_analysis.

Modes:
  dense     — the arch itself; on the multi-pod mesh the pod axis is extra
              data parallelism (baseline: gradient all-reduce crosses pods).
  smalltalk — the paper: 2 experts stacked on the pod axis via vmap; the
              compiled HLO must contain NO pod-crossing collectives.

Usage:
  python -m repro.launch.dryrun --arch gemma2-27b --shape train_4k
  python -m repro.launch.dryrun --all --out results/dryrun
  python -m repro.launch.dryrun --arch qwen2-1.5b --shape train_4k \
      --multi-pod --mode smalltalk
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import INPUT_SHAPES, get_config
from repro.configs.archs import ASSIGNED_NAMES, FSDP_ARCHS
from repro.launch import hlo_cost, specs as speclib, steps as steplib
from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16,
                               make_production_mesh)


def _named(tree, mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P))


def _stack_struct(tree, e):
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct((e,) + s.shape, s.dtype), tree)


def _stack_spec(tree):
    return jax.tree_util.tree_map(
        lambda s: P("pod", *s), tree, is_leaf=lambda x: isinstance(x, P))


def arg_bytes_per_device(args, shardings, mesh) -> float:
    """Lower bound on resident bytes/device from the input shardings."""
    total = 0.0
    ms = dict(zip(mesh.axis_names, mesh.devices.shape))
    for key in args:
        leaves = jax.tree_util.tree_leaves(args[key])
        sp = jax.tree_util.tree_leaves(shardings[key],
                                       is_leaf=lambda x: isinstance(x, P))
        for leaf, spec in zip(leaves, sp):
            shards = 1
            for ax in spec:
                if ax is None:
                    continue
                for a in ((ax,) if isinstance(ax, str) else ax):
                    shards *= ms.get(a, 1)
            total += leaf.size * leaf.dtype.itemsize / shards
    return total


def lower_one(arch: str, shape_name: str, *, multi_pod: bool = False,
              mode: str = "dense", verbose: bool = True,
              unroll: bool = True, hlo_path: str | None = None,
              sharding_mode: str = "tp") -> dict:
    # unroll=True exposes every layer to HLO cost analysis (XLA counts a
    # while body once, not x trip-count); scan_layers=True is the real
    # training configuration (bounded HLO) — both must compile.
    cfg = get_config(arch).replace(scan_layers=not unroll)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    fsdp = arch in FSDP_ARCHS
    opt_cfg = steplib.default_opt_cfg(cfg)
    kind, args = speclib.input_specs(cfg, shape_name, opt_cfg=opt_cfg)
    rec = {"arch": arch, "shape": shape_name, "kind": kind, "mode": mode,
           "mesh": "x".join(map(str, mesh.devices.shape)),
           "chips": n_chips, "fsdp": fsdp}
    if kind == "skip":
        rec["status"] = "SKIP"
        rec["why"] = ("encoder-only: no decode step" if not cfg.has_decode
                      else "full-attention arch: long_500k needs sub-quadratic")
        return rec
    if mode == "smalltalk" and not multi_pod:
        raise ValueError("smalltalk mode needs the multi-pod mesh")
    if mode == "smalltalk" and kind != "train":
        rec["status"] = "SKIP"
        rec["why"] = "smalltalk pod-sharding demo is a training-step property"
        return rec

    batch_axis = ("pod", "data") if (multi_pod and mode == "dense") else "data"
    sh = speclib.shardings_for(cfg, kind, args, mesh, fsdp=fsdp,
                               batch_axis=batch_axis, mode=sharding_mode)
    rec["sharding_mode"] = sharding_mode

    if mode == "smalltalk":
        # stack E=2 experts over the pod axis: each pod trains its own
        e = mesh.devices.shape[0]
        for key in ("params", "opt_state", "batch"):
            args[key] = _stack_struct(args[key], e)
            sh[key] = _stack_spec(sh[key])
        # per-expert batch within a pod uses the data axis only
        step = steplib.build_mixture_train_step(cfg, opt_cfg)
    elif kind == "train":
        step = steplib.build_train_step(cfg, opt_cfg)
    elif kind == "prefill":
        step = steplib.build_prefill_step(cfg)
    else:
        step = steplib.build_decode_step(cfg)

    metrics_spec = {"ce": P(), "aux": P(), "tokens": P(), "loss": P(),
                    "lr": P(), "gnorm": P()}
    if mode == "smalltalk":
        metrics_spec = _stack_spec(metrics_spec)
    if kind == "train":
        in_tree = (args["params"], args["opt_state"], args["batch"])
        in_sh = (_named(sh["params"], mesh), _named(sh["opt_state"], mesh),
                 _named(sh["batch"], mesh))
        out_sh = (_named(sh["params"], mesh), _named(sh["opt_state"], mesh),
                  _named(metrics_spec, mesh))
    elif kind == "prefill":
        in_tree = (args["params"], args["batch"])
        in_sh = (_named(sh["params"], mesh), _named(sh["batch"], mesh))
        out_sh = None
    else:
        in_tree = (args["params"], args["batch"], args["caches"])
        in_sh = (_named(sh["params"], mesh), _named(sh["batch"], mesh),
                 _named(sh["caches"], mesh))
        out_sh = None

    t0 = time.time()
    from repro.parallel import act_sharding
    da = ("pod", "data") if (multi_pod and mode == "dense") else None
    with mesh, act_sharding.use(mesh, dp_only=(sharding_mode == "dp"),
                                data_axes=da):
        jitted = (jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)
                  if out_sh is not None else
                  jax.jit(step, in_shardings=in_sh))
        lowered = jitted.lower(*in_tree)
        compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t0, 1)

    # ---- memory ---------------------------------------------------------
    try:
        ma = compiled.memory_analysis()
        rec["memory_analysis"] = {
            k: int(getattr(ma, k)) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(ma, k)}
    except Exception as ex:  # CPU backend may not support it
        rec["memory_analysis"] = {"error": str(ex)[:200]}
    rec["arg_bytes_per_device"] = arg_bytes_per_device(args, sh, mesh)

    # ---- cost (XLA's own, for reference; undercounts while bodies) ------
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        rec["cost_analysis"] = {k: float(v) for k, v in ca.items()
                                if isinstance(v, (int, float))
                                and k in ("flops", "bytes accessed",
                                          "transcendentals")}
    except Exception as ex:
        rec["cost_analysis"] = {"error": str(ex)[:200]}

    # ---- trip-count-aware HLO analysis (flops/bytes/collectives) --------
    text = compiled.as_text()
    if hlo_path:
        import gzip
        with gzip.open(hlo_path, "wt") as f:
            f.write(text)
    pod_boundary = 256 if multi_pod else None
    cost = hlo_cost.analyze(text, pod_boundary=pod_boundary)
    rec["hlo_cost"] = {
        "flops": cost.flops,
        "hbm_bytes": cost.hbm_bytes,
        "collective_bytes_per_device": cost.coll_bytes,
        "pod_crossing_bytes": cost.coll_pod_bytes,
        "collective_count": cost.coll_count,
        "by_kind": cost.coll_by_kind,
    }
    rec["top_mem"] = cost.top("mem_by_tag", 12)
    rec["top_flops"] = cost.top("flops_by_tag", 8)

    # ---- roofline terms (per-device quantities / per-chip rates) --------
    rec["roofline"] = {
        "compute_s": cost.flops / PEAK_FLOPS_BF16,
        "memory_s": cost.hbm_bytes / HBM_BW,
        "collective_s": cost.coll_bytes / ICI_BW,
    }
    dom = max(rec["roofline"], key=rec["roofline"].get)
    rec["roofline"]["dominant"] = dom
    rec["status"] = "OK"
    if verbose:
        r = rec["roofline"]
        print(f"[{rec['mesh']}|{mode}] {arch} x {shape_name} ({kind}): "
              f"compile {rec['compile_s']}s  "
              f"compute {r['compute_s']*1e3:.2f}ms  "
              f"memory {r['memory_s']*1e3:.2f}ms  "
              f"collective {r['collective_s']*1e3:.2f}ms  -> {dom}"
              + (f"  pod-crossing {cost.coll_pod_bytes/1e6:.1f}MB"
                 if multi_pod else ""))
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--mode", default="dense", choices=["dense", "smalltalk"])
    ap.add_argument("--all", action="store_true",
                    help="all assigned archs x shapes on the single-pod mesh")
    ap.add_argument("--out", default=None, help="directory for JSON records")
    ap.add_argument("--scan", action="store_true",
                    help="keep lax.scan over layers (bounded HLO; "
                         "cost analysis undercounts loop bodies)")
    args = ap.parse_args()

    runs = []
    if args.all:
        for arch in ASSIGNED_NAMES:
            for shape in INPUT_SHAPES:
                runs.append((arch, shape, args.multi_pod, args.mode))
    else:
        runs.append((args.arch, args.shape, args.multi_pod, args.mode))

    records = []
    for arch, shape, mp, mode in runs:
        tag = f"{arch}-{shape}-{'mp' if mp else 'sp'}-{mode}"
        try:
            hlo_path = None
            if args.out:
                os.makedirs(args.out, exist_ok=True)
                hlo_path = os.path.join(args.out, tag + ".hlo.gz")
            rec = lower_one(arch, shape, multi_pod=mp, mode=mode,
                            unroll=not args.scan, hlo_path=hlo_path)
        except Exception:
            rec = {"arch": arch, "shape": shape, "mode": mode,
                   "status": "FAIL", "error": traceback.format_exc()[-2000:]}
            print(f"FAIL {arch} x {shape}:\n{rec['error']}")
        records.append(rec)
        if args.out:
            with open(os.path.join(args.out, tag + ".json"), "w") as f:
                json.dump(rec, f, indent=1)
    ok = sum(r["status"] == "OK" for r in records)
    sk = sum(r["status"] == "SKIP" for r in records)
    print(f"\n{ok} OK / {sk} SKIP / {len(records) - ok - sk} FAIL")
    if any(r["status"] == "FAIL" for r in records):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
