"""SmallTalk serving CLI: a thin front-end over the continuous-batching
engine in :mod:`repro.serving`.

The serving path is the paper's inference story (§2.2): score the request
prefix with all E tiny routers, ``argmax`` (no balancing), then run ONLY
the selected expert — 1/E of mixture parameters active, router overhead
<3% FLOPs.  The engine keeps each expert's fixed decode lanes full by
admitting and evicting requests mid-decode (``--baseline`` runs the old
one-shot serial per-group loop instead, for comparison).  Generation is
controlled per request by ``SamplingParams`` — ``--temperature`` /
``--top-k`` / ``--top-p`` / ``--sample-seed`` (temperature 0 = greedy)
— and optional ``--stop-tokens`` ids that end a sequence early and hand
its KV blocks to the next queued request the same tick.  ``--transport
process`` runs each expert in its own spawned OS process (own params +
KV pool; the router scores are the only cross-process traffic — the
paper's multi-host story on one machine), and ``--replicas 0:2`` clones
hot expert 0 into two servers with least-loaded admission between them
(the shared engine flags live in :mod:`repro.serving.cli`).
``--autoscale`` lets the engine grow/shrink that replica map live —
backlogged experts gain replicas, idle ones drain and release them —
with tokens provably unchanged (see ``--scale-*`` for the policy).

Usage (demo on synthetic prompts with randomly-initialized weights, or on
checkpoints produced by launch/train.py):
  PYTHONPATH=src python -m repro.launch.serve --preset tiny --requests 8 \
      --ckpt results/train --temperature 0.8 --top-k 40 --stop-tokens 0,1
"""
from __future__ import annotations

import argparse
import os

import jax
import numpy as np

from repro.checkpoint import restore
from repro.core import router as routerlib
from repro.data import SyntheticCorpus
from repro.launch.train import PRESETS
from repro.models import model as modellib
from repro.serving import ServeFrontend, baseline
from repro.serving import cli as servecli


def build_mixture(preset: str, n_experts: int, ckpt: str | None, seed: int = 0):
    """(ecfg, rcfg, expert_params, router_params) for a preset, random or
    restored from a launch/train.py output directory."""
    p = PRESETS[preset]
    ecfg, rcfg = p["expert"], p["router"]
    key = jax.random.PRNGKey(seed)
    router_params = routerlib.init_ensemble(key, rcfg, n_experts)
    expert_params = [modellib.init_params(jax.random.fold_in(key, e), ecfg)
                     for e in range(n_experts)]
    if ckpt:
        router_params = restore(os.path.join(ckpt, "routers"), router_params)
        expert_params = [restore(os.path.join(ckpt, f"expert_{e}"), ep)
                         for e, ep in enumerate(expert_params)]
    return ecfg, rcfg, expert_params, router_params


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=list(PRESETS))
    ap.add_argument("--experts", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prefix-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    servecli.add_engine_args(ap)
    servecli.add_autoscale_args(ap)
    servecli.add_sampling_args(ap)
    ap.add_argument("--arrive-every", type=int, default=2,
                    help="simulated arrival: one request per N ticks")
    ap.add_argument("--stop-tokens", default="",
                    help="comma-separated token ids that end a request "
                         "early (the stop token is kept)")
    ap.add_argument("--ckpt", default=None,
                    help="directory from launch/train.py (else random init)")
    ap.add_argument("--baseline", action="store_true",
                    help="run the old one-shot serial per-group path")
    return ap


def main() -> None:
    args = build_parser().parse_args()
    sampling = servecli.sampling_from_args(args)
    stop_tokens = frozenset(int(t) for t in args.stop_tokens.split(",") if t)

    ecfg, rcfg, expert_params, router_params = build_mixture(
        args.preset, args.experts, args.ckpt)
    corpus = SyntheticCorpus(PRESETS[args.preset]["data"])
    prompts, doms = corpus.sequences(np.arange(args.requests) + 777_000)
    prompts = prompts[:, :max(args.prefix_len, 8)]

    if args.baseline:
        res = baseline.serve_serial(
            ecfg, rcfg, expert_params, router_params, prompts,
            np.full(args.requests, args.new_tokens),
            prefix_len=args.prefix_len, sampling=sampling,
            stop_tokens=stop_tokens)
        print("routes:", res["routes"].tolist(), " domains:", doms.tolist())
        print(f"{res['useful_tokens']} tokens in {res['wall_s']:.2f}s "
              f"({res['wasted_tokens']} decoded then thrown away)")
        for i in range(min(4, args.requests)):
            print(f"req{i} -> expert {res['routes'][i]}: "
                  f"{np.asarray(res['tokens'][i])[:12].tolist()}")
        return

    total = prompts.shape[1] + args.new_tokens
    max_len = -(-total // args.block_size) * args.block_size
    eng = ServeFrontend(ecfg, rcfg, expert_params, router_params,
                        servecli.engine_config_from_args(
                            args, max_len=max_len,
                            prefix_len=args.prefix_len),
                        replicas=args.replicas,
                        scale=servecli.scale_policy_from_args(args))
    with eng:                      # releases worker processes on exit
        for i in range(args.requests):
            eng.submit(prompts[i], args.new_tokens, sampling=sampling,
                       stop_tokens=stop_tokens,
                       arrival_tick=i // max(args.arrive_every, 1))
        res = eng.run()
    print(f"{args.requests} requests, {args.experts} experts, "
          f"{args.lanes} lanes ({res['transport']}): "
          f"{res['useful_tokens']} tokens in "
          f"{res['wall_s']:.2f}s = {res['tokens_per_s']:.1f} tok/s, "
          f"occupancy {res['occupancy']:.2f}, "
          f"mean TTFT {res['mean_ttft_s'] * 1e3:.0f}ms, "
          f"{res['early_stops']} early stops")
    print(f"paged KV: {eng.pool_blocks} blocks/expert x {args.block_size} "
          f"tokens, {res['kv_bytes_per_lane']} B/lane, "
          f"{res['prefill_calls']} prefill calls")
    rb = res["decode_read_bytes"]
    print(f"decode KV reads ({res['decode_impl']}): paged "
          f"{rb['paged_per_tick']} B/tick vs gathered "
          f"{rb['gathered_per_tick']} B/tick")
    ps = res["prefix_sharing"]
    print(f"prefix sharing: {'on' if ps['enabled'] else 'off'}, "
          f"{ps['hit_blocks']} hit blocks, "
          f"{ps['prefill_tokens_saved']} prefill tokens saved, "
          f"{res['n_unadmitted']} never admitted")
    if res.autoscale is not None:
        a = res.autoscale
        print(f"autoscale: {a.scale_ups} up / {a.scale_downs} down, "
              f"peak {a.peak_replicas}, final {a.final_replicas}")
    print("per-expert:", res["per_expert"])
    print("routes:", [r.expert for r in res["requests"]],
          " domains:", doms.tolist())
    for r in res["requests"][:4]:
        print(f"req{r.uid} -> expert {r.expert} "
              f"(queued {r.queue_ticks} ticks, {r.finish_reason}): "
              f"{r.tokens[:12]}")


if __name__ == "__main__":
    main()
