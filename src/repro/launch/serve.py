"""SmallTalk serving: batched requests -> prefix routing -> per-expert
batched prefill + decode.

The serving path is the paper's inference story (§2.2): score the request
prefix with all E tiny routers, ``argmax`` (no balancing), then run ONLY
the selected expert — 1/E of mixture parameters active, router overhead
<3% FLOPs.  Requests routed to the same expert are batched together.

Usage (demo on synthetic prompts with randomly-initialized weights, or on
checkpoints produced by launch/train.py):
  PYTHONPATH=src python -m repro.launch.serve --preset tiny --requests 8 \
      --ckpt results/train
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore
from repro.core import assignment as asg
from repro.core import router as routerlib
from repro.data import SyntheticCorpus
from repro.launch.train import PRESETS
from repro.models import model as modellib


def generate(cfg, params, prompts: jnp.ndarray, n_new: int,
             greedy: bool = True, key=None) -> np.ndarray:
    """Batched prefill + decode loop for one expert."""
    B, S = prompts.shape
    logits, caches = modellib.prefill(params, cfg, {"tokens": prompts},
                                      cache_len=S + n_new)
    outs = []
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    step = jax.jit(lambda p, b, c: modellib.decode_step(p, cfg, b, c))
    for t in range(n_new):
        outs.append(np.asarray(tok[:, 0]))
        lg, caches = step(params, {
            "tokens": tok,
            "positions": jnp.full((B, 1), S + t, jnp.int32),
            "cache_index": jnp.int32(S + t)}, caches)
        tok = jnp.argmax(lg[:, 0], -1)[:, None].astype(jnp.int32)
    return np.stack(outs, 1)                      # (B, n_new)


def serve_batch(ecfg, rcfg, expert_params: list, router_params,
                prompts: np.ndarray, *, prefix_len: int, n_new: int) -> dict:
    """Route a request batch and generate per expert group."""
    t0 = time.time()
    scores = routerlib.ensemble_scores(router_params, rcfg,
                                       jnp.asarray(prompts[:, :prefix_len]))
    eids = np.asarray(asg.argmax_assignment(scores))
    t_route = time.time() - t0
    out = np.zeros((prompts.shape[0], n_new), np.int32)
    per_expert = {}
    for e in np.unique(eids):
        sel = np.nonzero(eids == e)[0]
        t1 = time.time()
        out[sel] = generate(ecfg, expert_params[int(e)],
                            jnp.asarray(prompts[sel]), n_new)
        per_expert[int(e)] = {"n": len(sel), "s": round(time.time() - t1, 2)}
    return {"tokens": out, "routes": eids, "route_s": round(t_route, 3),
            "per_expert": per_expert}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=list(PRESETS))
    ap.add_argument("--experts", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prefix-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--ckpt", default=None,
                    help="directory from launch/train.py (else random init)")
    args = ap.parse_args()

    p = PRESETS[args.preset]
    ecfg, rcfg = p["expert"], p["router"]
    key = jax.random.PRNGKey(0)
    router_params = routerlib.init_ensemble(key, rcfg, args.experts)
    expert_params = [modellib.init_params(jax.random.fold_in(key, e), ecfg)
                     for e in range(args.experts)]
    if args.ckpt:
        router_params = restore(os.path.join(args.ckpt, "routers"),
                                router_params)
        expert_params = [restore(os.path.join(args.ckpt, f"expert_{e}"), ep)
                         for e, ep in enumerate(expert_params)]

    corpus = SyntheticCorpus(p["data"])
    prompts, doms = corpus.sequences(np.arange(args.requests) + 777_000)
    prompts = prompts[:, :max(args.prefix_len, 8)]
    res = serve_batch(ecfg, rcfg, expert_params, router_params, prompts,
                      prefix_len=args.prefix_len, n_new=args.new_tokens)
    print("routes:", res["routes"].tolist(), " domains:", doms.tolist())
    print("routing time:", res["route_s"], "s; per-expert:", res["per_expert"])
    for i in range(min(4, args.requests)):
        print(f"req{i} -> expert {res['routes'][i]}: "
              f"{res['tokens'][i][:12].tolist()}")


if __name__ == "__main__":
    main()
