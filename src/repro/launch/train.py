"""End-to-end SmallTalk LM training driver (paper Algorithm 1).

Runs the full pipeline at a configurable scale:
  1. EM-train E tiny routers (alternating SGD / balanced re-assignment);
  2. segment the corpus with the trained routers (the only communication:
     one f16 score per sequence per router);
  3. train E experts fully independently on their segments;
  4. (optional) train a dense baseline on the same total token budget and
     report both perplexities on held-out data.

Presets:
  tiny  — seconds on CPU (CI smoke);
  small — ~100M-class mixture, a few hundred steps (the deliverable (b)
          end-to-end driver; takes a while on CPU, sized for one host);
  paper — the paper's 335M x 4-expert configuration (needs real TPUs).

Usage:
  PYTHONPATH=src python -m repro.launch.train --preset tiny --dense-baseline
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro.checkpoint import save
from repro.configs import get_config
from repro.configs.base import ModelConfig
from repro.core import em, mixture as mixlib
from repro.data import DataConfig, Stream, SyntheticCorpus, make_lm_batch
from repro.models import model as modellib
from repro.optim import AdamWConfig

PRESETS = {
    "tiny": dict(
        expert=ModelConfig(name="tiny-expert", n_layers=2, d_model=128,
                           n_heads=4, n_kv_heads=4, d_ff=512, vocab_size=256,
                           ffn_type="gelu", loss_chunk=64),
        router=ModelConfig(name="tiny-router", n_layers=2, d_model=64,
                           n_heads=4, n_kv_heads=4, d_ff=256, vocab_size=256,
                           ffn_type="gelu", loss_chunk=64),
        data=DataConfig(vocab_size=256, seq_len=64, n_domains=4),
        em=dict(em_iters=3, chunk_size=2048, steps_per_iter=40, batch_size=32,
                prefix_len=32, lr=3e-3),
        expert_steps=150, batch_size=16, lr=1e-3, shard_n=8192,
    ),
    "small": dict(
        expert=ModelConfig(name="small-expert", n_layers=8, d_model=512,
                           n_heads=8, n_kv_heads=8, d_ff=2048,
                           vocab_size=2048, ffn_type="gelu", loss_chunk=128),
        router=ModelConfig(name="small-router", n_layers=4, d_model=96,
                           n_heads=4, n_kv_heads=4, d_ff=384, vocab_size=2048,
                           ffn_type="gelu", loss_chunk=128),
        data=DataConfig(vocab_size=2048, seq_len=256, n_domains=8),
        em=dict(em_iters=4, chunk_size=6144, steps_per_iter=60, batch_size=32,
                prefix_len=64, lr=2e-3),
        expert_steps=300, batch_size=16, lr=8e-4, shard_n=32768,
    ),
    "paper": dict(
        expert="smalltalk-335m", router="router-4m",
        data=DataConfig(vocab_size=32000, seq_len=1024, n_domains=16),
        em=dict(em_iters=8, chunk_size=45_000, steps_per_iter=1000,
                batch_size=32, prefix_len=256, lr=1e-4),
        expert_steps=256_000, batch_size=128, lr=5e-4, shard_n=2_000_000,
    ),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=list(PRESETS))
    ap.add_argument("--experts", type=int, default=4)
    ap.add_argument("--dense-baseline", action="store_true")
    ap.add_argument("--outdir", default="results/train")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    p = PRESETS[args.preset]
    ecfg = get_config(p["expert"]) if isinstance(p["expert"], str) else p["expert"]
    rcfg = get_config(p["router"]) if isinstance(p["router"], str) else p["router"]
    corpus = SyntheticCorpus(p["data"])
    key = jax.random.PRNGKey(args.seed)
    os.makedirs(args.outdir, exist_ok=True)
    t0 = time.time()

    # ---- Stage 1: routers (EM) ------------------------------------------
    emcfg = em.EMConfig(n_experts=args.experts, **p["em"])
    state = em.train_routers(corpus, rcfg, emcfg, key)
    print("router EM history:")
    for h in state.history:
        print("  ", h)
    save(os.path.join(args.outdir, "routers"), state.router_params)

    # ---- Stage 2: shard the corpus ---------------------------------------
    assign, doms, comm = em.shard_corpus(state, rcfg, corpus, p["shard_n"],
                                         emcfg)
    print(f"corpus sharded: purity={em.domain_purity(assign, doms, args.experts):.3f} "
          f"load={np.bincount(assign, minlength=args.experts).tolist()} "
          f"comm={1e-6 * (state.comm_bytes + comm):.3f} MB total")

    # ---- Stage 3: experts (independent) ----------------------------------
    opt = AdamWConfig(peak_lr=p["lr"], warmup_steps=max(p["expert_steps"] // 10, 1),
                      total_steps=p["expert_steps"], clip_norm=1.0,
                      opt_dtype=ecfg.opt_dtype)
    mix = mixlib.train_mixture_experts(
        ecfg, corpus, assign, p["expert_steps"], p["batch_size"], opt, key,
        router_state=state, prefix_len=emcfg.prefix_len, router_cfg=rcfg)
    for e, params in enumerate(mix.expert_params):
        save(os.path.join(args.outdir, f"expert_{e}"), params)
    print(f"experts trained ({time.time() - t0:.0f}s)")

    # ---- Eval -------------------------------------------------------------
    held = corpus.sequences(np.arange(10_000_000, 10_000_000 + 512))
    batch = make_lm_batch(*held)
    ppl_mix = mixlib.mixture_eval_ppl(mix, batch)
    report = {"preset": args.preset, "experts": args.experts,
              "ppl_mixture": ppl_mix,
              "router_comm_MB": 1e-6 * (state.comm_bytes + comm),
              "em_history": state.history,
              "expert_params": modellib.param_count(mix.expert_params[0]),
              "router_params": modellib.param_count(
                  jax.tree_util.tree_map(lambda x: x[0], state.router_params))}
    print(f"MIXTURE ppl = {ppl_mix:.3f}")

    if args.dense_baseline:
        dense = modellib.init_params(key, ecfg)
        optd = AdamWConfig(peak_lr=p["lr"],
                           warmup_steps=max(p["expert_steps"] // 10, 1),
                           total_steps=args.experts * p["expert_steps"],
                           clip_norm=1.0)
        dense, _ = mixlib.train_expert(
            ecfg, dense, Stream(corpus, p["batch_size"]),
            args.experts * p["expert_steps"], optd)
        ppl_dense = mixlib.dense_eval_ppl(ecfg, dense, batch)
        report["ppl_dense"] = ppl_dense
        print(f"DENSE   ppl = {ppl_dense:.3f}  "
              f"(mixture better by {100 * (1 - ppl_mix / ppl_dense):.1f}%)")

    with open(os.path.join(args.outdir, "report.json"), "w") as f:
        json.dump(report, f, indent=1)
    print("report ->", os.path.join(args.outdir, "report.json"))


if __name__ == "__main__":
    main()
