"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state; the dry-run entrypoint is the only place that forces the 512-device
host platform.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """single-pod: (data=16, model=16) = 256 chips (one v5e pod slice);
    multi-pod:  (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(*, multi_pod: bool = False):
    """Reduced mesh for CPU tests: (2,2) / (2,2,2) on 8 host devices."""
    shape = (2, 2, 2) if multi_pod else (2, 2)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


# TPU v5e roofline constants (per chip)
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # bytes/s
ICI_BW = 50e9                     # bytes/s/link
