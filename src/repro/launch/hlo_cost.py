"""Trip-count-aware HLO cost analyzer.

XLA's built-in ``cost_analysis`` counts a ``while`` body ONCE, so any model
that scans over layers (ours do — that is how paper-scale HLO stays
compilable) has its FLOPs/bytes/collectives undercounted by ~n_layers.
This module parses ``compiled.as_text()`` (the SPMD-partitioned,
per-device module) and computes:

  * flops            — 2 * prod(result) * prod(contracting dims) per dot,
                       multiplied through while-loop trip counts;
  * hbm_bytes        — TPU-style fusion model: every *top-level* op writes
                       its result once and reads its operands once; fusion
                       internals are free (the CPU backend's
                       ``bytes accessed`` counts unfused internals and
                       overestimates TPU HBM traffic by >10x);
  * collective bytes — ring-factored per-device traffic (see hlo_stats),
                       also trip-count-multiplied, with pod-crossing split.

Validated against XLA cost_analysis on fully-unrolled modules (equal trip
counts of 1): flops agree to <1%.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from functools import lru_cache

import numpy as np

from repro.launch import hlo_stats

_DTYPE_BYTES = hlo_stats._DTYPE_BYTES

_COMP_HEADER = re.compile(r"^(ENTRY\s+)?%?([\w\.\-~]+)\s*\(.*\)\s*->\s*\S.*\{\s*$")
_TRIP_CFG = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_OP_ASSIGN = re.compile(r"^\s+(?:ROOT\s+)?%?([\w\.\-~]+)\s*=\s*(.*)$")
_KIND_CALL = re.compile(r"^([\w\-]+)\((.*)$")
_SHAPE = re.compile(r"(\w+?)\[([\d,]*)\]")
_ATTR_CALLS = re.compile(r"calls=%?([\w\.\-~]+)")
_ATTR_BODY = re.compile(r"body=%?([\w\.\-~]+)")
_ATTR_COND = re.compile(r"condition=%?([\w\.\-~]+)")
_ATTR_TOAPPLY = re.compile(r"to_apply=%?([\w\.\-~]+)")
_ATTR_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_CONST_INT = re.compile(r"constant\((\d+)\)")

_MEM_SKIP = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "while", "call", "conditional", "after-all", "custom-call",
             "partition-id", "replica-id", "iota"}
_CALL_KINDS = {"while", "call", "conditional", "fusion"}


def _shape_dims(tok: str):
    m = _SHAPE.match(tok)
    if not m:
        return None, []
    dt, dims = m.groups()
    return dt, [int(d) for d in dims.split(",") if d]


def _bytes_of(type_str: str) -> int:
    total = 0
    for m in _SHAPE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


@dataclass
class Op:
    name: str
    type_str: str
    kind: str
    rest: str          # operand list + attrs (raw remainder of line)


@dataclass
class Computation:
    name: str
    ops: list = field(default_factory=list)
    shapes: dict = field(default_factory=dict)   # %name -> type string

    @property
    def ops_by_name(self) -> dict:
        if not hasattr(self, "_by_name"):
            self._by_name = {o.name: o for o in self.ops}
        return self._by_name


def parse_module(text: str) -> tuple[dict, str]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    entry = None
    for line in text.splitlines():
        h = _COMP_HEADER.match(line)
        if h:
            cur = Computation(h.group(2))
            comps[cur.name] = cur
            if h.group(1):
                entry = cur.name
            continue
        if cur is None:
            continue
        if line.startswith("}"):
            cur = None
            continue
        m = _OP_ASSIGN.match(line)
        if m:
            name, rhs = m.groups()
            parsed = _split_rhs(rhs)
            if parsed is None:
                continue
            tstr, kind, rest = parsed
            cur.ops.append(Op(name, tstr, kind, rest))
            cur.shapes[name] = tstr
    return comps, entry


def _split_rhs(rhs: str):
    """rhs = '<type> <kind>(<operands...>), attrs'.  Tuple types contain
    spaces and /*index=k*/ comments, so split paren-aware."""
    rhs = rhs.strip()
    if rhs.startswith("("):
        depth = 0
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    tstr, rest = rhs[:i + 1], rhs[i + 1:].strip()
                    break
        else:
            return None
    else:
        parts = rhs.split(None, 1)
        if len(parts) != 2:
            return None
        tstr, rest = parts
    m = _KIND_CALL.match(rest)
    if not m:
        return None
    return tstr, m.group(1), m.group(2)


def _operand_names(rest: str) -> list[str]:
    """First-level operand %names up to the closing paren."""
    out, depth = [], 1
    token = ""
    for ch in rest:
        if ch == "(" or ch == "{":
            depth += 1
        elif ch == ")" or ch == "}":
            depth -= 1
            if depth == 0:
                break
        token += ch
    for part in token.split(","):
        part = part.strip()
        m = re.search(r"%([\w\.\-~]+)$", part)
        if m:
            out.append(m.group(1))
    return out


_META_RE = re.compile(r'op_name="([^"]*)"')


def _op_tag(op: "Op") -> str:
    """Provenance tag for hillclimbing: jax op_name (trimmed) + HLO kind."""
    m = _META_RE.search(op.rest)
    name = m.group(1) if m else ""
    name = re.sub(r"\[.*?\]", "", name)[-90:]
    return f"{op.kind}:{name}" if name else op.kind


@dataclass
class Cost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_pod_bytes: float = 0.0
    coll_count: float = 0.0
    coll_by_kind: dict = field(default_factory=dict)
    pod_by_tag: dict = field(default_factory=dict)    # pod-crossing provenance
    mem_by_tag: dict = field(default_factory=dict)    # provenance -> bytes
    flops_by_tag: dict = field(default_factory=dict)

    def _tag(self, table: dict, tag: str, v: float):
        table[tag] = table.get(tag, 0.0) + v
        if len(table) > 400:                          # bound memory
            for k in sorted(table, key=table.get)[:200]:
                del table[k]

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        self.coll_bytes += other.coll_bytes * mult
        self.coll_pod_bytes += other.coll_pod_bytes * mult
        self.coll_count += other.coll_count * mult
        for k, v in other.coll_by_kind.items():
            self.coll_by_kind[k] = self.coll_by_kind.get(k, 0.0) + v * mult
        for k, v in other.pod_by_tag.items():
            self._tag(self.pod_by_tag, k, v * mult)
        for k, v in other.mem_by_tag.items():
            self._tag(self.mem_by_tag, k, v * mult)
        for k, v in other.flops_by_tag.items():
            self._tag(self.flops_by_tag, k, v * mult)

    def top(self, table: str = "mem_by_tag", n: int = 15) -> list:
        t = getattr(self, table)
        return sorted(t.items(), key=lambda kv: -kv[1])[:n]


def _sliced_params(comp: "Computation") -> set:
    """Indices of fusion parameters consumed ONLY via dynamic-slice/gather
    inside the fused computation (slice-wise access on real hardware)."""
    if hasattr(comp, "_sliced"):
        return comp._sliced
    param_idx = {}
    uses: dict[str, list] = {}
    for op in comp.ops:
        if op.kind == "parameter":
            m = re.match(r"\s*(\d+)", op.rest)
            if m:
                param_idx[op.name] = int(m.group(1))
            continue
        for o in _operand_names(op.rest):
            uses.setdefault(o, []).append(op.kind)
    out = set()
    for pname, idx in param_idx.items():
        kinds = uses.get(pname, [])
        if kinds and all(k in ("dynamic-slice", "gather") for k in kinds):
            out.add(idx)
    comp._sliced = out
    return out


def _trip_count(comps: dict, cond_name: str) -> int:
    comp = comps.get(cond_name)
    if comp is None:
        return 1
    best = 1
    for op in comp.ops:
        if op.kind == "constant":
            m = _CONST_INT.search("constant(" + op.rest)
            if m:
                best = max(best, int(m.group(1)))
    return best


def analyze(text: str, *, pod_boundary: int | None = None) -> Cost:
    comps, entry = parse_module(text)
    memo: dict[str, Cost] = {}

    def comp_cost(name: str) -> Cost:
        if name in memo:
            return memo[name]
        memo[name] = Cost()          # break cycles defensively
        comp = comps.get(name)
        if comp is None:
            return memo[name]
        c = Cost()
        for op in comp.ops:
            # ---- flops ------------------------------------------------
            if op.kind == "dot":
                res_elems = 1
                for m in _SHAPE.finditer(op.type_str):
                    for d in m.group(2).split(","):
                        if d:
                            res_elems *= int(d)
                contract = 1
                cm = _CONTRACT.search(op.rest)
                opnds = _operand_names(op.rest)
                if cm and opnds:
                    lhs_t = comp.shapes.get(opnds[0])
                    if lhs_t:
                        _, dims = _shape_dims(lhs_t)
                        for idx in cm.group(1).split(","):
                            if idx and int(idx) < len(dims):
                                contract *= dims[int(idx)]
                f = 2.0 * res_elems * contract
                c.flops += f
                c._tag(c.flops_by_tag, _op_tag(op), f)
            # ---- collectives -------------------------------------------
            base = op.kind.replace("-start", "")
            if base in ("all-reduce", "all-gather", "reduce-scatter",
                        "all-to-all", "collective-permute"):
                nbytes = _bytes_of(op.type_str)
                gm = hlo_stats._GROUPS_RE.search(op.rest)
                groups = hlo_stats._parse_groups(gm.group(1)) if gm else None
                n = len(groups[0]) if groups and groups[0] else 2
                factor = {"all-gather": (n - 1) / n,
                          "reduce-scatter": float(n - 1),
                          "all-reduce": 2 * (n - 1) / n,
                          "all-to-all": (n - 1) / n,
                          "collective-permute": 1.0}[base]
                moved = nbytes * factor
                c.coll_bytes += moved
                c.coll_count += 1
                c.coll_by_kind[base] = c.coll_by_kind.get(base, 0.0) + moved
                if pod_boundary is not None and groups:
                    if any(g and min(g) < pod_boundary <= max(g)
                           for g in groups):
                        c.coll_pod_bytes += moved
                        c._tag(c.pod_by_tag, _op_tag(op), moved)
            # ---- memory (fusion model) ----------------------------------
            if op.kind == "fusion":
                # in-place update fusions (root = dynamic-update-slice, or a
                # tuple of them — scan residual stacking) move only the
                # update slices, NOT the carried buffer; same for
                # dynamic-slice-rooted read fusions.  Counting the full
                # buffer once per loop iteration inflated memory terms by
                # >100x on recurrent models before this special case.
                called = _ATTR_CALLS.search(op.rest)
                sub = comps.get(called.group(1)) if called else None
                root = sub.ops[-1] if sub and sub.ops else None
                handled = False
                if root is not None:
                    roots = [root]
                    if root.kind == "tuple":
                        roots = [sub.ops_by_name[n] for n in
                                 _operand_names(root.rest)
                                 if n in sub.ops_by_name]
                    if roots and all(r.kind in ("dynamic-update-slice",
                                                "dynamic-slice", "gather",
                                                "scatter") for r in roots):
                        b = 0
                        for r in roots:
                            if r.kind == "dynamic-update-slice":
                                ops_r = _operand_names(r.rest)
                                upd = sub.shapes.get(ops_r[1]) \
                                    if len(ops_r) > 1 else None
                                b += 2 * _bytes_of(upd) if upd else \
                                    _bytes_of(r.type_str)
                            else:
                                b += 2 * _bytes_of(r.type_str)
                        c.hbm_bytes += b
                        c._tag(c.mem_by_tag, _op_tag(op), b)
                        handled = True
                if not handled:
                    res_b = _bytes_of(op.type_str)
                    b = res_b
                    # sliced-access heuristic: operands feeding only an
                    # internal dynamic-slice are read slice-wise (loop-
                    # carried stacks inside scan bodies), not in full
                    sliced = _sliced_params(sub) if sub else set()
                    opnds = _operand_names(op.rest)
                    for i, o in enumerate(opnds):
                        t = comp.shapes.get(o)
                        if not t:
                            continue
                        ob = _bytes_of(t)
                        if i in sliced and ob > 8 * max(res_b, 1):
                            ob = min(ob, res_b)
                        b += ob
                    c.hbm_bytes += b
                    c._tag(c.mem_by_tag, _op_tag(op), b)
            elif op.kind not in _MEM_SKIP:
                if op.kind in ("dynamic-slice", "gather"):
                    # only the slice moves, not the sliced-from operand
                    b = 2 * _bytes_of(op.type_str)
                    c.hbm_bytes += b
                    c._tag(c.mem_by_tag, _op_tag(op), b)
                elif op.kind in ("dynamic-update-slice", "scatter"):
                    # in-place update: traffic ~ 2x the update, not the buffer
                    idx = 1 if op.kind == "dynamic-update-slice" else 2
                    opnds = _operand_names(op.rest)
                    upd = comp.shapes.get(opnds[idx]) if len(opnds) > idx \
                        else None
                    b = 2 * _bytes_of(upd) if upd else _bytes_of(op.type_str)
                    c.hbm_bytes += b
                    c._tag(c.mem_by_tag, _op_tag(op), b)
                else:
                    b = _bytes_of(op.type_str)
                    for o in _operand_names(op.rest):
                        t = comp.shapes.get(o)
                        if t:
                            b += _bytes_of(t)
                    c.hbm_bytes += b
                    c._tag(c.mem_by_tag, _op_tag(op), b)
            # ---- recurse into called computations -----------------------
            if op.kind == "while":
                body = _ATTR_BODY.search(op.rest)
                cond = _ATTR_COND.search(op.rest)
                tc = _TRIP_CFG.search(op.rest)
                if tc:
                    trips = int(tc.group(1))
                else:
                    trips = _trip_count(comps, cond.group(1)) if cond else 1
                if body:
                    c.add(comp_cost(body.group(1)), trips)
                if cond:
                    c.add(comp_cost(cond.group(1)), trips + 1)
            elif op.kind == "fusion":
                called = _ATTR_CALLS.search(op.rest)
                if called:
                    sub = comp_cost(called.group(1))
                    c.flops += sub.flops           # flops only: mem is fused
                    c.coll_bytes += sub.coll_bytes
            elif op.kind == "call":
                called = _ATTR_TOAPPLY.search(op.rest)
                if called:
                    c.add(comp_cost(called.group(1)))
            elif op.kind == "conditional":
                br = _ATTR_BRANCHES.search(op.rest)
                if br:
                    subs = [comp_cost(b.strip().lstrip("%"))
                            for b in br.group(1).split(",") if b.strip()]
                    for s in subs:                  # assume all branches run
                        c.add(s, 1.0 / max(len(subs), 1))
        memo[name] = c
        return c

    return comp_cost(entry) if entry else Cost()
