"""Parse compiled (SPMD-partitioned, per-device) HLO text for roofline
inputs: per-collective byte counts with bandwidth-optimal ring factors,
plus pod-crossing detection on the multi-pod mesh.

Ring factors (Thakur et al. 2005; Patarasuk & Yuan 2009), per device:
  all-gather        (n-1)/n * result_bytes
  reduce-scatter    (n-1)   * result_bytes          (operand = n*result)
  all-reduce        2(n-1)/n * bytes
  all-to-all        (n-1)/n * bytes
  collective-permute 1.0    * bytes
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

import numpy as np

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2,
                "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}

_OP_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\w+\[[\d,]*\]))\S*\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"(\w+?)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=(\{\{.*?\}\}|\[[^\]]*\]<=\[[^\]]*\](?:T\([\d,]+\))?)")


def _shape_bytes(tok: str) -> int:
    m = _SHAPE_RE.match(tok)
    if not m:
        return 0
    dt, dims = m.groups()
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dt, 4)


def _parse_groups(text: str) -> list[list[int]] | None:
    """Materialize replica groups (explicit or iota v2 format)."""
    if text.startswith("{{"):
        return [[int(x) for x in g.split(",") if x.strip()]
                for g in re.findall(r"\{([\d,\s]+)\}", text[1:-1])]
    m = re.match(r"\[([\d,]+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?", text)
    if not m:
        return None
    out_dims = [int(x) for x in m.group(1).split(",")]
    in_dims = [int(x) for x in m.group(2).split(",")]
    ids = np.arange(int(np.prod(in_dims))).reshape(in_dims)
    if m.group(3):
        perm = [int(x) for x in m.group(3).split(",")]
        ids = ids.transpose(perm)
    return ids.reshape(out_dims).tolist()


@dataclass
class CollectiveStats:
    bytes_per_device: float = 0.0          # ring-factored, per chip
    pod_crossing_bytes: float = 0.0        # subset crossing the pod boundary
    by_kind: dict = field(default_factory=dict)
    count: int = 0


def collect(hlo_text: str, *, pod_boundary: int | None = None) -> CollectiveStats:
    """pod_boundary: device-id threshold (e.g. 256 on the 512-chip mesh)."""
    st = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        tuple_body, single, kind = m.groups()
        if tuple_body:
            nbytes = sum(_shape_bytes(t.strip())
                         for t in tuple_body.split(",") if "[" in t)
        else:
            nbytes = _shape_bytes(single)
        gm = _GROUPS_RE.search(line)
        groups = _parse_groups(gm.group(1)) if gm else None
        n = len(groups[0]) if groups and groups[0] else 2
        factor = {"all-gather": (n - 1) / n,
                  "reduce-scatter": float(n - 1),
                  "all-reduce": 2 * (n - 1) / n,
                  "all-to-all": (n - 1) / n,
                  "collective-permute": 1.0}[kind]
        moved = nbytes * factor
        st.bytes_per_device += moved
        st.by_kind[kind] = st.by_kind.get(kind, 0.0) + moved
        st.count += 1
        if pod_boundary is not None and groups:
            crossing = any(
                min(g) < pod_boundary <= max(g) for g in groups if g)
            if crossing:
                st.pod_crossing_bytes += moved
    return st
