from repro.configs.base import (INPUT_SHAPES, InputShape, MixtureConfig,
                                ModelConfig, MoEConfig, get_config,
                                list_configs, smoke_variant)

__all__ = ["INPUT_SHAPES", "InputShape", "MixtureConfig", "ModelConfig",
           "MoEConfig", "get_config", "list_configs", "smoke_variant"]
