"""Configuration system for the repro framework.

A :class:`ModelConfig` fully describes one architecture from the assigned
pool (or the paper's own expert/router models).  Layer heterogeneity
(gemma2 local/global alternation, zamba2 mamba+shared-attention, xlstm
mLSTM/sLSTM interleave) is expressed as a *stage schedule*: a list of
``(unit, repeat)`` pairs where ``unit`` is a tuple of block kinds.  Params
for each unit position are stacked over ``repeat`` and executed with
``lax.scan`` so HLO size stays bounded for paper-scale configs.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any

# ---------------------------------------------------------------------------
# Block kinds
# ---------------------------------------------------------------------------
ATTN = "attn"                # global (full) attention
ATTN_LOCAL = "attn_local"    # sliding-window attention
ATTN_SHARED = "attn_shared"  # attention with weights shared across layers
MAMBA2 = "mamba2"            # Mamba-2 SSD block
MLSTM = "mlstm"              # xLSTM matrix-memory block
SLSTM = "slstm"              # xLSTM scalar-memory block (sequential scan)

BLOCK_KINDS = (ATTN, ATTN_LOCAL, ATTN_SHARED, MAMBA2, MLSTM, SLSTM)
RECURRENT_KINDS = (MAMBA2, MLSTM, SLSTM)


@dataclass(frozen=True)
class MoEConfig:
    """Token-level mixture-of-experts FFN (inside one SmallTalk expert)."""
    n_experts: int
    top_k: int = 2
    capacity_factor: float = 1.25
    dense_residual: bool = False       # snowflake-arctic style parallel dense FFN
    router_softcap: float = 0.0


@dataclass(frozen=True)
class MixtureConfig:
    """SmallTalk LM sequence-level mixture (the paper's technique)."""
    n_experts: int = 4
    prefix_len: int = 256              # M — routing prefix length
    router: str = "router-4m"          # config name of the router LM
    capacity_factor: float = 1.0       # balanced-assignment capacity slack
    router_chunk_tokens: int = 45_000_000  # T — tokens between router comms


@dataclass(frozen=True)
class ModelConfig:
    # identity -------------------------------------------------------------
    name: str = "model"
    arch_type: str = "dense"           # dense|moe|ssm|hybrid|vlm|audio
    citation: str = ""
    # trunk ----------------------------------------------------------------
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 1024
    vocab_size: int = 32000
    head_dim: int = 0                  # 0 -> d_model // n_heads
    stages: tuple[tuple[tuple[str, ...], int], ...] = ()  # () -> ((ATTN,), n_layers)
    # attention ------------------------------------------------------------
    qkv_bias: bool = False
    rope_variant: str = "full"         # full|half|mrope|none
    rope_theta: float = 10_000.0
    mrope_sections: tuple[int, int, int] = (16, 24, 24)
    attn_softcap: float = 0.0          # tanh logit soft-capping (gemma2/grok)
    final_softcap: float = 0.0         # final-logit soft-capping (gemma2)
    sliding_window: int = 4096         # window for ATTN_LOCAL blocks
    causal: bool = True                # False => encoder-only (bidirectional)
    # ffn ------------------------------------------------------------------
    ffn_type: str = "swiglu"           # swiglu|geglu|gelu|none
    moe: MoEConfig | None = None
    # ssm / xlstm ----------------------------------------------------------
    ssm_state: int = 64
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_headdim: int = 64
    slstm_proj_factor: float = 1.3333333
    mlstm_proj_factor: float = 2.0
    # io -------------------------------------------------------------------
    input_mode: str = "tokens"         # tokens|embeddings|multimodal
    input_embed_dim: int = 0           # for embeddings/multimodal stubs
    n_image_tokens: int = 0            # multimodal: image token budget
    tie_embeddings: bool = True
    # numerics ---------------------------------------------------------------
    norm_eps: float = 1e-6
    param_dtype: str = "float32"       # master params
    compute_dtype: str = "bfloat16"
    opt_dtype: str = "float32"         # adam m/v (bf16 for >=300B archs)
    logit_dtype: str = "float32"
    # training -------------------------------------------------------------
    remat: str = "unit"                # none|unit (checkpoint each scanned unit)
    scan_layers: bool = True           # False: unroll stages (dry-run cost accounting)
    loss_chunk: int = 256              # token-chunk for chunked CE
    use_pallas: bool = False           # TPU target: pallas kernels; CPU: jnp refs
    # mixture (paper) --------------------------------------------------------
    mixture: MixtureConfig | None = None

    # derived ---------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def layer_pattern(self) -> tuple[str, ...]:
        pat: list[str] = []
        for unit, rep in self.resolved_stages:
            pat.extend(unit * rep)
        return tuple(pat)

    @property
    def resolved_stages(self) -> tuple[tuple[tuple[str, ...], int], ...]:
        if self.stages:
            return self.stages
        return (((ATTN,), self.n_layers),)

    @property
    def is_encoder(self) -> bool:
        return not self.causal

    @property
    def has_decode(self) -> bool:
        return self.causal

    @property
    def subquadratic(self) -> bool:
        """True if no block holds an unbounded full-attention KV cache...

        ... i.e. the arch is eligible for long_500k per the assignment rules.
        ATTN_LOCAL keeps O(window) KV; recurrent blocks keep O(1) state.
        gemma2 (alternating local/global) is grandfathered in via its native
        sliding-window variant (see DESIGN.md §4).
        """
        kinds = set(self.layer_pattern)
        full_attn = {ATTN, ATTN_SHARED} & kinds
        local_or_rec = ({ATTN_LOCAL} | set(RECURRENT_KINDS)) & kinds
        if not full_attn:
            return True
        # mixed local/global counts (bounded KV on most layers)
        return ATTN_LOCAL in kinds or bool(set(RECURRENT_KINDS) & kinds)

    def validate(self) -> None:
        assert self.d_model % self.n_heads == 0 or self.head_dim, self.name
        assert self.n_heads % self.n_kv_heads == 0, self.name
        assert len(self.layer_pattern) == self.n_layers, (
            f"{self.name}: stage schedule covers {len(self.layer_pattern)} "
            f"layers, config says {self.n_layers}")
        for k in self.layer_pattern:
            assert k in BLOCK_KINDS, k
        if self.moe is not None:
            assert self.moe.top_k <= self.moe.n_experts

    def replace(self, **kw: Any) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                          # train|prefill|decode


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k":    InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k":  InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k":   InputShape("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    cfg.validate()
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown config {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> list[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


_LOADED = False


def _ensure_loaded() -> None:
    global _LOADED
    if _LOADED:
        return
    _LOADED = True
    # import for registration side effects
    from repro.configs import archs  # noqa: F401


def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    """Reduced config of the same family: 2 layers, d_model<=512, <=4 experts."""
    d_model = min(cfg.d_model, 256)
    n_heads = min(cfg.n_heads, 4)
    n_kv = max(1, min(cfg.n_kv_heads, n_heads))
    while n_heads % n_kv:
        n_kv -= 1
    # keep one unit of each distinct stage kind, at most 2 layers total
    pattern = cfg.layer_pattern
    unit: tuple[str, ...]
    if len(set(pattern)) == 1:
        unit = (pattern[0],) * min(2, len(pattern))
    else:
        # first occurrence of up to 2 distinct kinds, preserving order
        seen: list[str] = []
        for k in pattern:
            if k not in seen:
                seen.append(k)
            if len(seen) == 2:
                break
        unit = tuple(seen)
    moe = cfg.moe
    if moe is not None:
        moe = dataclasses.replace(moe, n_experts=min(4, moe.n_experts),
                                  top_k=min(2, moe.n_experts, moe.top_k))
    return cfg.replace(
        name=cfg.name + "-smoke",
        n_layers=len(unit),
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=0,
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512),
        stages=((unit, 1),),
        sliding_window=min(cfg.sliding_window, 64),
        mrope_sections=(8, 12, 12) if cfg.rope_variant == "mrope" else cfg.mrope_sections,
        n_image_tokens=min(cfg.n_image_tokens, 16),
        input_embed_dim=min(cfg.input_embed_dim, 64) if cfg.input_embed_dim else 0,
        ssm_headdim=min(cfg.ssm_headdim, 32),
        ssm_state=min(cfg.ssm_state, 16),
        loss_chunk=64,
        moe=moe,
    )
