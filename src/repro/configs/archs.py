"""Assigned architecture pool (10 archs, 6 families) + the paper's own
SmallTalk expert/router models.  Every config cites its source.

Sharding/memory policy notes (see parallel/sharding.py):
  - archs >= ~7B params set ``fsdp`` in SHARDING_OVERRIDES (params + opt
    state sharded over data*model, ZeRO-3 style);
  - the >=300B MoEs store optimizer moments in bf16 (documented in
    EXPERIMENTS.md) to fit 16 GB/chip v5e.
"""
from __future__ import annotations

from repro.configs.base import (ATTN, ATTN_LOCAL, ATTN_SHARED, MAMBA2, MLSTM,
                                SLSTM, MixtureConfig, ModelConfig, MoEConfig,
                                register)

# ---------------------------------------------------------------------------
# Assigned pool
# ---------------------------------------------------------------------------
GEMMA2_27B = register(ModelConfig(
    name="gemma2-27b", arch_type="dense", citation="arXiv:2408.00118",
    n_layers=46, d_model=4608, n_heads=32, n_kv_heads=16, d_ff=36864,
    vocab_size=256000, head_dim=144,
    stages=(((ATTN_LOCAL, ATTN), 23),),          # local+global alternating
    sliding_window=4096, attn_softcap=50.0, final_softcap=30.0,
    ffn_type="geglu", rope_theta=10_000.0,
))

ZAMBA2_1P2B = register(ModelConfig(
    name="zamba2-1.2b", arch_type="hybrid", citation="arXiv:2411.15242",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=8192,
    vocab_size=32000,
    # Mamba2 backbone with a *shared* full transformer block every 6 layers
    stages=(((MAMBA2,) * 5 + (ATTN_SHARED,), 6), ((MAMBA2,), 2)),
    ssm_state=64, ssm_headdim=64, ssm_expand=2,
    ffn_type="swiglu",
))

QWEN2_VL_7B = register(ModelConfig(
    name="qwen2-vl-7b", arch_type="vlm", citation="arXiv:2409.12191",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4, d_ff=18944,
    vocab_size=152064,
    stages=(((ATTN,), 28),), qkv_bias=True,
    rope_variant="mrope", mrope_sections=(16, 24, 24), rope_theta=1e6,
    input_mode="multimodal", input_embed_dim=1176, n_image_tokens=1024,
    ffn_type="swiglu",
))

CHATGLM3_6B = register(ModelConfig(
    name="chatglm3-6b", arch_type="dense", citation="arXiv:2406.12793",
    n_layers=28, d_model=4096, n_heads=32, n_kv_heads=2, d_ff=13696,
    vocab_size=65024,
    stages=(((ATTN,), 28),), qkv_bias=True,
    rope_variant="half",                          # 2d RoPE: rotary on half dims
    ffn_type="swiglu",
))

GROK1_314B = register(ModelConfig(
    name="grok-1-314b", arch_type="moe", citation="hf:xai-org/grok-1",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=32768,
    vocab_size=131072,
    stages=(((ATTN,), 64),), attn_softcap=30.0,
    moe=MoEConfig(n_experts=8, top_k=2),
    ffn_type="gelu", opt_dtype="bfloat16",
))

ARCTIC_480B = register(ModelConfig(
    name="arctic-480b", arch_type="moe", citation="hf:Snowflake/snowflake-arctic-base",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8, d_ff=4864,
    vocab_size=32000,
    stages=(((ATTN,), 35),),
    moe=MoEConfig(n_experts=128, top_k=2, dense_residual=True),
    ffn_type="swiglu", param_dtype="bfloat16", opt_dtype="bfloat16",
))

QWEN2_1P5B = register(ModelConfig(
    name="qwen2-1.5b", arch_type="dense", citation="arXiv:2407.10671",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2, d_ff=8960,
    vocab_size=151936,
    stages=(((ATTN,), 28),), qkv_bias=True,
    ffn_type="swiglu", rope_theta=1e6,
))

QWEN1P5_4B = register(ModelConfig(
    name="qwen1.5-4b", arch_type="dense", citation="hf:Qwen/Qwen1.5-0.5B",
    n_layers=40, d_model=2560, n_heads=20, n_kv_heads=20, d_ff=6912,
    vocab_size=151936,
    stages=(((ATTN,), 40),), qkv_bias=True,
    ffn_type="swiglu",
))

HUBERT_XLARGE = register(ModelConfig(
    name="hubert-xlarge", arch_type="audio", citation="arXiv:2106.07447",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16, d_ff=5120,
    vocab_size=504,
    stages=(((ATTN,), 48),), causal=False,        # encoder-only
    input_mode="embeddings", input_embed_dim=512,  # conv feature-extractor stub
    ffn_type="gelu", tie_embeddings=False,
))

XLSTM_1P3B = register(ModelConfig(
    name="xlstm-1.3b", arch_type="ssm", citation="arXiv:2405.04517",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab_size=50304,
    stages=(((MLSTM,) * 7 + (SLSTM,), 6),),       # xLSTM[7:1]
    ffn_type="none", rope_variant="none", tie_embeddings=False,
))

ASSIGNED = [GEMMA2_27B, ZAMBA2_1P2B, QWEN2_VL_7B, CHATGLM3_6B, GROK1_314B,
            ARCTIC_480B, QWEN2_1P5B, QWEN1P5_4B, HUBERT_XLARGE, XLSTM_1P3B]
ASSIGNED_NAMES = [c.name for c in ASSIGNED]

# archs whose params/opt-state must be sharded over data*model (ZeRO-3)
FSDP_ARCHS = {"gemma2-27b", "grok-1-314b", "arctic-480b", "qwen2-vl-7b",
              "chatglm3-6b"}

# ---------------------------------------------------------------------------
# The paper's own models (Table 1)
# ---------------------------------------------------------------------------
_MIX = MixtureConfig(n_experts=4, prefix_len=256, router="router-4m")

SMALLTALK_335M = register(ModelConfig(
    name="smalltalk-335m", arch_type="dense", citation="SmallTalk LM Table 1",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=4096,
    vocab_size=32000, stages=(((ATTN,), 24),),
    ffn_type="gelu", mixture=_MIX,
))

SMALLTALK_1P3B = register(ModelConfig(
    name="smalltalk-1.3b", arch_type="dense", citation="SmallTalk LM Table 1",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=8192,
    vocab_size=32000, stages=(((ATTN,), 24),),
    ffn_type="gelu", mixture=_MIX,
))

ROUTER_4M = register(ModelConfig(
    name="router-4m", arch_type="dense", citation="SmallTalk LM Table 1",
    n_layers=12, d_model=96, n_heads=12, n_kv_heads=12, d_ff=384,
    vocab_size=32000, stages=(((ATTN,), 12),), ffn_type="gelu",
))

ROUTER_64M = register(ModelConfig(
    name="router-64m", arch_type="dense", citation="SmallTalk LM Table 1",
    n_layers=12, d_model=416, n_heads=12, n_kv_heads=12, d_ff=1664,
    vocab_size=32000, head_dim=32, stages=(((ATTN,), 12),), ffn_type="gelu",
))

ROUTER_110M = register(ModelConfig(
    name="router-110m", arch_type="dense", citation="SmallTalk LM Table 1",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, d_ff=3072,
    vocab_size=32000, stages=(((ATTN,), 12),), ffn_type="gelu",
))
