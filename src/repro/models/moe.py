"""Token-level mixture-of-experts FFN (grok-1: 8e top-2; arctic: 128e top-2
+ dense residual).

Dispatch is sort-based (Megablocks-style, XLA-friendly): tokens are ranked
within their assigned expert via a stable argsort, scattered into a fixed
``(E, C, D)`` capacity buffer, processed with stacked expert matmuls, and
combined back with router-probability weighting.  This keeps memory at
O(E*C*D) instead of the GShard one-hot O(N*E*C) and induces an all-to-all
when the expert dim is sharded over the ``model`` mesh axis.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import common

Params = dict[str, Any]


def moe_init(key, cfg, dtype) -> Params:
    d, f, m = cfg.d_model, cfg.d_ff, cfg.moe
    ks = jax.random.split(key, 6)
    p: Params = {
        "norm": common.rmsnorm_init(d, dtype),
        "router": common.dense_init(ks[0], d, m.n_experts, jnp.float32),
        "wi": _stacked(ks[1], m.n_experts, d, f, dtype),
        "wo": _stacked(ks[2], m.n_experts, f, d, dtype),
    }
    if cfg.ffn_type in ("swiglu", "geglu"):
        p["wg"] = _stacked(ks[3], m.n_experts, d, f, dtype)
    if m.dense_residual:
        p["dense"] = common.ffn_init(ks[4], cfg, dtype)
    return p


def _stacked(key, e, din, dout, dtype):
    scale = 1.0 / math.sqrt(din)
    return (jax.random.normal(key, (e, din, dout), jnp.float32) * scale).astype(dtype)


def expert_capacity(n_tokens: int, cfg) -> int:
    m = cfg.moe
    c = int(math.ceil(n_tokens * m.top_k / m.n_experts * m.capacity_factor))
    return max(8, -(-c // 8) * 8)  # round up to 8 for TPU-friendly shapes


def moe_apply(params: Params, x: jnp.ndarray, cfg) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (residual output, aux load-balancing loss scalar).

    Dispatch is GROUP-LOCAL (GShard-style groups = batch rows): top-k,
    ranking, scatter and combine are all batched over B, so under a
    data-sharded batch every sort/scatter stays on-shard.  The global-sort
    variant we started from turned each MoE layer into an all-gather +
    global argsort + scattered writes across the whole mesh — the §Perf
    log shows it made grok-1 train 65x collective-bound.  Capacity is per
    group: C = ceil(S * k / E * cf).
    """
    m = cfg.moe
    B, S, D = x.shape
    E, K = m.n_experts, m.top_k
    C = expert_capacity(S, cfg)

    h = common.rmsnorm(params["norm"], x, cfg.norm_eps)
    gate_logits = h.astype(jnp.float32) @ params["router"].astype(jnp.float32)
    if m.router_softcap:
        gate_logits = common.softcap(gate_logits, m.router_softcap)
    gate_probs = jax.nn.softmax(gate_logits, axis=-1)
    top_p, top_e = jax.lax.top_k(gate_probs, K)                        # (B,S,K)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)    # renorm among selected

    # Switch-style aux loss: E * sum_e fraction_routed_e * mean_prob_e
    onehot_top1 = jax.nn.one_hot(top_e[..., 0], E, dtype=jnp.float32)
    frac = onehot_top1.reshape(-1, E).mean(0)
    mean_p = gate_probs.reshape(-1, E).mean(0)
    aux = E * jnp.sum(frac * mean_p)

    # ---- group-local slot computation (batched over B, all on-shard) ---
    from repro.parallel import act_sharding as act
    NK = S * K
    eflat = top_e.reshape(B, NK)                                       # expert ids
    order = jnp.argsort(eflat, axis=1, stable=True)                    # per-row
    e_sorted = jnp.take_along_axis(eflat, order, axis=1)
    counts = jnp.zeros((B, E), jnp.int32).at[
        jnp.arange(B)[:, None], eflat].add(1)
    starts = jnp.concatenate(
        [jnp.zeros((B, 1), jnp.int32), jnp.cumsum(counts, 1)[:, :-1]], 1)
    rank = jnp.arange(NK, dtype=jnp.int32)[None] - \
        jnp.take_along_axis(starts, e_sorted, axis=1)
    # invert the sort: slot per (token, k-choice), -1 = dropped
    slot_flat = jnp.zeros((B, NK), jnp.int32).at[
        jnp.arange(B)[:, None], order].set(jnp.where(rank < C, rank, -1))
    slots = slot_flat.reshape(B, S, K)

    # ---- one-hot einsum dispatch (GShard-style, factored per choice) ---
    # scatter/gather across the model-sharded expert dim makes GSPMD emit
    # mask+all-reduce storms (§Perf log); these einsums keep dispatch fully
    # local and leave exactly ONE all-reduce (over 'model') at combine.
    def disp_k(k):
        e_oh = jax.nn.one_hot(top_e[..., k], E, dtype=h.dtype)
        c_oh = jax.nn.one_hot(slots[..., k], C, dtype=h.dtype)  # -1 -> zeros
        return e_oh[..., :, None] * c_oh[..., None, :]          # (B,S,E,C)

    buf = jnp.zeros((B, E, C, D), h.dtype)
    for k in range(K):
        buf = buf + jnp.einsum("bsec,bsd->becd", disp_k(k), h)
    buf = act.constrain(buf, "data", "model", None, None)

    # ---- stacked expert FFN --------------------------------------------
    if cfg.ffn_type in ("swiglu", "geglu"):
        actfn = jax.nn.silu if cfg.ffn_type == "swiglu" else (
            lambda t: jax.nn.gelu(t, approximate=True))
        inner = actfn(jnp.einsum("becd,edf->becf", buf, params["wg"])) * \
            jnp.einsum("becd,edf->becf", buf, params["wi"])
    else:
        inner = jax.nn.gelu(jnp.einsum("becd,edf->becf", buf, params["wi"]),
                            approximate=True)
    out_buf = jnp.einsum("becf,efd->becd", inner, params["wo"])
    out_buf = act.constrain(out_buf, "data", "model", None, None)

    # ---- combine (contraction over sharded E -> one all-reduce) ---------
    y = jnp.zeros((B, S, D), h.dtype)
    for k in range(K):
        yk = jnp.einsum("bsec,becd->bsd", disp_k(k), out_buf)
        y = y + yk * top_p[..., k, None].astype(h.dtype)
    y = act.shard_tokens(y)

    if m.dense_residual:
        y = y + common.ffn_core(params["dense"],
                                common.rmsnorm(params["dense"]["norm"], x,
                                               cfg.norm_eps), cfg.ffn_type)
    return x + y, aux
