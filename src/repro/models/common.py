"""Shared building blocks: norms, RoPE variants, attention, FFNs.

Everything is pure-functional JAX: ``init_*`` builds param pytrees (nested
dicts of ``jnp.ndarray``), ``*_apply`` consumes them.  Attention is routed
through :mod:`repro.kernels.flash_attention.ops` so the Pallas TPU kernel
and the blockwise-jnp reference share one call site.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

Params = dict[str, Any]


def dt(name: str):
    return jnp.dtype(name)


# ---------------------------------------------------------------------------
# Init helpers
# ---------------------------------------------------------------------------
def dense_init(key, d_in: int, d_out: int, dtype, scale: float | None = None):
    scale = scale if scale is not None else 1.0 / np.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------
def rmsnorm_init(d: int, dtype) -> Params:
    return {"scale": jnp.zeros((d,), dtype)}  # (1 + scale) parametrization


def rmsnorm(params: Params, x: jnp.ndarray, eps: float) -> jnp.ndarray:
    orig = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + params["scale"].astype(jnp.float32))).astype(orig)


# ---------------------------------------------------------------------------
# RoPE (full / half / M-RoPE) — half-split (llama) convention
# ---------------------------------------------------------------------------
def rope_frequencies(cfg, positions: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray, int]:
    """Return (cos, sin, rotary_dim).

    positions: (B, S) int32 for full/half; (B, S, 3) for mrope.
    cos/sin: (B, S, rotary_dim//2) float32.
    """
    head_dim = cfg.resolved_head_dim
    if cfg.rope_variant == "none":
        raise ValueError("rope disabled")
    if cfg.rope_variant == "half":
        rot = head_dim // 2
    else:
        rot = head_dim
    rot = (rot // 2) * 2
    half = rot // 2
    inv_freq = 1.0 / (cfg.rope_theta ** (np.arange(0, half, dtype=np.float32) / half))
    inv_freq = jnp.asarray(inv_freq)
    if cfg.rope_variant == "mrope":
        sections = np.asarray(cfg.mrope_sections)
        assert sections.sum() == half, (sections, half)
        sect_id = np.repeat(np.arange(3), sections)           # (half,)
        if positions.ndim == 2:                               # text-only fallback
            positions = positions[..., None] * jnp.ones((3,), positions.dtype)
        pos = jnp.take_along_axis(
            positions.astype(jnp.float32),
            jnp.broadcast_to(jnp.asarray(sect_id)[None, None, :],
                             positions.shape[:2] + (half,)),
            axis=-1)                                          # (B,S,half)
        angles = pos * inv_freq[None, None, :]
    else:
        if positions.ndim == 3:
            positions = positions[..., 0]
        angles = positions.astype(jnp.float32)[..., None] * inv_freq[None, None, :]
    return jnp.cos(angles), jnp.sin(angles), rot


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray, rot: int) -> jnp.ndarray:
    """x: (B, S, H, head_dim); cos/sin: (B, S, rot//2)."""
    orig = x.dtype
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = jnp.split(xr.astype(jnp.float32), 2, axis=-1)
    c = cos[:, :, None, :]
    s = sin[:, :, None, :]
    rotated = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    return jnp.concatenate([rotated.astype(orig), xp], axis=-1) if rot < x.shape[-1] \
        else rotated.astype(orig)


# ---------------------------------------------------------------------------
# Attention block (GQA; full / sliding-window; softcap; decode cache)
# ---------------------------------------------------------------------------
def attn_init(key, cfg, dtype) -> Params:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    p: Params = {
        "wq": dense_init(ks[0], d, hq * hd, dtype),
        "wk": dense_init(ks[1], d, hkv * hd, dtype),
        "wv": dense_init(ks[2], d, hkv * hd, dtype),
        "wo": dense_init(ks[3], hq * hd, d, dtype),
        "norm": rmsnorm_init(d, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * hd,), dtype)
        p["bk"] = jnp.zeros((hkv * hd,), dtype)
        p["bv"] = jnp.zeros((hkv * hd,), dtype)
    return p


def attn_apply(params: Params, x: jnp.ndarray, cfg, *,
               kind: str, positions: jnp.ndarray,
               cache: Params | None = None,
               cache_index: jnp.ndarray | None = None,
               cache_len: int | None = None,
               block_tables: jnp.ndarray | None = None,
               paged_prefill: bool = False,
               true_lens: jnp.ndarray | None = None) -> tuple[jnp.ndarray, Params | None]:
    """Pre-norm attention block.  Returns (residual_output, new_cache).

    Train/prefill: ``cache`` is None (prefill returns a fresh cache when
    ``cache_index`` is not None, meaning "materialize cache please").
    Decode: ``x`` is (B, 1, D); ``cache`` holds k/v (B, Skv, Hkv, hd) plus
    ``pos`` (B, Skv) int32 slot positions (-1 = empty); ``cache_index`` is
    the write slot — a scalar (all rows at the same index, the one-shot
    decode loop) or a (B,) vector (per-row slots, the continuous-batching
    serving engine where every lane is at a different sequence length).

    Paged decode (``block_tables`` given, full-attention kinds only):
    ``cache`` is a shared block *pool* — k/v ``(P+1, bs, Hkv, hd)`` and
    ``pos`` ``(P+1, bs)`` where row P is a scratch block absorbing writes
    of inactive lanes.  ``block_tables`` (B, max_len//bs) int32 maps each
    lane's position range [i*bs, (i+1)*bs) to a pool block (-1 = not
    reserved).  The write scatters the new token at (table[p//bs], p%bs)
    and the read goes through the paged decode dispatch
    (:mod:`repro.kernels.paged_attention.ops`): the jnp reference keeps
    decode bit-identical to the unpaged path, while ``cfg.use_pallas``
    selects the block-table-chasing Pallas kernel that reads only live
    blocks instead of materializing the (B, max_len, ...) gather.

    Fused paged prefill (``paged_prefill=True``; needs ``cache`` +
    ``block_tables`` + ``true_lens``, full-attention kinds only): ``x``
    is a right-padded prompt bucket (B, S, D) prefilled from position 0;
    causal attention and the pool KV write happen in one dispatch
    (:mod:`repro.kernels.paged_prefill.ops`) — no dense per-lane slab is
    materialized and no separate insert scatter runs afterwards.  The
    jnp impl makes the same blockwise flash call as the slab path, so
    the hidden state (hence logits, hence tokens) is bitwise unchanged.
    """
    from repro.kernels.flash_attention import ops as fa
    from repro.kernels.paged_attention import ops as pa

    B, S, D = x.shape
    hd = cfg.resolved_head_dim
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    h = rmsnorm(params["norm"], x, cfg.norm_eps)
    q = h @ params["wq"]
    k = h @ params["wk"]
    v = h @ params["wv"]
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    from repro.parallel import act_sharding as act
    q = act.shard_attn_q(q.reshape(B, S, hq, hd))
    k = act.shard_attn_kv(k.reshape(B, S, hkv, hd))
    v = act.shard_attn_kv(v.reshape(B, S, hkv, hd))
    if cfg.rope_variant != "none":
        cos, sin, rot = rope_frequencies(cfg, positions)
        q = apply_rope(q, cos, sin, rot)
        k = apply_rope(k, cos, sin, rot)

    window = cfg.sliding_window if kind == "attn_local" else 0
    causal = cfg.causal
    q_pos = positions[..., 0] if positions.ndim == 3 else positions

    new_cache: Params | None = None
    if paged_prefill:
        if cache is None or block_tables is None or true_lens is None \
                or kind != "attn":
            raise ValueError(
                "paged_prefill needs the paged pool layout (cache + "
                "block_tables + true_lens) on a full-attention layer; "
                f"got kind={kind!r}")
        from repro.kernels.paged_prefill import ops as ppf
        out, ck, cv, cpos = ppf.paged_prefill_attention(
            q, k, v, block_tables=block_tables, true_lens=true_lens,
            k_pool=cache["k"], v_pool=cache["v"], pos_pool=cache["pos"],
            softcap=cfg.attn_softcap,
            impl="pallas" if cfg.use_pallas else "jnp")
        new_cache = {"k": ck, "v": cv, "pos": cpos}
        out = act.shard_attn_q(out)
    elif cache is not None and block_tables is not None and kind == "attn":
        # paged decode: cache leaves are the shared block pool
        n_blocks, bs = cache["k"].shape[0], cache["k"].shape[1]
        scratch = n_blocks - 1
        nb = block_tables.shape[1]
        p = jnp.broadcast_to(cache_index, (B,)).astype(jnp.int32)
        bi = jnp.clip(jnp.where(p >= 0, p // bs, 0), 0, nb - 1)
        blk = jnp.take_along_axis(block_tables, bi[:, None], axis=1)[:, 0]
        wblk = jnp.where((p >= 0) & (blk >= 0), blk, scratch)
        off = jnp.where(p >= 0, p % bs, 0)
        ck = cache["k"].at[wblk, off].set(k[:, 0])
        cv = cache["v"].at[wblk, off].set(v[:, 0])
        cpos = cache["pos"].at[wblk, off].set(
            q_pos[:, 0].astype(cache["pos"].dtype))
        new_cache = {"k": ck, "v": cv, "pos": cpos}
        out = pa.decode_attention(q, ck, cv, q_pos=q_pos, kv_pos=cpos,
                                  block_tables=block_tables,
                                  softcap=cfg.attn_softcap,
                                  impl="pallas" if cfg.use_pallas else "jnp")
    elif cache is not None:
        # single-token decode against the cache; local layers use a
        # rotating buffer of `window` slots (slot = pos % size)
        size = cache["k"].shape[1]
        idx = cache_index % size
        if jnp.ndim(idx) == 0:
            ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, idx, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, idx, axis=1)
            cpos = jax.lax.dynamic_update_slice_in_dim(
                cache["pos"], q_pos.astype(cache["pos"].dtype), idx, axis=1)
        else:
            # per-row write slots: row b writes its token at idx[b]
            bidx = jnp.arange(B)
            ck = cache["k"].at[bidx, idx].set(k[:, 0])
            cv = cache["v"].at[bidx, idx].set(v[:, 0])
            cpos = cache["pos"].at[bidx, idx].set(
                q_pos[:, 0].astype(cache["pos"].dtype))
        new_cache = {"k": ck, "v": cv, "pos": cpos}
        out = pa.decode_attention(q, ck, cv, q_pos=q_pos, kv_pos=cpos,
                                  window=window, softcap=cfg.attn_softcap)
    else:
        # context-parallel mode: S is sharded over 'model', so the q-chunk
        # map must not re-chunk S (per-device memory is already bounded)
        ctx = act.attn_mode(hq) == "ctx"
        out = fa.flash_attention(
            q, k, v, causal=causal, window=window, softcap=cfg.attn_softcap,
            impl="pallas" if cfg.use_pallas else "jnp",
            q_chunk=S if ctx else 1024)
        if ctx:
            out = act.constrain(out, "data", "model", None, None)
        else:
            out = act.shard_attn_q(out)
        if cache_index is not None:   # prefill: materialize the cache
            total = cache_len if cache_len else S   # decode budget
            size = min(total, window) if window > 0 else total
            pos32 = q_pos.astype(jnp.int32)
            if size >= S:
                pad = size - S
                new_cache = {
                    "k": jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))),
                    "v": jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))),
                    "pos": jnp.pad(pos32, ((0, 0), (0, pad)),
                                   constant_values=-1),
                }
            else:
                # keep the last `size` entries, rolled so position p sits at
                # slot p % size — the decode write rule then evicts oldest
                sh = S % size
                new_cache = {
                    "k": jnp.roll(k[:, S - size:], sh, axis=1),
                    "v": jnp.roll(v[:, S - size:], sh, axis=1),
                    "pos": jnp.roll(pos32[:, S - size:], sh, axis=1),
                }
    out = out.reshape(B, S, hq * hd)
    return act.shard_tokens(x + out @ params["wo"]), new_cache


def attn_cache_spec(cfg, batch: int, seq: int, kind: str) -> dict[str, jax.ShapeDtypeStruct]:
    """Shape of the decode cache for one attention layer."""
    size = min(seq, cfg.sliding_window) if kind == "attn_local" else seq
    hd = cfg.resolved_head_dim
    cdt = dt(cfg.compute_dtype)
    return {
        "k": jax.ShapeDtypeStruct((batch, size, cfg.n_kv_heads, hd), cdt),
        "v": jax.ShapeDtypeStruct((batch, size, cfg.n_kv_heads, hd), cdt),
        "pos": jax.ShapeDtypeStruct((batch, size), jnp.int32),
    }


def attn_pool_spec(cfg, n_blocks: int, block_size: int) -> dict[str, jax.ShapeDtypeStruct]:
    """Shape of the paged KV block pool for one full-attention layer.

    ``n_blocks`` is the number of allocatable blocks; one extra scratch
    block (index ``n_blocks``) is appended to absorb writes of inactive
    lanes and of unreserved block-table rows, so every scatter index can
    be clamped there instead of needing a drop mode.
    """
    hd = cfg.resolved_head_dim
    cdt = dt(cfg.compute_dtype)
    return {
        "k": jax.ShapeDtypeStruct((n_blocks + 1, block_size,
                                   cfg.n_kv_heads, hd), cdt),
        "v": jax.ShapeDtypeStruct((n_blocks + 1, block_size,
                                   cfg.n_kv_heads, hd), cdt),
        "pos": jax.ShapeDtypeStruct((n_blocks + 1, block_size), jnp.int32),
    }


# ---------------------------------------------------------------------------
# FFN (swiglu / geglu / gelu) and block wrapper
# ---------------------------------------------------------------------------
def ffn_init(key, cfg, dtype) -> Params:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    p: Params = {"norm": rmsnorm_init(d, dtype)}
    if cfg.ffn_type in ("swiglu", "geglu"):
        p["wi"] = dense_init(ks[0], d, f, dtype)
        p["wg"] = dense_init(ks[1], d, f, dtype)
    else:
        p["wi"] = dense_init(ks[0], d, f, dtype)
    p["wo"] = dense_init(ks[2], f, d, dtype)
    return p


def ffn_core(params: Params, h: jnp.ndarray, ffn_type: str) -> jnp.ndarray:
    if ffn_type == "swiglu":
        a = jax.nn.silu(h @ params["wg"]) * (h @ params["wi"])
    elif ffn_type == "geglu":
        a = jax.nn.gelu(h @ params["wg"], approximate=True) * (h @ params["wi"])
    else:
        a = jax.nn.gelu(h @ params["wi"], approximate=True)
    return a @ params["wo"]


def ffn_apply(params: Params, x: jnp.ndarray, cfg) -> jnp.ndarray:
    h = rmsnorm(params["norm"], x, cfg.norm_eps)
    return x + ffn_core(params, h, cfg.ffn_type)


def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    if not cap:
        return x
    return jnp.tanh(x / cap) * cap
