"""Mamba-2 block (SSD chunked algorithm) — zamba2's recurrent backbone.

Train/prefill use the chunked SSD formulation: a single ``lax.scan`` over
chunks computes both the intra-chunk quadratic term and the inter-chunk
state recurrence, so the workspace is O(B*Q*Q*H) per step instead of
O(B*S*Q*H).  Decode is the O(1)-state single-step recurrence.  Single SSM
group (G=1).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import common

Params = dict[str, Any]


def _dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    nheads = d_inner // cfg.ssm_headdim
    return d_inner, nheads


def mamba2_init(key, cfg, dtype) -> Params:
    d = cfg.d_model
    d_inner, nheads = _dims(cfg)
    n = cfg.ssm_state
    conv_ch = d_inner + 2 * n
    ks = jax.random.split(key, 4)
    d_in_proj = 2 * d_inner + 2 * n + nheads          # z, x, B, C, dt
    return {
        "norm": common.rmsnorm_init(d, dtype),
        "in_proj": common.dense_init(ks[0], d, d_in_proj, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, conv_ch), jnp.float32)
                   * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.zeros((nheads,), jnp.float32),    # A = -exp(A_log) = -1
        "D": jnp.ones((nheads,), jnp.float32),
        "dt_bias": jnp.zeros((nheads,), jnp.float32),
        "out_norm": common.rmsnorm_init(d_inner, dtype),
        "out_proj": common.dense_init(ks[2], d_inner, d, dtype),
    }


def _split_proj(cfg, proj):
    d_inner, _ = _dims(cfg)
    n = cfg.ssm_state
    z, xbc, dt = jnp.split(proj, [d_inner, 2 * d_inner + 2 * n], axis=-1)
    return z, xbc, dt                                   # xbc = (x|B|C)


def _causal_conv(xbc, w, b, state=None):
    """Depthwise causal conv, kernel K.  xbc: (B,S,C); w: (K,C).

    If ``state`` (B,K-1,C) is given (decode), prepend it; returns
    (out, new_state)."""
    K = w.shape[0]
    if state is not None:
        xp = jnp.concatenate([state, xbc], axis=1)
    else:
        xp = jnp.pad(xbc, ((0, 0), (K - 1, 0), (0, 0)))
    new_state = xp[:, xp.shape[1] - (K - 1):] if K > 1 else \
        jnp.zeros((xbc.shape[0], 0, xbc.shape[2]), xbc.dtype)
    out = sum(xp[:, i:i + xbc.shape[1]] * w[i] for i in range(K)) + b
    return jax.nn.silu(out), new_state


def mamba2_apply(params: Params, x: jnp.ndarray, cfg, *,
                 cache: Params | None = None, want_cache: bool = False,
                 chunk: int = 128) -> tuple[jnp.ndarray, Params | None]:
    """Pre-norm Mamba2 block.  Returns (residual output, new cache).

    ``cache`` given  => single-token decode step.
    ``want_cache``   => prefill: also return the decode-ready cache.
    """
    Bb, S, D = x.shape
    d_inner, nheads = _dims(cfg)
    n, P = cfg.ssm_state, cfg.ssm_headdim
    h = common.rmsnorm(params["norm"], x, cfg.norm_eps)
    z, xbc, dt_raw = _split_proj(cfg, h @ params["in_proj"])

    new_cache: Params | None = None
    if cache is not None:   # single-token decode
        xbc, conv_state = _causal_conv(xbc, params["conv_w"], params["conv_b"],
                                       state=cache["conv"])
        xs, B_, C_ = jnp.split(xbc, [d_inner, d_inner + n], axis=-1)
        dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32)
                             + params["dt_bias"].astype(jnp.float32))  # (B,H)
        A = -jnp.exp(params["A_log"].astype(jnp.float32))      # (H,)
        xh = xs[:, 0].reshape(Bb, nheads, P).astype(jnp.float32)
        Bv = B_[:, 0].astype(jnp.float32)                      # (B,N)
        Cv = C_[:, 0].astype(jnp.float32)
        decay = jnp.exp(dt * A)                                # (B,H)
        upd = (dt[..., None] * xh)[..., None] * Bv[:, None, None, :]
        ssm = cache["ssm"] * decay[..., None, None] + upd      # (B,H,P,N)
        y = jnp.einsum("bhpn,bn->bhp", ssm, Cv)
        y = y + params["D"][None, :, None] * xh
        y = y.reshape(Bb, 1, d_inner).astype(h.dtype)
        new_cache = {"conv": conv_state.astype(h.dtype), "ssm": ssm}
    else:
        xbc, conv_state = _causal_conv(xbc, params["conv_w"], params["conv_b"])
        xs, B_, C_ = jnp.split(xbc, [d_inner, d_inner + n], axis=-1)
        y, ssm_final = _ssd_chunked(cfg, xs, B_, C_, dt_raw, params, chunk)
        y = y.astype(h.dtype)
        if want_cache:
            new_cache = {"conv": conv_state.astype(h.dtype), "ssm": ssm_final}

    y = common.rmsnorm(params["out_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return x + y @ params["out_proj"], new_cache


def _ssd_chunked(cfg, xs, B_, C_, dt_raw, params, chunk):
    """Chunked SSD via one scan over chunks.

    xs: (B,S,d_inner); B_,C_: (B,S,N); dt_raw: (B,S,H).
    Returns (y (B,S,d_inner) f32, final_state (B,H,P,N) f32).
    """
    Bb, S, _ = xs.shape
    d_inner, H = _dims(cfg)
    N, P = cfg.ssm_state, cfg.ssm_headdim
    Q = min(chunk, S)
    while S % Q:
        Q -= 1
    nc = S // Q

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))          # (B,S,H)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))                      # (H,)
    dA = dt * A
    xh = xs.reshape(Bb, nc, Q, H, P).astype(jnp.float32).transpose(1, 0, 2, 3, 4)
    Bv = B_.reshape(Bb, nc, Q, N).astype(jnp.float32).transpose(1, 0, 2, 3)
    Cv = C_.reshape(Bb, nc, Q, N).astype(jnp.float32).transpose(1, 0, 2, 3)
    dtc = dt.reshape(Bb, nc, Q, H).transpose(1, 0, 2, 3)
    dAc = dA.reshape(Bb, nc, Q, H).transpose(1, 0, 2, 3)
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    Dp = params["D"].astype(jnp.float32)

    def step(state, inp):
        xc, bc, cc, dtq, daq = inp               # per-chunk slices
        cs = jnp.cumsum(daq, axis=1)             # (B,Q,H)
        total = cs[:, -1]                        # (B,H)
        # intra-chunk
        seg = cs[:, :, None, :] - cs[:, None, :, :]      # (B,Qi,Qj,H)
        L = jnp.where(tri[None, :, :, None], jnp.exp(seg), 0.0)
        cb = jnp.einsum("bin,bjn->bij", cc, bc)          # (B,Q,Q)
        scores = cb[..., None] * L * dtq[:, None, :, :]  # (B,Qi,Qj,H)
        y = jnp.einsum("bijh,bjhp->bihp", scores, xc)
        # inter-chunk from carried state
        y = y + jnp.einsum("bin,bhpn,bih->bihp", cc, state, jnp.exp(cs))
        y = y + Dp[None, None, :, None] * xc
        # state update
        decay_out = jnp.exp(total[:, None, :] - cs) * dtq          # (B,Q,H)
        upd = jnp.einsum("bjh,bjn,bjhp->bhpn", decay_out, bc, xc)  # (B,H,P,N)
        state = state * jnp.exp(total)[..., None, None] + upd
        return state, y

    init = jnp.zeros((Bb, H, P, N), jnp.float32)
    final, ys = jax.lax.scan(step, init, (xh, Bv, Cv, dtc, dAc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(Bb, S, d_inner)
    return y, final


def mamba2_cache_spec(cfg, batch: int) -> dict[str, jax.ShapeDtypeStruct]:
    d_inner, nheads = _dims(cfg)
    conv_ch = d_inner + 2 * cfg.ssm_state
    return {
        "conv": jax.ShapeDtypeStruct((batch, cfg.ssm_conv - 1, conv_ch),
                                     common.dt(cfg.compute_dtype)),
        "ssm": jax.ShapeDtypeStruct((batch, nheads, cfg.ssm_headdim,
                                     cfg.ssm_state), jnp.float32),
    }
