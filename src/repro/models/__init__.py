from repro.models import common, model  # noqa: F401
