"""xLSTM blocks: mLSTM (matrix memory, chunked-parallel) and sLSTM
(scalar memory, sequential scan with head-wise recurrence).

mLSTM follows the stabilized chunkwise formulation: a scan over chunks
carries (C, n, m) with the running max folded into the state scale, so the
parallel intra-chunk term stays numerically safe in f32.  sLSTM is
inherently sequential (the xLSTM paper says as much) — a lax.scan over
time with block-diagonal per-head recurrent kernels.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import common

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------
def _mlstm_dims(cfg):
    d_inner = int(cfg.mlstm_proj_factor * cfg.d_model)
    nh = cfg.n_heads
    return d_inner, nh, d_inner // nh


def mlstm_init(key, cfg, dtype) -> Params:
    d = cfg.d_model
    d_inner, nh, dh = _mlstm_dims(cfg)
    ks = jax.random.split(key, 8)
    return {
        "norm": common.rmsnorm_init(d, dtype),
        "up": common.dense_init(ks[0], d, 2 * d_inner, dtype),
        "conv_w": (jax.random.normal(ks[1], (4, d_inner), jnp.float32) * 0.1
                   ).astype(dtype),
        "conv_b": jnp.zeros((d_inner,), dtype),
        "wq": common.dense_init(ks[2], d_inner, d_inner, dtype),
        "wk": common.dense_init(ks[3], d_inner, d_inner, dtype),
        "wv": common.dense_init(ks[4], d_inner, d_inner, dtype),
        "wi": common.dense_init(ks[5], d_inner, nh, jnp.float32),
        "bi": jnp.zeros((nh,), jnp.float32),
        "wf": common.dense_init(ks[6], d_inner, nh, jnp.float32),
        "bf": jnp.full((nh,), 3.0, jnp.float32),   # forget-gate bias init
        "hnorm": common.rmsnorm_init(d_inner, dtype),
        "down": common.dense_init(ks[7], d_inner, d, dtype),
    }


def mlstm_apply(params: Params, x: jnp.ndarray, cfg, *,
                cache: Params | None = None, want_cache: bool = False,
                chunk: int = 128) -> tuple[jnp.ndarray, Params | None]:
    B, S, D = x.shape
    d_inner, nh, dh = _mlstm_dims(cfg)
    h = common.rmsnorm(params["norm"], x, cfg.norm_eps)
    up = h @ params["up"]
    xin, zgate = jnp.split(up, 2, axis=-1)

    from repro.parallel import act_sharding as act
    conv_state = cache["conv"] if cache is not None else None
    xc, new_conv = _conv4(xin, params["conv_w"], params["conv_b"], conv_state)
    # keep the recurrent cell batch-sharded: GSPMD otherwise replicates the
    # whole scan over the model axis (xLSTM cells do not tensor-parallelize;
    # the model axis serves the up/down projections + embedding/loss)
    q = act.constrain((xc @ params["wq"]).reshape(B, S, nh, dh), "data")
    k = act.constrain((xc @ params["wk"]).reshape(B, S, nh, dh), "data")
    v = act.constrain((xin @ params["wv"]).reshape(B, S, nh, dh), "data")
    logi = act.constrain(
        xc.astype(jnp.float32) @ params["wi"] + params["bi"], "data")  # (B,S,NH)
    logf = act.constrain(jax.nn.log_sigmoid(
        xc.astype(jnp.float32) @ params["wf"] + params["bf"]), "data")

    if cache is not None:
        hcell, new_cell = _mlstm_step(cache, q[:, 0], k[:, 0], v[:, 0],
                                      logi[:, 0], logf[:, 0])
        hcell = hcell[:, None]
        new_cache: Params | None = {"conv": new_conv, **new_cell}
    else:
        # manual-SPMD (data-parallel) cell: GSPMD replicates the transposed
        # nested scan otherwise (§Perf log, xlstm hillclimb)
        cell = lambda *a: _mlstm_chunked(*a, chunk)  # noqa: E731
        args = (q, k, v, logi, logf)
        out_ex = jax.eval_shape(cell, *args)
        cell = act.data_shard_map(cell, args, out_ex, B)
        hcell, final = cell(*args)
        new_cache = {"conv": new_conv, **final} if want_cache else None

    hcell = hcell.reshape(B, -1, d_inner).astype(h.dtype)
    out = common.rmsnorm(params["hnorm"], hcell, cfg.norm_eps) * jax.nn.silu(zgate)
    return x + out @ params["down"], new_cache


def _conv4(xin, w, b, state):
    K = w.shape[0]
    if state is not None:
        xp = jnp.concatenate([state, xin], axis=1)
    else:
        xp = jnp.pad(xin, ((0, 0), (K - 1, 0), (0, 0)))
    new_state = xp[:, xp.shape[1] - (K - 1):]
    out = sum(xp[:, i:i + xin.shape[1]] * w[i] for i in range(K)) + b
    return jax.nn.silu(out), new_state


def _mlstm_step(cache, q, k, v, logi, logf):
    """Single decode step.  q,k,v: (B,NH,dh); logi/logf: (B,NH)."""
    dh = q.shape[-1]
    qs = q.astype(jnp.float32) / math.sqrt(dh)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    m_new = jnp.maximum(logf + cache["m"], logi)
    fs = jnp.exp(logf + cache["m"] - m_new)[..., None]
    is_ = jnp.exp(logi - m_new)[..., None]
    C = cache["C"] * fs[..., None] + is_[..., None] * kf[..., :, None] * vf[..., None, :]
    n = cache["n"] * fs + is_ * kf
    num = jnp.einsum("bhk,bhkv->bhv", qs, C)
    den = jnp.abs(jnp.einsum("bhk,bhk->bh", qs, n))
    hcell = num / jnp.maximum(den, jnp.exp(-m_new))[..., None]
    return hcell.reshape(q.shape[0], -1), {"C": C, "n": n, "m": m_new}


def _mlstm_chunked(q, k, v, logi, logf, chunk):
    """Chunkwise-parallel stabilized mLSTM.

    q,k,v: (B,S,NH,dh); logi/logf: (B,S,NH).
    Returns (h (B,S,NH*dh), final {C,n,m}).
    """
    B, S, NH, dh = q.shape
    Q = min(chunk, S)
    while S % Q:
        Q -= 1
    nc = S // Q

    def rsh(t):  # -> (nc, B, NH, Q, ...)
        t = t.reshape(B, nc, Q, *t.shape[2:])
        perm = (1, 0) + tuple(range(3, t.ndim)) + (2,)
        # (B,nc,Q,NH,dh) -> (nc,B,NH,Q,dh); (B,nc,Q,NH) -> (nc,B,NH,Q)
        if t.ndim == 5:
            return t.transpose(1, 0, 3, 2, 4)
        return t.transpose(1, 0, 3, 2)

    qs = rsh(q.astype(jnp.float32) / math.sqrt(dh))
    ks = rsh(k.astype(jnp.float32))
    vs = rsh(v.astype(jnp.float32))
    li = rsh(logi)
    lf = rsh(logf)
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    NEG = -1e30

    def step(carry, inp):
        C, n, m = carry                       # (B,NH,dh,dh), (B,NH,dh), (B,NH)
        qc, kc, vc, lic, lfc = inp
        b = jnp.cumsum(lfc, axis=-1)          # (B,NH,Q) inclusive
        g = b[..., -1]                        # (B,NH)
        a = lic - b                           # logi_j - b_j
        m_local = b + jax.lax.cummax(a, axis=a.ndim - 1)
        m_inter = m[..., None] + b
        m_t = jnp.maximum(m_local, m_inter)   # (B,NH,Q)
        # intra D matrix
        logD = b[..., :, None] - b[..., None, :] + lic[..., None, :] - m_t[..., None]
        logD = jnp.where(tri[None, None], logD, NEG)
        Dm = jnp.exp(logD)                    # (B,NH,Q,Q)
        sc = jnp.einsum("bhik,bhjk->bhij", qc, kc) * Dm
        inter = jnp.exp(b + m[..., None] - m_t)          # (B,NH,Q)
        num = jnp.einsum("bhij,bhjv->bhiv", sc, vc) \
            + inter[..., None] * jnp.einsum("bhik,bhkv->bhiv", qc, C)
        den = sc.sum(-1) + inter * jnp.einsum("bhik,bhk->bhi", qc, n)
        hq = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]
        # carry update
        m_new = jnp.maximum(m + g, g + jax.lax.cummax(a, axis=a.ndim - 1)[..., -1])
        wk = jnp.exp(g[..., None] + a - m_new[..., None])            # (B,NH,Q)
        C = C * jnp.exp(m + g - m_new)[..., None, None] \
            + jnp.einsum("bhj,bhjk,bhjv->bhkv", wk, kc, vc)
        n = n * jnp.exp(m + g - m_new)[..., None] \
            + jnp.einsum("bhj,bhjk->bhk", wk, kc)
        return (C, n, m_new), hq

    C0 = jnp.zeros((B, NH, dh, dh), jnp.float32)
    n0 = jnp.zeros((B, NH, dh), jnp.float32)
    m0 = jnp.full((B, NH), -1e30, jnp.float32)
    (Cf, nf, mf), hs = jax.lax.scan(step, (C0, n0, m0), (qs, ks, vs, li, lf))
    # hs: (nc,B,NH,Q,dh) -> (B,S,NH*dh)
    h = hs.transpose(1, 0, 3, 2, 4).reshape(B, S, NH * dh)
    return h, {"C": Cf, "n": nf, "m": mf}


def mlstm_cache_spec(cfg, batch: int) -> dict[str, jax.ShapeDtypeStruct]:
    d_inner, nh, dh = _mlstm_dims(cfg)
    cdt = common.dt(cfg.compute_dtype)
    return {
        "conv": jax.ShapeDtypeStruct((batch, 3, d_inner), cdt),
        "C": jax.ShapeDtypeStruct((batch, nh, dh, dh), jnp.float32),
        "n": jax.ShapeDtypeStruct((batch, nh, dh), jnp.float32),
        "m": jax.ShapeDtypeStruct((batch, nh), jnp.float32),
    }


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------
def slstm_init(key, cfg, dtype) -> Params:
    d = cfg.d_model
    nh = cfg.n_heads
    dh = d // nh
    ks = jax.random.split(key, 10)
    f_ff = int(cfg.slstm_proj_factor * d)
    p: Params = {"norm": common.rmsnorm_init(d, dtype),
                 "hnorm": common.rmsnorm_init(d, dtype)}
    for i, g in enumerate(("z", "i", "f", "o")):
        p[f"w{g}"] = common.dense_init(ks[i], d, d, dtype)
        p[f"r{g}"] = (jax.random.normal(ks[4 + i], (nh, dh, dh), jnp.float32)
                      / math.sqrt(dh)).astype(dtype)
        p[f"b{g}"] = jnp.full((d,), 1.0 if g == "f" else 0.0, jnp.float32)
    # post up-projection (GeLU MLP, pf ~ 4/3)
    p["ffn_wi"] = common.dense_init(ks[8], d, f_ff, dtype)
    p["ffn_wo"] = common.dense_init(ks[9], f_ff, d, dtype)
    return p


def slstm_apply(params: Params, x: jnp.ndarray, cfg, *,
                cache: Params | None = None, want_cache: bool = False,
                ) -> tuple[jnp.ndarray, Params | None]:
    B, S, D = x.shape
    nh = cfg.n_heads
    dh = D // nh
    from repro.parallel import act_sharding as act
    xn = common.rmsnorm(params["norm"], x, cfg.norm_eps)
    # input contributions, all timesteps at once (batch-sharded: see mlstm)
    pre = {g: act.constrain((xn @ params[f"w{g}"]).astype(jnp.float32)
                            + params[f"b{g}"], "data")
           for g in ("z", "i", "f", "o")}

    rparams = {g: params[f"r{g}"] for g in ("z", "i", "f", "o")}

    def cell(state, t_pre, rp=None):
        rp = rp if rp is not None else rparams
        c, n, m, hprev = state                          # (b,D) x4 (b = local)
        b = hprev.shape[0]
        hh = hprev.reshape(b, nh, dh)
        rec = {g: jnp.einsum("bhk,hkv->bhv", hh,
                             rp[g].astype(jnp.float32)).reshape(b, D)
               for g in ("z", "i", "f", "o")}
        zt = jnp.tanh(t_pre["z"] + rec["z"])
        it = t_pre["i"] + rec["i"]
        ft = t_pre["f"] + rec["f"]
        ot = jax.nn.sigmoid(t_pre["o"] + rec["o"])
        m_new = jnp.maximum(ft + m, it)
        fp = jnp.exp(ft + m - m_new)
        ip = jnp.exp(it - m_new)
        c_new = fp * c + ip * zt
        n_new = fp * n + ip
        h_new = ot * c_new / jnp.maximum(n_new, 1e-6)
        return (c_new, n_new, m_new, h_new), h_new

    if cache is not None:
        state = (cache["c"], cache["n"], cache["m"], cache["h"])
        t_pre = {g: pre[g][:, 0] for g in pre}
        state, h_seq = cell(state, t_pre)
        h_seq = h_seq[:, None]
        new_cache: Params | None = dict(zip("cnmh", state))
    else:
        def scan_cell(pre_bmajor, rp):
            b = pre_bmajor["z"].shape[0]
            state0 = (jnp.zeros((b, D), jnp.float32),
                      jnp.zeros((b, D), jnp.float32),
                      jnp.full((b, D), -1e30, jnp.float32),
                      jnp.zeros((b, D), jnp.float32))
            xs = {g: pre_bmajor[g].transpose(1, 0, 2) for g in pre_bmajor}
            state, hs = jax.lax.scan(lambda s, t: cell(s, t, rp), state0, xs)
            return state, hs.transpose(1, 0, 2)

        # manual-SPMD recurrence (see mlstm_apply / §Perf log)
        out_ex = jax.eval_shape(scan_cell, pre, rparams)
        smcell = act.data_shard_map(scan_cell, (pre,), out_ex, B,
                                    repl_args=(rparams,))
        state, h_seq = smcell(pre, rparams)
        new_cache = dict(zip("cnmh", state)) if want_cache else None

    h_seq = h_seq.astype(x.dtype)
    y = x + h_seq
    # post up-projection MLP
    hn = common.rmsnorm(params["hnorm"], y, cfg.norm_eps)
    y = y + jax.nn.gelu(hn @ params["ffn_wi"], approximate=True) @ params["ffn_wo"]
    return y, new_cache


def slstm_cache_spec(cfg, batch: int) -> dict[str, jax.ShapeDtypeStruct]:
    d = cfg.d_model
    f32 = jnp.float32
    return {k: jax.ShapeDtypeStruct((batch, d), f32) for k in "cnmh"}
