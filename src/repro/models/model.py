"""Model assembly: stage-scanned heterogeneous decoder/encoder LMs.

One composable definition covers all assigned architectures.  The layer
pattern is factored into ``(unit, repeat)`` stages (config); params for
each unit position are stacked over ``repeat`` and executed with
``lax.scan`` (remat per unit), keeping HLO size bounded at paper scale.

Public API:
  init_params(key, cfg)
  loss_and_metrics(params, cfg, batch)        -- training objective
  prefill(params, cfg, batch)                 -- forward + materialize caches
  decode_step(params, cfg, batch, caches)     -- one token, update caches
  cache_specs(cfg, batch, seq)                -- ShapeDtypeStruct cache tree
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs import base as cfglib
from repro.models import common, moe as moelib, ssm, xlstm

Params = dict[str, Any]

ATTN_KINDS = (cfglib.ATTN, cfglib.ATTN_LOCAL, cfglib.ATTN_SHARED)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------
def _init_block(key, kind: str, cfg, dtype) -> Params:
    if kind in ATTN_KINDS:
        k1, k2 = jax.random.split(key)
        p: Params = {"attn": common.attn_init(k1, cfg, dtype)}
        if cfg.d_ff > 0:
            if cfg.moe is not None:
                p["moe"] = moelib.moe_init(k2, cfg, dtype)
            else:
                p["ffn"] = common.ffn_init(k2, cfg, dtype)
        return p
    if kind == cfglib.MAMBA2:
        return {"mamba2": ssm.mamba2_init(key, cfg, dtype)}
    if kind == cfglib.MLSTM:
        return {"mlstm": xlstm.mlstm_init(key, cfg, dtype)}
    if kind == cfglib.SLSTM:
        return {"slstm": xlstm.slstm_init(key, cfg, dtype)}
    raise ValueError(kind)


def init_params(key, cfg) -> Params:
    dtype = common.dt(cfg.param_dtype)
    keys = jax.random.split(key, 8)
    params: Params = {
        "embed": common.embed_init(keys[0], cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": common.rmsnorm_init(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = common.embed_init(keys[1], cfg.vocab_size,
                                              cfg.d_model, dtype)
    if cfg.input_mode == "embeddings":
        params["in_proj"] = common.dense_init(keys[2], cfg.input_embed_dim,
                                              cfg.d_model, dtype)
        params["mask_emb"] = (jax.random.normal(keys[3], (cfg.d_model,),
                                                jnp.float32) * 0.02).astype(dtype)
    if cfg.input_mode == "multimodal":
        params["img_proj"] = common.dense_init(keys[2], cfg.input_embed_dim,
                                               cfg.d_model, dtype)
    if cfglib.ATTN_SHARED in cfg.layer_pattern:
        params["shared_block"] = _init_block(keys[4], cfglib.ATTN, cfg, dtype)

    stages = []
    skey = keys[5]
    for unit, rep in cfg.resolved_stages:
        stage = []
        for kind in unit:
            skey, bkey = jax.random.split(skey)
            if kind == cfglib.ATTN_SHARED:
                stage.append({})      # weights live in params["shared_block"]
            else:
                stage.append(jax.vmap(
                    lambda k, kind=kind: _init_block(k, kind, cfg, dtype))(
                        jax.random.split(bkey, rep)))
        stages.append(tuple(stage))
    params["stages"] = tuple(stages)
    return params


def param_count(params) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(params))


def cast_params(params: Params, cfg) -> Params:
    """Cast floating-point leaves to the compute dtype (mixed precision).

    Numerics-sensitive leaves (gate biases, A_log, routers) are re-upcast
    to f32 at their use sites inside the blocks."""
    cdt = common.dt(cfg.compute_dtype)
    def cast(x):
        return x.astype(cdt) if jnp.issubdtype(x.dtype, jnp.floating) else x
    return jax.tree_util.tree_map(cast, params)


# ---------------------------------------------------------------------------
# Block application
# ---------------------------------------------------------------------------
def _apply_block(kind: str, bparams: Params, x, cfg, *, positions,
                 cache=None, cache_index=None, want_cache=False,
                 shared=None, cache_len=None, block_tables=None,
                 paged_prefill=False, true_lens=None):
    """Returns (x, new_cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    if kind in ATTN_KINDS:
        p = shared if kind == cfglib.ATTN_SHARED else bparams
        ci = cache_index if (cache is not None or want_cache) else None
        if ci is None and want_cache:
            ci = 0
        x, new_cache = common.attn_apply(
            p["attn"], x, cfg,
            kind="attn_local" if kind == cfglib.ATTN_LOCAL else "attn",
            positions=positions, cache=cache,
            cache_index=ci, cache_len=cache_len,
            block_tables=block_tables,
            paged_prefill=paged_prefill, true_lens=true_lens)
        if cfg.d_ff > 0:
            if cfg.moe is not None:
                x, aux = moelib.moe_apply(p["moe"], x, cfg)
            else:
                x = common.ffn_apply(p["ffn"], x, cfg)
        return x, new_cache, aux
    if kind == cfglib.MAMBA2:
        x, c = ssm.mamba2_apply(bparams["mamba2"], x, cfg, cache=cache,
                                want_cache=want_cache)
        return x, c, aux
    if kind == cfglib.MLSTM:
        x, c = xlstm.mlstm_apply(bparams["mlstm"], x, cfg, cache=cache,
                                 want_cache=want_cache)
        return x, c, aux
    if kind == cfglib.SLSTM:
        x, c = xlstm.slstm_apply(bparams["slstm"], x, cfg, cache=cache,
                                 want_cache=want_cache)
        return x, c, aux
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Trunk
# ---------------------------------------------------------------------------
def forward(params: Params, cfg, x, positions, *, caches=None,
            cache_index=None, want_cache=False, cache_len=None,
            block_tables=None, paged_prefill=False, true_lens=None):
    """x: (B,S,D) embedded inputs.  Returns (hidden, new_caches, aux).

    ``paged_prefill=True`` (with ``caches`` holding the paged block pool,
    ``block_tables`` and ``true_lens``) runs the full-sequence fused
    paged prefill: every attention layer computes causal attention over
    the bucket *and* lands its K/V directly in the pool blocks — see
    :func:`repro.models.common.attn_apply`.
    """
    mode = "decode" if caches is not None else (
        "prefill" if want_cache else "train")
    shared = params.get("shared_block")
    aux = jnp.zeros((), jnp.float32)
    new_caches = []

    for si, (unit, rep) in enumerate(cfg.resolved_stages):
        stage_params = params["stages"][si]
        stage_cache = caches[si] if caches is not None else None

        def unit_fn(carry, xs, unit=unit):
            xc, auxc = carry
            if mode == "decode":
                uparams, ucache = xs
            else:
                uparams, ucache = xs, None
            out_caches = []
            for pos, kind in enumerate(unit):
                bc = ucache[pos] if ucache is not None else None
                xc, c, a = _apply_block(
                    kind, uparams[pos], xc, cfg, positions=positions,
                    cache=bc, cache_index=cache_index,
                    want_cache=(mode == "prefill"), shared=shared,
                    cache_len=cache_len, block_tables=block_tables,
                    paged_prefill=paged_prefill, true_lens=true_lens)
                out_caches.append(c)
                auxc = auxc + a
            ys = tuple(out_caches) if mode in ("decode", "prefill") else None
            return (xc, auxc), ys

        if cfg.remat == "unit" and mode == "train":
            unit_fn = jax.checkpoint(unit_fn, prevent_cse=False)

        xs = (stage_params, stage_cache) if mode == "decode" else stage_params
        if cfg.scan_layers:
            (x, aux), ys = jax.lax.scan(unit_fn, (x, aux), xs, length=rep)
        else:
            # unrolled: identical math, layer bodies visible to HLO cost
            # analysis (XLA counts a while body once, not x trip-count)
            ys_list = []
            for r in range(rep):
                xs_r = jax.tree_util.tree_map(lambda t: t[r], xs)
                (x, aux), ys_r = unit_fn((x, aux), xs_r)
                ys_list.append(ys_r)
            ys = (jax.tree_util.tree_map(lambda *ts: jnp.stack(ts), *ys_list)
                  if ys_list and ys_list[0] is not None else None)
        if mode in ("decode", "prefill"):
            new_caches.append(ys)

    h = common.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return h, (tuple(new_caches) if new_caches else None), aux


# ---------------------------------------------------------------------------
# Input embedding
# ---------------------------------------------------------------------------
def embed_inputs(params: Params, cfg, batch: dict) -> tuple[jnp.ndarray, jnp.ndarray]:
    cdt = common.dt(cfg.compute_dtype)
    if cfg.input_mode == "tokens":
        x = jnp.take(params["embed"], batch["tokens"], axis=0).astype(cdt)
        B, S = batch["tokens"].shape
    elif cfg.input_mode == "embeddings":
        x = (batch["embeds"].astype(cdt) @ params["in_proj"].astype(cdt))
        if "frame_mask" in batch:
            x = jnp.where(batch["frame_mask"][..., None],
                          params["mask_emb"].astype(cdt), x)
        B, S = x.shape[:2]
    elif cfg.input_mode == "multimodal":
        x = jnp.take(params["embed"], batch["tokens"], axis=0).astype(cdt)
        if "image_embeds" in batch:       # decode steps are text-only
            img = batch["image_embeds"].astype(cdt) @ \
                params["img_proj"].astype(cdt)
            ipos = batch["image_positions"]                   # (B, Nimg)
            bidx = jnp.arange(x.shape[0])[:, None]
            x = x.at[bidx, ipos].set(img)
        B, S = batch["tokens"].shape
    else:
        raise ValueError(cfg.input_mode)
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    from repro.parallel import act_sharding as act
    return act.shard_tokens(x), positions


def unembed_matrix(params: Params, cfg):
    return params["embed"] if cfg.tie_embeddings else params["lm_head"]


# ---------------------------------------------------------------------------
# Training objective
# ---------------------------------------------------------------------------
def per_token_nll(params: Params, cfg, batch: dict) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (nll (B,S) f32, aux scalar)."""
    from repro.kernels.lm_loss import ops as lm_ops
    params = cast_params(params, cfg)
    x, positions = embed_inputs(params, cfg, batch)
    h, _, aux = forward(params, cfg, x, positions)
    unemb = unembed_matrix(params, cfg).astype(common.dt(cfg.compute_dtype))
    nll = lm_ops.lm_loss(h, unemb, batch["labels"],
                         softcap=cfg.final_softcap, chunk=cfg.loss_chunk,
                         impl="pallas" if cfg.use_pallas else "jnp")
    return nll, aux


def loss_and_metrics(params: Params, cfg, batch: dict,
                     aux_coef: float = 0.01) -> tuple[jnp.ndarray, dict]:
    nll, aux = per_token_nll(params, cfg, batch)
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones_like(nll)
    mask = mask.astype(jnp.float32)
    denom = jnp.maximum(mask.sum(), 1.0)
    ce = (nll * mask).sum() / denom
    loss = ce + (aux_coef * aux if cfg.moe is not None else 0.0)
    return loss, {"ce": ce, "aux": aux, "tokens": denom}


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------
def _logits(params, cfg, h):
    unemb = unembed_matrix(params, cfg).astype(common.dt(cfg.compute_dtype))
    logits = (h @ unemb.T).astype(common.dt(cfg.logit_dtype))
    return common.softcap(logits, cfg.final_softcap)


def prefill(params: Params, cfg, batch: dict, cache_len: int | None = None,
            last_index=None):
    """Full-sequence forward; returns (last-position logits (B,V), caches).

    ``cache_len`` reserves decode budget in attention caches (defaults to
    the prefill length, i.e. no room for new tokens).  ``last_index``
    ((B,) int32) selects each row's logit position instead of the final
    one — for right-padded prompts (the serving engine buckets prompt
    lengths to bound prefill recompiles) the causal mask makes positions
    < true length independent of the padding, so the true-last-token
    logits are exact; the caller is responsible for masking the padded
    cache slots (see ``repro.serving.cache.insert_requests``)."""
    params = cast_params(params, cfg)
    x, positions = embed_inputs(params, cfg, batch)
    h, caches, _ = forward(params, cfg, x, positions, want_cache=True,
                           cache_index=0, cache_len=cache_len)
    if last_index is None:
        hl = h[:, -1:]
    else:
        li = jnp.asarray(last_index, jnp.int32)
        hl = h[jnp.arange(h.shape[0]), li][:, None]
    return _logits(params, cfg, hl)[:, 0], caches


def decode_step(params: Params, cfg, batch: dict, caches):
    """One-token decode.  batch: tokens (B,1) (+ positions), cache_index.

    ``cache_index`` is the KV write slot: a scalar when every row sits at
    the same sequence length (the one-shot demo loop), or a (B,) int32
    vector for per-slot decode where each batch lane is an independent
    request at its own length (the continuous-batching serving engine;
    pair it with per-row ``positions``).

    ``batch["block_tables"]`` ((B, max_len//block_size) int32, optional)
    switches full-attention layers to the paged KV pool layout: each
    lane's KV lives in pool blocks resolved through its block-table row
    (see :func:`repro.models.common.attn_apply`).  Sliding-window and
    recurrent layers keep their per-lane caches either way.

    Returns (logits (B,1,V), new_caches)."""
    params = cast_params(params, cfg)
    x, positions = embed_inputs(params, cfg, batch)
    h, new_caches, _ = forward(params, cfg, x, positions, caches=caches,
                               cache_index=batch["cache_index"],
                               block_tables=batch.get("block_tables"))
    return _logits(params, cfg, h), new_caches


def prefill_paged(params: Params, cfg, batch: dict, caches, *,
                  block_tables, true_lens, last_index):
    """Fused paged prefill: bucket forward + in-place pool KV landing.

    Same contract as :func:`prefill` with ``last_index`` — returns
    ``(true-last-token logits (B, V), new_caches)`` — except ``caches``
    is the live paged block pool and the new K/V is written directly
    into each lane's reserved blocks through ``block_tables`` ((B, R)
    int32, -1 = unreserved) instead of materializing dense per-lane
    slabs for a separate ``insert_requests`` scatter.  ``true_lens``
    ((B,) int32) drives the full-span ``pos`` rewrite that clears a
    previous tenant's stale positions.  Only valid for pure
    full-attention (pool-only) layer patterns; on the jnp dispatch the
    hidden state matches the slab path bit for bit.
    """
    params = cast_params(params, cfg)
    x, positions = embed_inputs(params, cfg, batch)
    h, new_caches, _ = forward(params, cfg, x, positions, caches=caches,
                               block_tables=block_tables,
                               paged_prefill=True, true_lens=true_lens)
    li = jnp.asarray(last_index, jnp.int32)
    hl = h[jnp.arange(h.shape[0]), li][:, None]
    return _logits(params, cfg, hl)[:, 0], new_caches


def decode_and_sample(params: Params, cfg, batch: dict, caches, *,
                      keys, steps, temps, top_ks, top_ps,
                      epilogue_impl: str = "jnp"):
    """One-token decode with the sampler fused into the program.

    :func:`decode_step` minus the logits round-trip: the last-layer
    hidden state goes straight through the fused epilogue dispatch
    (:mod:`repro.kernels.sample_epilogue.ops`), so the ``(B, vocab)``
    logits never leave the program — returns ``(tokens (B,) int32,
    new_caches)``.  Sampling operands follow
    :func:`repro.serving.sampling.sample_tokens`'s per-row contract and
    the token stream is bitwise identical to ``decode_step`` +
    ``sample_tokens`` on the jnp dispatch by construction.
    """
    from repro.kernels.sample_epilogue import ops as ep_ops
    params = cast_params(params, cfg)
    x, positions = embed_inputs(params, cfg, batch)
    h, new_caches, _ = forward(params, cfg, x, positions, caches=caches,
                               cache_index=batch["cache_index"],
                               block_tables=batch.get("block_tables"))
    unemb = unembed_matrix(params, cfg).astype(common.dt(cfg.compute_dtype))
    tok = ep_ops.decode_and_sample(
        h, unemb, keys=keys, steps=steps, temps=temps, top_ks=top_ks,
        top_ps=top_ps, final_softcap=cfg.final_softcap,
        logit_dtype=common.dt(cfg.logit_dtype), impl=epilogue_impl)
    return tok, new_caches


def decode_greedy(params: Params, cfg, batch: dict, caches, *,
                  epilogue_impl: str = "jnp"):
    """One-token greedy decode with the argmax fused into the program.

    Returns ``(tokens (B,) int32, new_caches)``; see
    :func:`decode_and_sample`.
    """
    from repro.kernels.sample_epilogue import ops as ep_ops
    params = cast_params(params, cfg)
    x, positions = embed_inputs(params, cfg, batch)
    h, new_caches, _ = forward(params, cfg, x, positions, caches=caches,
                               cache_index=batch["cache_index"],
                               block_tables=batch.get("block_tables"))
    unemb = unembed_matrix(params, cfg).astype(common.dt(cfg.compute_dtype))
    tok = ep_ops.decode_greedy(
        h, unemb, final_softcap=cfg.final_softcap,
        logit_dtype=common.dt(cfg.logit_dtype), impl=epilogue_impl)
    return tok, new_caches


# ---------------------------------------------------------------------------
# Cache specs (for dry-runs: ShapeDtypeStruct stand-ins, no allocation)
# ---------------------------------------------------------------------------
def _block_cache_spec(kind: str, cfg, batch: int, seq: int):
    if kind in ATTN_KINDS:
        k = "attn_local" if kind == cfglib.ATTN_LOCAL else "attn"
        return common.attn_cache_spec(cfg, batch, seq, k)
    if kind == cfglib.MAMBA2:
        return ssm.mamba2_cache_spec(cfg, batch)
    if kind == cfglib.MLSTM:
        return xlstm.mlstm_cache_spec(cfg, batch)
    if kind == cfglib.SLSTM:
        return xlstm.slstm_cache_spec(cfg, batch)
    raise ValueError(kind)


def cache_specs(cfg, batch: int, seq: int):
    """Mirror of the cache pytree as ShapeDtypeStructs (stacked per stage)."""
    def stack(spec, rep):
        return jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct((rep,) + s.shape, s.dtype), spec)

    out = []
    for unit, rep in cfg.resolved_stages:
        out.append(tuple(stack(_block_cache_spec(k, cfg, batch, seq), rep)
                         for k in unit))
    return tuple(out)


def paged_cache_specs(cfg, lanes: int, n_blocks: int, block_size: int,
                      max_len: int):
    """Cache pytree specs for the paged serving layout.

    Full-attention layers share one KV block pool per layer
    ((n_blocks+1, block_size, ...) — see ``common.attn_pool_spec``);
    sliding-window layers keep their per-lane rotating buffer (already
    O(window), paging it buys nothing) and recurrent layers their O(1)
    per-lane state.  The tree structure matches :func:`cache_specs`, only
    the full-attention leaf shapes differ.
    """
    def spec(kind):
        if kind in (cfglib.ATTN, cfglib.ATTN_SHARED):
            return common.attn_pool_spec(cfg, n_blocks, block_size)
        return _block_cache_spec(kind, cfg, lanes, max_len)

    def stack(s, rep):
        return jax.tree_util.tree_map(
            lambda t: jax.ShapeDtypeStruct((rep,) + t.shape, t.dtype), s)

    out = []
    for unit, rep in cfg.resolved_stages:
        out.append(tuple(stack(spec(k), rep) for k in unit))
    return tuple(out)
