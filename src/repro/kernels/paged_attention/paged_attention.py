"""Pallas TPU kernel: block-table paged single-token GQA decode attention.

The vLLM/PagedAttention read pattern for the serving engine's KV block
pool: instead of gathering every lane's ``nb`` blocks into a contiguous
``(B, max_len, Hkv, hd)`` view per layer per tick — O(lanes * max_len)
HBM traffic regardless of how many tokens are actually live — the grid
walks ``(lane, kv_head, block)`` with the block index innermost and lets
the BlockSpec index map chase each lane's block table directly: the
tables arrive via scalar prefetch (SMEM), so step ``(b, h, i)`` DMAs
pool block ``tables[b, i]`` (scratch for ``-1`` entries, whose compute
is skipped via ``pl.when``).  An online-softmax accumulator ``(m, l,
acc)`` in VMEM scratch merges blocks; masking follows the dense decode
oracle — slot positions ``< 0`` (never written) or ``> q_pos`` (the
future) drop out, with tanh soft-capping applied before the mask.

Masked probabilities are zeroed *exactly* (``p *= valid``), so a block
that is entirely dead contributes nothing even while the running max is
still at the ``NEG`` sentinel; a fully-dead lane (``q_pos < 0``) yields
zeros (the jnp oracle emits the degenerate uniform average instead —
dead-lane output is unspecified and ignored by the engine).

Decode is forward-only (no VJP needed), and the kernel runs under
``interpret=True`` on CPU JAX — that is how CI exercises it (see the
``kernels-interpret`` job) and how the fuzz suite in
``tests/test_kernels_paged_attention.py`` checks it against the gather
oracle without a TPU.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _kernel(tables_ref, qpos_ref, q_ref, k_ref, v_ref, pos_ref, o_ref,
            m_ref, l_ref, acc_ref, *, scale: float, softcap: float, nb: int):
    b = pl.program_id(0)
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    live = tables_ref[b, i] >= 0

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale       # (g, hd)
        k = k_ref[0, :, 0].astype(jnp.float32)            # (bs, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (g, bs)
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        pos = pos_ref[0]                                  # (bs,) int32
        qp = qpos_ref[b]
        valid = (pos >= 0) & (pos <= qp)                  # (bs,)
        s = jnp.where(valid[None, :], s, NEG)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        # exact-zero masked probabilities: with every slot so far dead the
        # running max still sits at NEG and exp(s - m) would be 1, not 0
        p = jnp.exp(s - m_new[:, None]) * valid[None, :].astype(jnp.float32)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + \
            jax.lax.dot(p, v_ref[0, :, 0].astype(jnp.float32))
        m_ref[...] = m_new

    @pl.when(i == nb - 1)
    def _emit():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)


def paged_decode_attention_pallas(q, k_pool, v_pool, pos_pool, block_tables, *,
                                  q_pos, softcap: float = 0.0,
                                  interpret: bool | None = None) -> jnp.ndarray:
    """Single-step paged GQA decode over the KV block pool.

    q: (B,1,Hq,hd); k_pool/v_pool: (n_blocks+1, bs, Hkv, hd) with row
    ``n_blocks`` the scratch block; pos_pool: (n_blocks+1, bs) int32;
    block_tables: (B, nb) int32 (-1 = unreserved); q_pos: (B,1) or (B,)
    int32 (-1 = dead lane).  Returns (B,1,Hq,hd); reads only live blocks.
    ``interpret=None`` resolves by backend: compiled on TPU, the Pallas
    interpreter everywhere else (CPU CI, tests).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, one, Hq, hd = q.shape
    assert one == 1, "paged decode is single-token"
    n_rows, bs, Hkv, _ = k_pool.shape
    scratch = n_rows - 1
    g = Hq // Hkv
    nb = block_tables.shape[1]
    scale = 1.0 / math.sqrt(hd)
    tables = jnp.asarray(block_tables, jnp.int32)
    qpos = jnp.asarray(q_pos, jnp.int32).reshape(B)

    def kv_map(b, h, i, t, qp):
        blk = t[b, i]
        return (jnp.where(blk >= 0, blk, scratch), 0, h, 0)

    def pos_map(b, h, i, t, qp):
        blk = t[b, i]
        return (jnp.where(blk >= 0, blk, scratch), 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, nb),
        in_specs=[
            pl.BlockSpec((1, 1, g, hd), lambda b, h, i, t, qp: (b, 0, h, 0)),
            pl.BlockSpec((1, bs, 1, hd), kv_map),
            pl.BlockSpec((1, bs, 1, hd), kv_map),
            pl.BlockSpec((1, bs), pos_map),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd),
                               lambda b, h, i, t, qp: (b, 0, h, 0)),
        scratch_shapes=[pltpu.VMEM((g,), jnp.float32),
                        pltpu.VMEM((g,), jnp.float32),
                        pltpu.VMEM((g, hd), jnp.float32)],
    )
    kern = functools.partial(_kernel, scale=scale, softcap=softcap, nb=nb)
    return pl.pallas_call(
        kern, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(tables, qpos, q, k_pool, v_pool, pos_pool)
