"""Unified entry point for single-token decode attention.

One signature covers both decode cache layouts the serving engine uses:

* **dense / sliding-window** — ``k``/``v`` are per-lane slabs
  ``(B, Skv, Hkv, hd)`` with slot positions ``kv_pos (B, Skv)`` (the
  rotating O(window) buffer of local layers, or the unpaged demo path);
* **paged** (``block_tables`` given) — ``k``/``v`` are the shared block
  pool ``(n_blocks+1, bs, Hkv, hd)``, ``kv_pos`` the pool's per-slot
  positions ``(n_blocks+1, bs)``, and ``block_tables (B, nb)`` maps each
  lane's position range ``[i*bs, (i+1)*bs)`` to a pool block (-1 =
  unreserved).

``impl`` selects the implementation and is validated instead of being
silently ignored: ``"jnp"`` is the reference (paged: the gather oracle
that keeps engine tokens bitwise identical to ``serving/baseline.py``);
``"pallas"`` is the block-table-chasing TPU kernel (paged layout only —
runs under ``interpret=True`` on CPU).  The dense path has no Pallas
kernel on purpose: sliding-window buffers are already O(window) and
gather-free, so ``impl="pallas"`` there is a configuration error, not a
fallback.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.paged_attention import ref as _ref

VALID_IMPLS = ("jnp", "pallas")


def decode_attention(q, k, v, *, q_pos, kv_pos, block_tables=None,
                     window: int = 0, softcap: float = 0.0,
                     impl: str = "jnp",
                     interpret: bool | None = None) -> jnp.ndarray:
    """Single-token GQA decode; q: (B,1,Hq,hd) -> (B,1,Hq,hd).

    See the module docstring for the two (k, v, kv_pos) layouts selected
    by ``block_tables``.  ``window`` (sliding-window masking) applies to
    the dense layout only — paged KV is full attention by construction.
    ``interpret=None`` lets the Pallas kernel pick by backend (compiled
    on TPU, interpreter on CPU).
    """
    if impl not in VALID_IMPLS:
        raise ValueError(f"decode_attention impl must be one of "
                         f"{VALID_IMPLS}, got {impl!r}")
    if block_tables is None:
        if impl == "pallas":
            raise ValueError(
                "decode_attention impl='pallas' needs the paged layout "
                "(block_tables): dense / sliding-window decode has no "
                "Pallas kernel — its per-lane buffer is already O(window) "
                "and gather-free; use impl='jnp'")
        return fa_ref.decode_attention_ref(q, k, v, q_pos=q_pos,
                                           kv_pos=kv_pos, window=window,
                                           softcap=softcap)
    if window:
        raise ValueError(f"paged decode covers full-attention layers only "
                         f"(sliding-window layers keep their rotating "
                         f"per-lane buffer), got window={window}")
    if impl == "pallas":
        from repro.kernels.paged_attention import paged_attention as _pl
        return _pl.paged_decode_attention_pallas(
            q, k, v, kv_pos, block_tables, q_pos=q_pos, softcap=softcap,
            interpret=interpret)
    return _ref.paged_decode_attention_ref(q, k, v, kv_pos, block_tables,
                                           q_pos=q_pos, softcap=softcap)
