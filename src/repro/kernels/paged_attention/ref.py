"""Pure-jnp oracle for block-table paged decode attention.

The serving engine keeps full-attention KV in a shared per-layer block
pool (``repro.serving.cache``): k/v ``(n_blocks+1, block_size, Hkv, hd)``
plus slot positions ``pos (n_blocks+1, block_size)``, with row
``n_blocks`` a scratch block for inactive lanes.  A lane's KV is
addressed through its block-table row ``(nb,)`` int32 (-1 = unreserved).

This reference *gathers* a lane's blocks back into the dense-slab slot
order (position p of a lane lands at gathered slot p) and then runs the
ordinary dense decode oracle — exactly the computation the engine's
decode path performed before the Pallas kernel existed, so engine tokens
through this path stay **bitwise identical** to ``serving/baseline.py``
(the oracle contract in ``tests/test_serving.py``).  The Pallas kernel
(:mod:`repro.kernels.paged_attention.paged_attention`) replaces the
gather with per-block reads + online softmax and must match this oracle
within fp tolerance on live lanes.

Dead lanes (``q_pos < 0`` or an all ``-1`` block-table row) have every
KV slot masked; their output is unspecified (this gather path emits the
uniform average the masked softmax degenerates to, the Pallas kernel
emits zeros) and callers must ignore it — the engine does.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.flash_attention import ref as fa_ref


def gather_lane_view(k_pool, v_pool, pos_pool, block_tables):
    """Resolve block tables into contiguous per-lane (B, nb*bs, ...) views.

    Unreserved rows (``block_tables < 0``) read the scratch block and
    have their positions forced to -1, so every gathered slot beyond a
    lane's reservation is masked.  Slot order equals the dense slab
    layout: position p sits at gathered slot ``(p // bs) * bs + p % bs
    == p``.
    """
    B, nb = block_tables.shape
    scratch = k_pool.shape[0] - 1
    bs = k_pool.shape[1]
    safe = jnp.where(block_tables >= 0, block_tables, scratch)
    kl = k_pool[safe].reshape((B, nb * bs) + k_pool.shape[2:])
    vl = v_pool[safe].reshape((B, nb * bs) + v_pool.shape[2:])
    pl = jnp.where(block_tables[..., None] >= 0, pos_pool[safe],
                   -1).reshape(B, nb * bs)
    return kl, vl, pl


def paged_decode_attention_ref(q, k_pool, v_pool, pos_pool, block_tables, *,
                               q_pos, softcap: float = 0.0) -> jnp.ndarray:
    """Single-step paged GQA decode oracle.

    q: (B,1,Hq,hd); k_pool/v_pool: (n_blocks+1, bs, Hkv, hd);
    pos_pool: (n_blocks+1, bs) int32; block_tables: (B, nb) int32;
    q_pos: (B,1) int32 (-1 = dead lane).  Returns (B,1,Hq,hd).
    """
    kl, vl, pl = gather_lane_view(k_pool, v_pool, pos_pool, block_tables)
    return fa_ref.decode_attention_ref(q, kl, vl, q_pos=q_pos, kv_pos=pl,
                                       softcap=softcap)
