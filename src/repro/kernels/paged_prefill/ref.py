"""Pure-jnp reference for fused paged prefill (the bitwise contract).

The legacy admission path computes causal flash attention over a
``(K, bucket)`` batch of right-padded prompts into a *dense* per-request
KV slab spanning the whole ``max_len`` decode budget, then a separate
jitted scatter (:func:`repro.serving.cache.insert_requests`) copies that
slab into the reserved pool blocks.  That is two full-span HBM writes of
every request's KV per admission.

The fused path replaces both with one op per attention layer:

  * the **attention output** is computed by *exactly* the same call the
    dense-slab prefill made (:func:`repro.kernels.flash_attention.ops.
    flash_attention` over the padded bucket, causal, ``q_chunk=1024``) —
    last-token logits are therefore bitwise identical by construction;
  * the new K/V lands **directly in the pool**: position ``p`` of lane
    ``i`` goes to ``(block_tables[i, p // bs], p % bs)``, unreserved rows
    and padding lanes clamp to the scratch row (``n_blocks``) exactly
    like ``insert_requests``;
  * the ``pos`` leaf is written over the lane's **full reserved span**
    with ``insert_requests``' mask (``p`` where ``p < true_len``, else
    ``-1``), so a previous tenant's stale positions in the growth blocks
    are cleared in the same op and the pool state after the fused op is
    **bitwise identical** to slab + scatter (K/V beyond the prompt span
    differ only behind the ``pos = -1`` mask, which the decode read
    treats as garbage either way — ``tests/test_kernels_paged_prefill``
    pins the readable state, i.e. the gathered lane view).

Blocks owned by other lanes (shared copy-on-write prefix blocks
included) are never touched: every write index resolves through the
caller's block tables or clamps to scratch.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.flash_attention import ops as fa


def scatter_kv(k, v, *, block_tables, true_lens, k_pool, v_pool, pos_pool):
    """Land a prefill bucket's K/V in the pool through the block tables.

    k, v: (K, S, Hkv, hd) new KV for the padded prompt bucket, position
    ``s`` of lane ``i`` being prompt position ``s`` (fresh-lane admission
    always prefills from position 0); block_tables: (K, R) int32
    full-span reserved rows (-1 = unreserved, padding lanes all -1);
    true_lens: (K,) int32 un-padded prompt lengths; pools as in
    :mod:`repro.serving.cache`.  Returns (k_pool', v_pool', pos_pool').

    The ``pos`` write covers all ``R * bs`` positions of every lane
    (stale-position clearing included); the k/v write covers the bucket.
    """
    K, S = k.shape[:2]
    n_rows, bs = pos_pool.shape
    scratch = n_rows - 1
    R = block_tables.shape[1]
    p = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (K, S))
    tl = jnp.asarray(true_lens, jnp.int32)
    tables = jnp.asarray(block_tables, jnp.int32)
    # k/v: position-addressed scatter over the bucket span
    bi = jnp.clip(jnp.where(p >= 0, p // bs, 0), 0, R - 1)
    blk = jnp.take_along_axis(tables, bi, axis=1)           # (K, S)
    wblk = jnp.where((p >= 0) & (blk >= 0), blk, scratch)
    off = jnp.where(p >= 0, p % bs, 0)
    k_pool = k_pool.at[wblk, off].set(k)
    v_pool = v_pool.at[wblk, off].set(v)
    # pos: insert_requests' full-span semantics — every reserved row gets
    # `position if position < true_len else -1`, clearing stale entries
    span = jnp.arange(R * bs, dtype=jnp.int32)[None, :]     # (1, R*bs)
    vals = jnp.where(span < tl[:, None], span, -1)          # (K, R*bs)
    ids = jnp.where(tables >= 0, tables, scratch).reshape(-1)
    pos_pool = pos_pool.at[ids].set(
        vals.reshape(K * R, bs).astype(pos_pool.dtype))
    return k_pool, v_pool, pos_pool


def paged_prefill_attention_ref(q, k, v, *, block_tables, true_lens,
                                k_pool, v_pool, pos_pool,
                                softcap: float = 0.0, q_chunk: int = 1024):
    """Fused paged prefill, jnp reference.

    q: (K, S, Hq, hd); k, v: (K, S, Hkv, hd) — post-RoPE, padded to the
    bucket.  Returns ``(out, k_pool', v_pool', pos_pool')`` where ``out``
    is bitwise identical to the dense-slab prefill's attention output
    (same blockwise flash call) and the pools carry the scattered KV.
    """
    out = fa.flash_attention(q, k, v, causal=True, window=0,
                             softcap=softcap, impl="jnp", q_chunk=q_chunk)
    k_pool, v_pool, pos_pool = scatter_kv(
        k, v, block_tables=block_tables, true_lens=true_lens,
        k_pool=k_pool, v_pool=v_pool, pos_pool=pos_pool)
    return out, k_pool, v_pool, pos_pool
