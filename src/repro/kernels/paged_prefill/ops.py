"""Unified entry point for fused paged prefill.

One op computes causal flash attention for a batch of pow2-bucketed
prompts **and** lands the new K/V directly in the block pool through
each lane's block table — replacing the dense ``(K, max_len)`` slab +
separate ``insert_requests`` scatter of the legacy admission path with
a single program whose write traffic is the bucket itself.

``impl`` is validated instead of silently ignored: ``"jnp"`` is the
reference (attention via the exact blockwise flash call the slab path
made, so last-token logits — and hence engine tokens — stay bitwise
identical to ``serving/baseline.py``; scatter via ``.at[].set``);
``"pallas"`` reuses the Pallas flash kernel and lands K/V with a
scalar-prefetch table-chasing writer kernel aliased onto the pools
(runs under ``interpret=True`` on CPU).

Contract (both impls): position ``s`` of lane ``i`` is prompt position
``s`` — fresh-lane admission prefills from position 0, RoPE already
applied by the caller; ``pos`` is rewritten over every lane's full
reserved span with ``insert_requests``' mask, clearing stale positions
from a previous tenant; blocks not in ``block_tables`` (other lanes',
shared copy-on-write prefix blocks) are never written.
"""
from __future__ import annotations

from repro.kernels.paged_prefill import ref as _ref

VALID_IMPLS = ("jnp", "pallas")


def paged_prefill_attention(q, k, v, *, block_tables, true_lens,
                            k_pool, v_pool, pos_pool,
                            softcap: float = 0.0, impl: str = "jnp",
                            interpret: bool | None = None,
                            q_chunk: int = 1024):
    """Fused paged prefill over one padded bucket.

    q: (K, S, Hq, hd); k, v: (K, S, Hkv, hd) post-RoPE; block_tables:
    (K, R) int32 (-1 = unreserved); true_lens: (K,) int32; pools as in
    :mod:`repro.serving.cache` (single replication slice).  Returns
    ``(out, k_pool', v_pool', pos_pool')``.  ``q_chunk`` applies to the
    jnp blockwise attention only; ``interpret=None`` lets the Pallas
    kernels pick by backend (compiled on TPU, interpreter on CPU).
    """
    if impl not in VALID_IMPLS:
        raise ValueError(f"paged_prefill_attention impl must be one of "
                         f"{VALID_IMPLS}, got {impl!r}")
    K, S, Hq, hd = q.shape
    if k.shape != (K, S) + k.shape[2:] or k.shape != v.shape:
        raise ValueError(f"k/v must be (K, S, Hkv, hd) matching q's "
                         f"(K, S)={K, S}: got k={k.shape} v={v.shape}")
    Hkv = k.shape[2]
    if Hq % Hkv or k.shape[3] != hd:
        raise ValueError(f"GQA shapes q={q.shape} k={k.shape}: Hq must be "
                         f"a multiple of Hkv and head dims must match")
    n_rows, bs = pos_pool.shape
    if k_pool.shape != (n_rows, bs, Hkv, hd) or k_pool.shape != v_pool.shape:
        raise ValueError(f"pools must be (n_rows, bs, Hkv, hd)="
                         f"{(n_rows, bs, Hkv, hd)} with pos_pool "
                         f"(n_rows, bs): got k_pool={k_pool.shape} "
                         f"v_pool={v_pool.shape} pos_pool={pos_pool.shape}")
    if block_tables.ndim != 2 or block_tables.shape[0] != K:
        raise ValueError(f"block_tables must be (K, R) with K={K}, got "
                         f"{block_tables.shape}")
    if S > block_tables.shape[1] * bs:
        raise ValueError(f"bucket S={S} exceeds the reserved span "
                         f"R*bs={block_tables.shape[1] * bs}: admission "
                         f"must reserve the full prompt before prefill")
    if true_lens.shape != (K,):
        raise ValueError(f"true_lens must be (K,), got {true_lens.shape}")
    if impl == "pallas":
        from repro.kernels.paged_prefill import paged_prefill as _pl
        return _pl.paged_prefill_attention_pallas(
            q, k, v, block_tables=block_tables, true_lens=true_lens,
            k_pool=k_pool, v_pool=v_pool, pos_pool=pos_pool,
            softcap=softcap, interpret=interpret)
    return _ref.paged_prefill_attention_ref(
        q, k, v, block_tables=block_tables, true_lens=true_lens,
        k_pool=k_pool, v_pool=v_pool, pos_pool=pos_pool,
        softcap=softcap, q_chunk=q_chunk)
