from repro.kernels.paged_prefill import ops, ref  # noqa: F401
