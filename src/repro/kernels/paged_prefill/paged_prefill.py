"""Pallas fused paged prefill: flash attention + in-place pool landing.

One jitted program, two kernels, zero dense slabs:

  1. attention over the padded bucket reuses the blockwise Pallas flash
     kernel (:func:`repro.kernels.flash_attention.flash_attention.
     flash_attention_pallas`) unchanged;
  2. the new K/V lands straight in the block pool through a
     scalar-prefetch **table-chasing writer kernel** whose output
     BlockSpecs resolve each grid step's destination from the lane's
     block table (``paged_attention.py``'s prefetch pattern, applied to
     the write side), with ``input_output_aliases`` so the pools are
     updated in place — no ``(K, max_len)`` slab is ever materialized
     and no separate ``insert_requests`` scatter re-reads it.

Writer grid is ``(K, Hkv, R)`` over lanes x kv-heads x reserved rows.
Every grid step fully defines its output block (Pallas flushes the
output buffer each step regardless, so partial writes would leak stale
buffer contents): bucket rows copy the new K/V tile, growth rows beyond
the bucket copy the pool block through unchanged, and the ``pos`` block
is rewritten over the lane's full reserved span with
``insert_requests``' mask — clearing a previous tenant's stale
positions in the same pass.  Unreserved table entries (and padding
lanes, table all ``-1``) clamp to the scratch row, so blocks owned by
other lanes — shared copy-on-write prefix blocks included — are never
addressed, let alone written.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.flash_attention.flash_attention import (
    flash_attention_pallas,
)


def _writer_kernel(tables_ref, tlens_ref, k_ref, v_ref,
                   kp_in, vp_in, pp_in, kp_out, vp_out, pp_out,
                   *, bs: int, nkb: int):
    b = pl.program_id(0)
    j = pl.program_id(2)
    inside = j < nkb  # rows past the bucket keep their K/V (growth span)
    kp_out[...] = jnp.where(inside, k_ref[...], kp_in[...])
    vp_out[...] = jnp.where(inside, v_ref[...], vp_in[...])
    p = j * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
    pp_out[...] = jnp.where(p < tlens_ref[b], p, -1)


def scatter_kv_pallas(k, v, *, block_tables, true_lens,
                      k_pool, v_pool, pos_pool, interpret=None):
    """Table-chasing in-place pool write of a prefill bucket's K/V.

    Same contract as :func:`repro.kernels.paged_prefill.ref.scatter_kv`:
    position ``s`` of lane ``i`` is prompt position ``s``; ``pos`` is
    rewritten over each lane's full ``R * bs`` reserved span.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    K, S, Hkv, hd = k.shape
    n_rows, bs = pos_pool.shape
    scratch = n_rows - 1
    R = block_tables.shape[1]
    tables = jnp.asarray(block_tables, jnp.int32)
    tlens = jnp.asarray(true_lens, jnp.int32)
    if S % bs:
        pad = bs - S % bs
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nkb = k.shape[1] // bs  # bucket rows; grid also covers growth rows

    def row_of(b, j, t, tl):
        blk = t[b, j]
        return jnp.where(blk >= 0, blk, scratch)

    def kv_new_map(b, h, j, t, tl):
        return (b, jnp.minimum(j, nkb - 1), h, 0)

    def kv_pool_map(b, h, j, t, tl):
        return (row_of(b, j, t, tl), 0, h, 0)

    def pos_map(b, h, j, t, tl):
        return (row_of(b, j, t, tl), 0)

    kv_new_spec = pl.BlockSpec((1, bs, 1, hd), kv_new_map)
    kv_pool_spec = pl.BlockSpec((1, bs, 1, hd), kv_pool_map)
    pos_spec = pl.BlockSpec((1, bs), pos_map)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(K, Hkv, R),
        in_specs=[kv_new_spec, kv_new_spec,
                  kv_pool_spec, kv_pool_spec, pos_spec],
        out_specs=[kv_pool_spec, kv_pool_spec, pos_spec],
    )
    kernel = functools.partial(_writer_kernel, bs=bs, nkb=nkb)
    # alias indices count *all* inputs, scalar-prefetch operands included:
    # (tables, tlens, k, v, k_pool, v_pool, pos_pool) -> pools are 4..6
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct(k_pool.shape, k_pool.dtype),
            jax.ShapeDtypeStruct(v_pool.shape, v_pool.dtype),
            jax.ShapeDtypeStruct(pos_pool.shape, pos_pool.dtype),
        ],
        input_output_aliases={4: 0, 5: 1, 6: 2},
        interpret=interpret,
    )(tables, tlens, k, v, k_pool, v_pool, pos_pool)


def paged_prefill_attention_pallas(q, k, v, *, block_tables, true_lens,
                                   k_pool, v_pool, pos_pool,
                                   softcap: float = 0.0, interpret=None):
    """Fused paged prefill, Pallas implementation.

    Causal flash attention over the bucket plus the in-place pool write;
    returns ``(out, k_pool', v_pool', pos_pool')`` like the reference.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    out = flash_attention_pallas(q, k, v, causal=True, window=0,
                                 softcap=softcap, interpret=interpret)
    k_pool, v_pool, pos_pool = scatter_kv_pallas(
        k, v, block_tables=block_tables, true_lens=true_lens,
        k_pool=k_pool, v_pool=v_pool, pos_pool=pos_pool,
        interpret=interpret)
    return out, k_pool, v_pool, pos_pool
