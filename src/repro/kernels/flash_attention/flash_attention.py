"""Pallas TPU flash attention (fwd) with causal / sliding-window masks,
tanh logit soft-capping and GQA.

Grid: (batch, q_head, q_tiles, kv_tiles) with kv innermost; online-softmax
state (m, l, acc) lives in VMEM scratch and the output tile is emitted at
the last kv step.  Fully-masked tiles (above the causal diagonal or left of
the sliding window) skip their matmuls via ``pl.when`` — this is the 2x
FLOP saving over the XLA blockwise path on causal shapes.

Backward: custom_vjp that recomputes with the blockwise-jnp reference
(XLA) — the paper's hot inference path (router scoring + expert prefill)
is forward-only, so the fwd kernel is where the VMEM tiling matters.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.flash_attention import ref as _ref

NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window: int, softcap: float,
            tq: int, tk: int, nk: int):
    i = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_lo = i * tq
    q_hi = q_lo + tq - 1
    k_lo = j * tk
    k_hi = k_lo + tk - 1
    live = True
    if causal:
        live = jnp.logical_and(live, k_lo <= q_hi)
    if window > 0:
        live = jnp.logical_and(live, k_hi > q_lo - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale        # (tq, d)
        k = k_ref[0, 0].astype(jnp.float32)                # (tk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        rows = q_lo + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 0)
        cols = k_lo + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 1)
        mask = jnp.ones((tq, tk), jnp.bool_)
        if causal:
            mask &= cols <= rows
        if window > 0:
            mask &= cols > rows - window
        s = jnp.where(mask, s, NEG)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + \
            jax.lax.dot(p, v_ref[0, 0].astype(jnp.float32))
        m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _emit():
        o_ref[0, 0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)


def _flash_fwd(q, k, v, *, causal, window, softcap, tq, tk, interpret):
    """q: (B,Hq,Sq,d); k,v: (B,Hkv,Skv,d) — head-major layout."""
    B, Hq, Sq, d = q.shape
    _, Hkv, Skv, _ = k.shape
    g = Hq // Hkv
    nq, nk = Sq // tq, Skv // tk
    scale = 1.0 / math.sqrt(d)
    f32 = jnp.float32
    kern = functools.partial(_kernel, scale=scale, causal=causal,
                             window=window, softcap=softcap,
                             tq=tq, tk=tk, nk=nk)
    return pl.pallas_call(
        kern,
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, tq, d), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, tk, d), lambda b, h, i, j: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, tk, d), lambda b, h, i, j: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, tq, d), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[pltpu.VMEM((tq,), f32), pltpu.VMEM((tq,), f32),
                        pltpu.VMEM((tq, d), f32)],
        interpret=interpret,
    )(q, k, v)


def _tiles(Sq: int, Skv: int) -> tuple[int, int]:
    tq = min(256, Sq)
    while Sq % tq:
        tq -= 1
    tk = min(512, Skv)
    while Skv % tk:
        tk -= 1
    return tq, tk


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash(q, k, v, causal, window, softcap, interpret):
    B, Sq, Hq, d = q.shape
    tq, tk = _tiles(Sq, k.shape[1])
    out = _flash_fwd(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                     v.transpose(0, 2, 1, 3), causal=causal, window=window,
                     softcap=softcap, tq=tq, tk=tk, interpret=interpret)
    return out.transpose(0, 2, 1, 3)


def _flash_vjp_fwd(q, k, v, causal, window, softcap, interpret):
    return _flash(q, k, v, causal, window, softcap, interpret), (q, k, v)


def _flash_vjp_bwd(causal, window, softcap, interpret, res, do):
    q, k, v = res
    _, vjp = jax.vjp(lambda q, k, v: _ref.blockwise_attention(
        q, k, v, causal=causal, window=window, softcap=softcap), q, k, v)
    return vjp(do)


_flash.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


def flash_attention_pallas(q, k, v, *, causal: bool = True, window: int = 0,
                           softcap: float = 0.0,
                           interpret: bool = True) -> jnp.ndarray:
    """q: (B,Sq,Hq,hd); k,v: (B,Skv,Hkv,hd) -> (B,Sq,Hq,hd)."""
    return _flash(q, k, v, causal, window, softcap, interpret)
