"""Pure-jnp oracles for the flash-attention kernel.

``mha_reference`` materializes the full (Sq, Skv) score matrix — the
ground-truth oracle for kernel tests.  ``blockwise_attention`` is the
memory-bounded online-softmax implementation used by the model code on
CPU / in dry-runs (the Pallas kernel replaces it on real TPU).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _soft_cap(x, cap: float):
    return jnp.tanh(x / cap) * cap if cap else x


def _mask(q_pos, kv_pos, causal: bool, window: int):
    """q_pos: (..., Sq), kv_pos: (..., Skv) -> bool (..., Sq, Skv)."""
    m = jnp.ones(q_pos.shape + kv_pos.shape[-1:], bool)
    qp = q_pos[..., :, None]
    kp = kv_pos[..., None, :]
    if causal:
        m &= kp <= qp
    if window > 0:
        m &= kp > qp - window
    return m


def mha_reference(q, k, v, *, causal: bool = True, window: int = 0,
                  softcap: float = 0.0) -> jnp.ndarray:
    """q: (B,Sq,Hq,hd); k,v: (B,Skv,Hkv,hd). Full-materialization oracle."""
    B, Sq, Hq, hd = q.shape
    _, Skv, Hkv, _ = k.shape
    g = Hq // Hkv
    qf = q.astype(jnp.float32).reshape(B, Sq, Hkv, g, hd)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qf, kf) / math.sqrt(hd)
    s = _soft_cap(s, softcap)
    q_pos = jnp.arange(Sq)
    kv_pos = jnp.arange(Skv)
    m = _mask(q_pos, kv_pos, causal, window)
    s = jnp.where(m[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, vf)
    return o.reshape(B, Sq, Hq, hd).astype(q.dtype)


def _chunk(n: int, pref: int) -> int:
    c = min(pref, n)
    while n % c:
        c -= 1
    return c


@partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                   "q_chunk", "kv_chunk"))
def blockwise_attention(q, k, v, *, causal: bool = True, window: int = 0,
                        softcap: float = 0.0, q_chunk: int = 1024,
                        kv_chunk: int = 1024) -> jnp.ndarray:
    """Online-softmax attention with O(Sq*kv_chunk) workspace."""
    B, Sq, Hq, hd = q.shape
    _, Skv, Hkv, _ = k.shape
    g = Hq // Hkv
    cq = _chunk(Sq, q_chunk)
    ck = _chunk(Skv, kv_chunk)
    nq, nk = Sq // cq, Skv // ck
    scale = 1.0 / math.sqrt(hd)

    qf = q.astype(jnp.float32).reshape(B, nq, cq, Hkv, g, hd).transpose(1, 0, 2, 3, 4, 5)
    kf = k.astype(jnp.float32).reshape(B, nk, ck, Hkv, hd)
    vf = v.astype(jnp.float32).reshape(B, nk, ck, Hkv, hd)

    def per_q(args):
        qi, qc = args                                # qc: (B,cq,Hkv,g,hd)
        q_pos = qi * cq + jnp.arange(cq)

        def kv_step(carry, inputs):
            m_i, l_i, acc = carry
            ki, kc, vc = inputs                      # (B,ck,Hkv,hd)
            kv_pos = ki * ck + jnp.arange(ck)
            s = jnp.einsum("bqkgd,bskd->bkgqs", qc, kc) * scale
            s = _soft_cap(s, softcap)
            msk = _mask(q_pos, kv_pos, causal, window)   # (cq, ck)
            s = jnp.where(msk[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m_i, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_i - m_new)
            l_new = l_i * corr + p.sum(axis=-1)
            acc = acc * corr[..., None] + jnp.einsum("bkgqs,bskd->bkgqd", p, vc)
            return (m_new, l_new, acc), None

        m0 = jnp.full((B, Hkv, g, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, g, cq), jnp.float32)
        a0 = jnp.zeros((B, Hkv, g, cq, hd), jnp.float32)
        (m_f, l_f, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.arange(nk), kf.transpose(1, 0, 2, 3, 4), vf.transpose(1, 0, 2, 3, 4)))
        out = acc / jnp.maximum(l_f, 1e-30)[..., None]   # (B,Hkv,g,cq,hd)
        return out.transpose(0, 3, 1, 2, 4)              # (B,cq,Hkv,g,hd)

    out = jax.lax.map(per_q, (jnp.arange(nq), qf))       # (nq,B,cq,Hkv,g,hd)
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, Hq, hd)
    return out.astype(q.dtype)


def decode_attention_ref(q, k, v, *, q_pos, kv_pos, window: int = 0,
                         softcap: float = 0.0) -> jnp.ndarray:
    """Single-step decode oracle.

    q: (B,1,Hq,hd); k,v: (B,Skv,Hkv,hd); q_pos: (B,1); kv_pos: (B,Skv)
    with -1 marking empty slots.
    """
    B, _, Hq, hd = q.shape
    _, Skv, Hkv, _ = k.shape
    g = Hq // Hkv
    qf = q.astype(jnp.float32).reshape(B, Hkv, g, hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qf, k.astype(jnp.float32)) / math.sqrt(hd)
    s = _soft_cap(s, softcap)
    valid = (kv_pos >= 0) & (kv_pos <= q_pos)            # (B,Skv)
    if window > 0:
        valid &= kv_pos > q_pos - window
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v.astype(jnp.float32))
    return o.reshape(B, 1, Hq, hd).astype(q.dtype)
