"""Public entry point for full-sequence attention.

``flash_attention`` dispatches between the Pallas TPU kernel and the
blockwise-jnp reference.  The single-token decode path lives in
:mod:`repro.kernels.paged_attention.ops.decode_attention` (one unified
dense+paged dispatch).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.flash_attention import ref as _ref


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, impl: str = "jnp",
                    interpret: bool = True, q_chunk: int = 1024) -> jnp.ndarray:
    """q: (B,Sq,Hq,hd); k,v: (B,Skv,Hkv,hd) -> (B,Sq,Hq,hd)."""
    if impl == "naive":
        return _ref.mha_reference(q, k, v, causal=causal, window=window,
                                  softcap=softcap)
    if impl == "pallas":
        from repro.kernels.flash_attention import flash_attention as _pl
        return _pl.flash_attention_pallas(
            q, k, v, causal=causal, window=window, softcap=softcap,
            interpret=interpret)
    return _ref.blockwise_attention(q, k, v, causal=causal, window=window,
                                    softcap=softcap, q_chunk=q_chunk)
