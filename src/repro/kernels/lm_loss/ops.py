"""Public entry point for the fused LM loss."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.lm_loss import ref as _ref


def lm_loss(hidden, unembed, labels, *, softcap: float = 0.0,
            chunk: int = 256, impl: str = "jnp",
            interpret: bool = True) -> jnp.ndarray:
    """Per-token NLL (B,S) f32 without materializing (B,S,V) logits."""
    if impl == "naive":
        return _ref.lm_loss_naive(hidden, unembed, labels, softcap=softcap)
    if impl == "pallas":
        from repro.kernels.lm_loss import lm_loss as _pl
        return _pl.lm_loss_pallas(hidden, unembed, labels, softcap=softcap,
                                  interpret=interpret)
    return _ref.lm_loss_chunked(hidden, unembed, labels, softcap=softcap,
                                chunk=chunk)
