"""Pure-jnp oracles for the fused LM-loss (softmax cross-entropy) kernel.

The hot-spot: with vocabularies up to 256k, materializing (B,S,V) logits
costs tens of GB.  ``lm_loss_chunked`` scans over token chunks so only
(B,chunk,V) exists at a time; the Pallas kernel additionally tiles the
vocab dimension through VMEM with an online logsumexp.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def _soft_cap(x, cap: float):
    return jnp.tanh(x / cap) * cap if cap else x


def lm_loss_naive(hidden, unembed, labels, *, softcap: float = 0.0) -> jnp.ndarray:
    """Full-materialization oracle.

    hidden: (B,S,D); unembed: (V,D); labels: (B,S) int32.
    Returns per-token NLL (B,S) float32.
    """
    logits = hidden.astype(jnp.float32) @ unembed.astype(jnp.float32).T
    logits = _soft_cap(logits, softcap)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return lse - gold


@partial(jax.jit, static_argnames=("softcap", "chunk"))
def lm_loss_chunked(hidden, unembed, labels, *, softcap: float = 0.0,
                    chunk: int = 256) -> jnp.ndarray:
    """Token-chunked NLL: peak logits workspace is (B,chunk,V)."""
    B, S, D = hidden.shape
    c = min(chunk, S)
    while S % c:
        c -= 1
    nc = S // c
    h = hidden.reshape(B, nc, c, D).transpose(1, 0, 2, 3)
    y = labels.reshape(B, nc, c).transpose(1, 0, 2)

    def step(_, inp):
        hc, yc = inp
        return None, lm_loss_naive(hc, unembed, yc, softcap=softcap)

    _, nll = jax.lax.scan(step, None, (h, y))
    return nll.transpose(1, 0, 2).reshape(B, S)
