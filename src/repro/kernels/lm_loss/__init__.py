from repro.kernels.lm_loss import ops, ref  # noqa: F401
