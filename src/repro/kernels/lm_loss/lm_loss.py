"""Pallas TPU kernel: fused softmax cross-entropy over a huge vocabulary.

Never materializes (N, V) logits in HBM: the grid walks (token tiles x
vocab tiles) with the vocab dimension innermost; per token tile we keep an
online (max, sumexp, gold-logit) triple in VMEM scratch and emit NLL at the
last vocab step.  Handles tanh logit soft-capping (gemma2 final_softcap).

Backward is two Pallas kernels with opposite grid nesting so each output
block is revisited on *consecutive* grid steps and can be accumulated
directly in its VMEM window:
  * dH   : grid (token, vocab)  — dh[i]   += (p - y) J g  @ E[j]
  * dEmb : grid (vocab, token)  — dE[j]   += ((p - y) J g)^T @ h[i]

VMEM budget per step (defaults T=256, VB=512, D<=8192, f32 scratch):
  h tile 256xD bf16 + emb tile 512xD bf16 + logits 256x512 f32 ~ <12 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _fwd_kernel(h_ref, emb_ref, lab_ref, nll_ref, m_out_ref, l_out_ref,
                m_ref, l_ref, g_ref, *, softcap: float, nv: int, vb: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        g_ref[...] = jnp.zeros_like(g_ref)

    h = h_ref[...].astype(jnp.float32)            # (T, D)
    emb = emb_ref[...].astype(jnp.float32)        # (VB, D)
    logits = jax.lax.dot_general(h, emb, (((1,), (1,)), ((), ())))  # (T, VB)
    if softcap:
        logits = jnp.tanh(logits / softcap) * softcap
    # gold logit for labels inside this vocab tile
    lab = lab_ref[...]                            # (T,) int32 (global ids)
    local = lab - j * vb
    col = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    hit = col == local[:, None]
    g_ref[...] += jnp.sum(jnp.where(hit, logits, 0.0), axis=1)
    # online logsumexp
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, logits.max(axis=1))
    l_ref[...] = l_ref[...] * jnp.exp(m_prev - m_new) + \
        jnp.exp(logits - m_new[:, None]).sum(axis=1)
    m_ref[...] = m_new

    @pl.when(j == nv - 1)
    def _emit():
        nll_ref[...] = jnp.log(l_ref[...]) + m_ref[...] - g_ref[...]
        m_out_ref[...] = m_ref[...]
        l_out_ref[...] = l_ref[...]


def _bwd_dh_kernel(h_ref, emb_ref, lab_ref, m_ref, l_ref, g_ref, dh_ref,
                   *, softcap: float, nv: int, vb: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        dh_ref[...] = jnp.zeros_like(dh_ref)

    h = h_ref[...].astype(jnp.float32)
    emb = emb_ref[...].astype(jnp.float32)
    raw = jax.lax.dot_general(h, emb, (((1,), (1,)), ((), ())))
    if softcap:
        capped = jnp.tanh(raw / softcap)
        logits = capped * softcap
        jac = 1.0 - capped * capped
    else:
        logits = raw
        jac = 1.0
    p = jnp.exp(logits - m_ref[...][:, None]) / l_ref[...][:, None]
    lab = lab_ref[...]
    col = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    y = (col == (lab - j * vb)[:, None]).astype(jnp.float32)
    dlog = (p - y) * jac * g_ref[...][:, None]     # (T, VB)
    dh_ref[...] += jax.lax.dot(dlog, emb).astype(dh_ref.dtype)


def _bwd_demb_kernel(h_ref, emb_ref, lab_ref, m_ref, l_ref, g_ref, demb_ref,
                     *, softcap: float, nt: int, vb: int):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        demb_ref[...] = jnp.zeros_like(demb_ref)

    h = h_ref[...].astype(jnp.float32)
    emb = emb_ref[...].astype(jnp.float32)
    raw = jax.lax.dot_general(h, emb, (((1,), (1,)), ((), ())))
    if softcap:
        capped = jnp.tanh(raw / softcap)
        logits = capped * softcap
        jac = 1.0 - capped * capped
    else:
        logits = raw
        jac = 1.0
    p = jnp.exp(logits - m_ref[...][:, None]) / l_ref[...][:, None]
    lab = lab_ref[...]
    j = pl.program_id(0)
    col = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    y = (col == (lab - j * vb)[:, None]).astype(jnp.float32)
    dlog = (p - y) * jac * g_ref[...][:, None]     # (T, VB)
    demb_ref[...] += jax.lax.dot_general(
        dlog, h, (((0,), (0,)), ((), ()))).astype(demb_ref.dtype)


def _pad_to(x, mult, axis, value=0):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    cfg = [(0, 0)] * x.ndim
    cfg[axis] = (0, pad)
    return jnp.pad(x, cfg, constant_values=value)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _lm_loss(hidden2d, unembed, labels1d, softcap, tb, vb, interpret):
    nll, _, _ = _fwd(hidden2d, unembed, labels1d, softcap, tb, vb, interpret)
    return nll


def _fwd(hidden2d, unembed, labels1d, softcap, tb, vb, interpret):
    N, D = hidden2d.shape
    V, _ = unembed.shape
    hp = _pad_to(hidden2d, tb, 0)
    lp = _pad_to(labels1d, tb, 0, value=-1)
    ep = _pad_to(unembed, vb, 0)
    # padded vocab rows must not win the max: push them to -inf via a
    # sentinel row of zeros — zeros give logit 0 which is fine for the
    # online max (true logits always include the gold; exp(0-m) only adds
    # a bounded term). To stay exact we mask padded columns inside the
    # kernel instead when V % vb != 0 — here we require V % vb == 0 by
    # choosing vb adaptively in the wrapper.
    nt, nv = hp.shape[0] // tb, ep.shape[0] // vb
    f32 = jnp.float32
    out = pl.pallas_call(
        functools.partial(_fwd_kernel, softcap=softcap, nv=nv, vb=vb),
        grid=(nt, nv),
        in_specs=[
            pl.BlockSpec((tb, D), lambda i, j: (i, 0)),
            pl.BlockSpec((vb, D), lambda i, j: (j, 0)),
            pl.BlockSpec((tb,), lambda i, j: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((tb,), lambda i, j: (i,)),
            pl.BlockSpec((tb,), lambda i, j: (i,)),
            pl.BlockSpec((tb,), lambda i, j: (i,)),
        ],
        out_shape=[jax.ShapeDtypeStruct((hp.shape[0],), f32)] * 3,
        scratch_shapes=[pltpu.VMEM((tb,), f32)] * 3,
        interpret=interpret,
    )(hp, ep, lp)
    nll, m, l = out
    return nll[:N], m, l


def _lm_loss_fwd(hidden2d, unembed, labels1d, softcap, tb, vb, interpret):
    nll, m, l = _fwd(hidden2d, unembed, labels1d, softcap, tb, vb, interpret)
    return nll, (hidden2d, unembed, labels1d, m, l)


def _lm_loss_bwd(softcap, tb, vb, interpret, res, dnll):
    hidden2d, unembed, labels1d, m, l = res
    N, D = hidden2d.shape
    V, _ = unembed.shape
    hp = _pad_to(hidden2d, tb, 0)
    lp = _pad_to(labels1d, tb, 0, value=-1)
    ep = _pad_to(unembed, vb, 0)
    gp = _pad_to(dnll.astype(jnp.float32), tb, 0)
    nt, nv = hp.shape[0] // tb, ep.shape[0] // vb

    dh = pl.pallas_call(
        functools.partial(_bwd_dh_kernel, softcap=softcap, nv=nv, vb=vb),
        grid=(nt, nv),
        in_specs=[
            pl.BlockSpec((tb, D), lambda i, j: (i, 0)),
            pl.BlockSpec((vb, D), lambda i, j: (j, 0)),
            pl.BlockSpec((tb,), lambda i, j: (i,)),
            pl.BlockSpec((tb,), lambda i, j: (i,)),
            pl.BlockSpec((tb,), lambda i, j: (i,)),
            pl.BlockSpec((tb,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((tb, D), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(hp.shape, hidden2d.dtype),
        interpret=interpret,
    )(hp, ep, lp, m, l, gp)

    demb = pl.pallas_call(
        functools.partial(_bwd_demb_kernel, softcap=softcap, nt=nt, vb=vb),
        grid=(nv, nt),
        in_specs=[
            pl.BlockSpec((tb, D), lambda j, t: (t, 0)),
            pl.BlockSpec((vb, D), lambda j, t: (j, 0)),
            pl.BlockSpec((tb,), lambda j, t: (t,)),
            pl.BlockSpec((tb,), lambda j, t: (t,)),
            pl.BlockSpec((tb,), lambda j, t: (t,)),
            pl.BlockSpec((tb,), lambda j, t: (t,)),
        ],
        out_specs=pl.BlockSpec((vb, D), lambda j, t: (j, 0)),
        out_shape=jax.ShapeDtypeStruct(ep.shape, unembed.dtype),
        interpret=interpret,
    )(hp, ep, lp, m, l, gp)

    return dh[:N], demb[:V], None


_lm_loss.defvjp(_lm_loss_fwd, _lm_loss_bwd)


def _tile_sizes(N: int, V: int, D: int) -> tuple[int, int]:
    tb = min(256, N)
    while N % tb:
        tb -= 1
    vb = min(512, V)
    while V % vb:
        vb -= 1
    return max(tb, 1), max(vb, 1)


def lm_loss_pallas(hidden, unembed, labels, *, softcap: float = 0.0,
                   interpret: bool = True) -> jnp.ndarray:
    """Per-token NLL (B,S) f32.  hidden (B,S,D); unembed (V,D); labels (B,S)."""
    B, S, D = hidden.shape
    V = unembed.shape[0]
    tb, vb = _tile_sizes(B * S, V, D)
    nll = _lm_loss(hidden.reshape(B * S, D), unembed,
                   labels.reshape(B * S).astype(jnp.int32),
                   float(softcap), tb, vb, interpret)
    return nll.reshape(B, S)
