"""Pallas fused decode epilogue: unembed + softcap + sample in VMEM.

Grid ``(B, vocab_chunks)``: each lane's logits row is built chunk by
chunk in a VMEM scratch buffer — ``(1, D) @ (D, Vc)`` unembed tile,
``astype(logit_dtype)``, final softcap, exactly ``model._logits``' op
order — and at the last chunk the **whole sampler runs in-kernel** on
the completed row: the literal :func:`repro.serving.sampling._sample_row`
(counter-based ``fold_in(key, step)`` threefry categorical, top-k /
top-p masks, temp-0 argmax branch), so the ``(lanes, vocab)`` logits
never leave VMEM and only the ``(lanes,)`` tokens are written back.

Per-lane sampling operands ride in as scalar-prefetch inputs (the same
mechanism ``paged_attention.py`` uses for block tables), so lane churn
never recompiles.  The vocab is padded up to the chunk size for the
matmul tiles, but the sampler reads exactly ``row[:V]`` — the categorical
draw sees the same ``(V,)`` shape as the unfused sampler, which is what
keeps the token stream bit-compatible.  In-kernel ``sort`` / threefry
lowering on real TPUs is the documented silicon validation gap
(``serving/README.md``); interpret mode is bit-exact.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.models import common
from repro.serving import sampling as samplib

_VOCAB_CHUNK = 512


def _chunks(V: int) -> tuple[int, int, int]:
    vc = min(V, _VOCAB_CHUNK)
    nc = -(-V // vc)
    return vc, nc, vc * nc  # (chunk, n_chunks, padded vocab)


def _logits_chunk(h_ref, u_ref, *, logit_dtype, softcap: float):
    vals = (h_ref[0] @ u_ref[...].T).astype(logit_dtype)   # (1, Vc)
    return common.softcap(vals, softcap)


def _sampled_kernel(keys_ref, steps_ref, temps_ref, topks_ref, topps_ref,
                    h_ref, u_ref, tok_ref, scratch,
                    *, V, Vc, nc, softcap, logit_dtype):
    b, j = pl.program_id(0), pl.program_id(1)
    vals = _logits_chunk(h_ref, u_ref, logit_dtype=logit_dtype,
                         softcap=softcap)
    pl.store(scratch, (slice(None), pl.ds(j * Vc, Vc)), vals)

    @pl.when(j == nc - 1)
    def _emit():
        tok = samplib._sample_row(scratch[0, :V], keys_ref[b], steps_ref[b],
                                  temps_ref[b], topks_ref[b], topps_ref[b])
        tok_ref[0] = tok.astype(jnp.int32)


def _greedy_kernel(h_ref, u_ref, tok_ref, scratch,
                   *, V, Vc, nc, softcap, logit_dtype):
    j = pl.program_id(1)
    vals = _logits_chunk(h_ref, u_ref, logit_dtype=logit_dtype,
                         softcap=softcap)
    pl.store(scratch, (slice(None), pl.ds(j * Vc, Vc)), vals)

    @pl.when(j == nc - 1)
    def _emit():
        tok_ref[0] = jnp.argmax(scratch[0, :V], -1).astype(jnp.int32)


def _pad_unemb(unemb, vpad: int):
    V = unemb.shape[0]
    if vpad == V:
        return unemb
    return jnp.pad(unemb, ((0, vpad - V), (0, 0)))


def decode_and_sample_pallas(h, unemb, *, keys, steps, temps, top_ks,
                             top_ps, final_softcap: float, logit_dtype,
                             interpret=None):
    """Fused sampled epilogue: h (B, 1, D) -> tokens (B,) int32."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, _, D = h.shape
    V = unemb.shape[0]
    Vc, nc, vpad = _chunks(V)
    logit_dtype = jnp.dtype(logit_dtype)
    kernel = functools.partial(_sampled_kernel, V=V, Vc=Vc, nc=nc,
                               softcap=final_softcap,
                               logit_dtype=logit_dtype)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=5,
        grid=(B, nc),
        in_specs=[
            pl.BlockSpec((1, 1, D), lambda b, j, *_: (b, 0, 0)),
            pl.BlockSpec((Vc, D), lambda b, j, *_: (j, 0)),
        ],
        out_specs=pl.BlockSpec((1,), lambda b, j, *_: (b,)),
        scratch_shapes=[pltpu.VMEM((1, vpad), logit_dtype)],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B,), jnp.int32),
        interpret=interpret,
    )(jnp.asarray(keys, jnp.uint32), jnp.asarray(steps, jnp.int32),
      jnp.asarray(temps, jnp.float32), jnp.asarray(top_ks, jnp.int32),
      jnp.asarray(top_ps, jnp.float32), h, _pad_unemb(unemb, vpad))


def decode_greedy_pallas(h, unemb, *, final_softcap: float, logit_dtype,
                         interpret=None):
    """Fused greedy epilogue: h (B, 1, D) -> argmax tokens (B,) int32."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, _, D = h.shape
    V = unemb.shape[0]
    Vc, nc, vpad = _chunks(V)
    logit_dtype = jnp.dtype(logit_dtype)
    kernel = functools.partial(_greedy_kernel, V=V, Vc=Vc, nc=nc,
                               softcap=final_softcap,
                               logit_dtype=logit_dtype)
    return pl.pallas_call(
        kernel,
        grid=(B, nc),
        in_specs=[
            pl.BlockSpec((1, 1, D), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((Vc, D), lambda b, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((1,), lambda b, j: (b,)),
        scratch_shapes=[pltpu.VMEM((1, vpad), logit_dtype)],
        out_shape=jax.ShapeDtypeStruct((B,), jnp.int32),
        interpret=interpret,
    )(h, _pad_unemb(unemb, vpad))
