"""Unified entry point for the fused decode epilogue.

One op pair finishes the decode step from the last-layer hidden state:
``decode_and_sample`` (unembed matmul + final softcap + the PR 3
counter-based ``(seed, uid, step)`` sampler) and ``decode_greedy``
(unembed + softcap + argmax).  On the fused path the ``(lanes, vocab)``
logits are an internal intermediate — only ``(lanes,)`` int32 tokens
come back — which kills the per-tick logits HBM round-trip between the
decode program and the separate ``sample_tokens_jit`` call.

``impl`` is validated instead of silently ignored: ``"jnp"`` replays the
legacy sequence bit for bit (same matmul shape and astype/softcap order
as ``model._logits``, same row-wise sampler — tokens bitwise identical
to ``serving/baseline.py`` by construction); ``"pallas"`` builds each
logits row chunk-wise in VMEM and runs the *same* ``_sample_row`` /
argmax in-kernel (interpret mode on CPU; in-kernel sort/threefry on TPU
silicon is the documented validation gap).

``unemb`` must already be cast to the compute dtype — callers hold cast
params, and re-casting here would diverge from ``model._logits``.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.sample_epilogue import ref as _ref

VALID_IMPLS = ("jnp", "pallas")


def _validate(h, unemb, impl):
    if impl not in VALID_IMPLS:
        raise ValueError(f"sample_epilogue impl must be one of "
                         f"{VALID_IMPLS}, got {impl!r}")
    if h.ndim != 3 or h.shape[1] != 1:
        raise ValueError(f"h must be (B, 1, D) — one decode position per "
                         f"lane — got {h.shape}")
    if unemb.ndim != 2 or unemb.shape[1] != h.shape[2]:
        raise ValueError(f"unemb must be (V, D) with D={h.shape[2]}, "
                         f"got {unemb.shape}")


def decode_and_sample(h, unemb, *, keys, steps, temps, top_ks, top_ps,
                      final_softcap: float = 0.0,
                      logit_dtype=jnp.float32, impl: str = "jnp",
                      interpret: bool | None = None):
    """Sampled fused epilogue: h (B, 1, D) -> tokens (B,) int32.

    ``keys`` (B, 2) uint32 request roots, ``steps``/``temps``/
    ``top_ks``/``top_ps`` (B,) per-lane operands — identical to
    :func:`repro.serving.sampling.sample_tokens`'s contract.
    """
    _validate(h, unemb, impl)
    B = h.shape[0]
    if keys.shape != (B, 2):
        raise ValueError(f"keys must be (B, 2)={B, 2} uint32 request "
                         f"roots, got {keys.shape}")
    for name, arr in (("steps", steps), ("temps", temps),
                      ("top_ks", top_ks), ("top_ps", top_ps)):
        if arr.shape != (B,):
            raise ValueError(f"{name} must be (B,)={(B,)}, got {arr.shape}")
    if impl == "pallas":
        from repro.kernels.sample_epilogue import sample_epilogue as _pl
        return _pl.decode_and_sample_pallas(
            h, unemb, keys=keys, steps=steps, temps=temps, top_ks=top_ks,
            top_ps=top_ps, final_softcap=final_softcap,
            logit_dtype=logit_dtype, interpret=interpret)
    return _ref.decode_and_sample_ref(
        h, unemb, keys=keys, steps=steps, temps=temps, top_ks=top_ks,
        top_ps=top_ps, final_softcap=final_softcap,
        logit_dtype=logit_dtype)


def decode_greedy(h, unemb, *, final_softcap: float = 0.0,
                  logit_dtype=jnp.float32, impl: str = "jnp",
                  interpret: bool | None = None):
    """Greedy fused epilogue: h (B, 1, D) -> argmax tokens (B,) int32."""
    _validate(h, unemb, impl)
    if impl == "pallas":
        from repro.kernels.sample_epilogue import sample_epilogue as _pl
        return _pl.decode_greedy_pallas(
            h, unemb, final_softcap=final_softcap,
            logit_dtype=logit_dtype, interpret=interpret)
    return _ref.decode_greedy_ref(h, unemb, final_softcap=final_softcap,
                                  logit_dtype=logit_dtype)
