from repro.kernels.sample_epilogue import ops, ref  # noqa: F401
