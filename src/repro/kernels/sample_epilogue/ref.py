"""Pure-jnp reference for the fused decode epilogue (the bitwise contract).

The legacy decode program ends at ``model._logits``: the full
``(lanes, vocab)`` logits land in HBM and a separate sampler
(:func:`repro.serving.sampling.sample_tokens`) or ``argmax`` reads them
back to draw one token per lane.  The fused epilogue moves that last
matmul + softcap + sample into the decode program itself, so only the
``(lanes,)`` tokens ever leave it.

This reference performs *exactly* the legacy sequence on the last-layer
hidden state — the same ``(B, 1, D) @ (D, V)`` matmul shape, the same
``astype(logit_dtype)``-then-softcap order as ``model._logits``, and the
same row-wise :func:`repro.serving.sampling._sample_row` counter-based
``(seed, uid, step)`` sampler — so engine tokens are bitwise identical
to the unfused path (and hence to ``serving/baseline.py``) by
construction.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.models import common
from repro.serving import sampling as samplib


def logits_from_hidden(h, unemb, *, final_softcap: float, logit_dtype):
    """``model._logits`` on a precomputed hidden state; h: (B, 1, D).

    ``unemb`` must already be cast to the compute dtype (the caller holds
    the cast params), matching the legacy decode program bit for bit.
    """
    logits = (h @ unemb.T).astype(logit_dtype)
    return common.softcap(logits, final_softcap)


def decode_and_sample_ref(h, unemb, *, keys, steps, temps, top_ks, top_ps,
                          final_softcap: float, logit_dtype):
    """Sampled epilogue: h (B, 1, D) -> tokens (B,) int32."""
    logits = logits_from_hidden(h, unemb, final_softcap=final_softcap,
                                logit_dtype=logit_dtype)
    return samplib.sample_tokens(logits[:, 0], keys, steps, temps,
                                 top_ks, top_ps)


def decode_greedy_ref(h, unemb, *, final_softcap: float, logit_dtype):
    """Greedy epilogue: h (B, 1, D) -> argmax tokens (B,) int32."""
    logits = logits_from_hidden(h, unemb, final_softcap=final_softcap,
                                logit_dtype=logit_dtype)
    return jnp.argmax(logits[:, 0], -1).astype(jnp.int32)
