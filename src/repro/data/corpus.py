"""Synthetic multi-domain corpus.

The paper trains on RedPajama-V2 (not shippable in this container), so the
framework provides a deterministic synthetic corpus with *K latent domains*
whose statistics differ enough that (a) a tiny LM can tell domains apart
from a short prefix and (b) per-domain specialists beat a single dense
model at equal total tokens — the two properties SmallTalk LM exploits.

Each domain d draws from an affine bigram chain
    x_{t+1} = (a_d * x_t + b_d + eps) mod V   with prob `signal`
    x_{t+1} ~ Uniform(V)                       otherwise
with per-domain (a_d, b_d) and jitter eps ~ U[0, jitter).  Domains are
therefore equally hard but mutually unpredictable: a model trained on
domain d sees ~uniform noise on other domains.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int = 512
    seq_len: int = 256
    n_domains: int = 4
    signal: float = 0.85
    jitter: int = 2
    seed: int = 0


class SyntheticCorpus:
    """Deterministic, stream-indexed corpus: sequence i is always the same."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        V, K = cfg.vocab_size, cfg.n_domains
        # co-prime multipliers => distinct chains
        cands = [a for a in range(3, 10 * K + 3, 2) if np.gcd(a, V) == 1]
        self.a = np.array(cands[:K], np.int64)
        self.b = rng.integers(1, V, size=K).astype(np.int64)

    def domain_of(self, index: int | np.ndarray) -> np.ndarray:
        return np.asarray(index) % self.cfg.n_domains

    def sequences(self, indices: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Generate sequences for stream indices.  Returns (tokens (N,S), domains (N,))."""
        cfg = self.cfg
        indices = np.asarray(indices, np.int64)
        N = len(indices)
        doms = self.domain_of(indices)
        V, S = cfg.vocab_size, cfg.seq_len
        # per-sequence counter-based RNG: sequence i is identical no matter
        # which batch it is generated in (expert pipelines regenerate their
        # assigned indices locally — see data/pipeline.py)
        toks = np.empty((N, S), np.int64)
        noise = np.empty((N, S - 1))
        jit = np.empty((N, S - 1), np.int64)
        unif = np.empty((N, S - 1), np.int64)
        for i, idx in enumerate(indices):
            r = np.random.default_rng(
                np.random.SeedSequence([cfg.seed + 1, int(idx)]))
            toks[i, 0] = r.integers(0, V)
            noise[i] = r.random(S - 1)
            jit[i] = r.integers(0, max(cfg.jitter, 1), size=S - 1)
            unif[i] = r.integers(0, V, size=S - 1)
        a = self.a[doms]
        b = self.b[doms]
        for t in range(1, S):
            nxt = (a * toks[:, t - 1] + b + jit[:, t - 1]) % V
            toks[:, t] = np.where(noise[:, t - 1] < cfg.signal, nxt,
                                  unif[:, t - 1])
        return toks.astype(np.int32), doms.astype(np.int32)

    def batch(self, step: int, batch_size: int, *, offset: int = 0) -> dict:
        """Training batch dict for ``step`` (deterministic)."""
        idx = offset + step * batch_size + np.arange(batch_size)
        toks, doms = self.sequences(idx)
        return make_lm_batch(toks, domains=doms)


def make_lm_batch(tokens: np.ndarray, domains: np.ndarray | None = None) -> dict:
    """tokens (N,S) -> next-token-prediction batch."""
    labels = np.roll(tokens, -1, axis=1)
    mask = np.ones_like(tokens, np.float32)
    mask[:, -1] = 0.0
    out = {"tokens": tokens, "labels": labels, "loss_mask": mask}
    if domains is not None:
        out["domain"] = domains
    return out
