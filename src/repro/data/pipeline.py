"""Data pipeline: deterministic sharded streams + assignment-driven
per-expert streams for SmallTalk training.

The pipeline is host-side numpy (as a real input pipeline would be) and
hands jax fully-formed batches.  ``ShardedStream`` models the "each expert
group reads its own slice of the corpus" layout from the paper: expert e's
stream only materializes the sequences assigned to e, so no token is ever
sent over the interconnect.
"""
from __future__ import annotations

from collections.abc import Iterator

import numpy as np

from repro.data.corpus import SyntheticCorpus, make_lm_batch


class Stream:
    """Round-robin deterministic batch stream over the corpus."""

    def __init__(self, corpus: SyntheticCorpus, batch_size: int,
                 offset: int = 0):
        self.corpus = corpus
        self.batch_size = batch_size
        self.offset = offset
        self.step = 0

    def next(self) -> dict:
        b = self.corpus.batch(self.step, self.batch_size, offset=self.offset)
        self.step += 1
        return b

    def __iter__(self) -> Iterator[dict]:
        while True:
            yield self.next()


class AssignedStream:
    """Batches drawn from an explicit set of assigned sequence indices.

    This is the expert-side view after routing: the router decided which
    corpus indices belong to this expert; the expert's input pipeline
    re-generates exactly those sequences locally.
    """

    def __init__(self, corpus: SyntheticCorpus, indices: np.ndarray,
                 batch_size: int, seed: int = 0):
        self.corpus = corpus
        self.indices = np.asarray(indices, np.int64)
        self.batch_size = batch_size
        self.rng = np.random.default_rng(seed)
        self._order = self.rng.permutation(len(self.indices))
        self._pos = 0

    def next(self) -> dict:
        n = self.batch_size
        if self._pos + n > len(self._order):           # reshuffle epoch
            self._order = self.rng.permutation(len(self.indices))
            self._pos = 0
        sel = self.indices[self._order[self._pos:self._pos + n]]
        self._pos += n
        toks, doms = self.corpus.sequences(sel)
        return make_lm_batch(toks, domains=doms)


def chunk_indices(chunk_id: int, chunk_size: int) -> np.ndarray:
    """Stream indices of corpus chunk ``chunk_id`` (disjoint, contiguous)."""
    return chunk_id * chunk_size + np.arange(chunk_size, dtype=np.int64)
