from repro.data.corpus import DataConfig, SyntheticCorpus, make_lm_batch
from repro.data.pipeline import AssignedStream, Stream, chunk_indices

__all__ = ["DataConfig", "SyntheticCorpus", "make_lm_batch",
           "AssignedStream", "Stream", "chunk_indices"]
