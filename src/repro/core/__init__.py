from repro.core import assignment, em, mixture, router  # noqa: F401
