"""Balanced assignments (paper §2.2, Fig. 1).

Training-time assignment of a chunk of N sequences to E experts under a
per-expert capacity: sort sequences by best-achievable log-likelihood
(``-max_e log p(x_{1:M}|e)`` ascending, i.e. most-confident first), then
greedily give each sequence its best *non-full* expert.  This avoids the
Fig.-1a failure where an early mediocre sequence fills an expert that a
later high-likelihood sequence needed.

At inference there is no balancing: pure ``argmax_e``.

Two implementations sharing tests:
  * :func:`balanced_assignment_np` — numpy oracle;
  * :func:`balanced_assignment` — jit-able (sort + fori_loop), used inside
    the EM loop so the whole assignment step can run on-device.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def default_capacity(n: int, e: int, capacity_factor: float = 1.0) -> int:
    """ceil(N/E * cf) — with cf=1 every expert gets an equal share."""
    return int(np.ceil(n / e * capacity_factor))


def balanced_assignment_np(scores: np.ndarray, capacity: int) -> np.ndarray:
    """scores: (N, E) log-likelihoods.  Returns expert id per sequence (N,)."""
    scores = np.asarray(scores, np.float64)
    n, e = scores.shape
    if capacity * e < n:
        raise ValueError(f"capacity {capacity} x {e} experts < {n} sequences")
    order = np.argsort(-scores.max(axis=1), kind="stable")
    counts = np.zeros(e, np.int64)
    out = np.full(n, -1, np.int64)
    for i in order:
        ranked = np.argsort(-scores[i], kind="stable")
        for ex in ranked:
            if counts[ex] < capacity:
                out[i] = ex
                counts[ex] += 1
                break
    return out


def balanced_assignment(scores: jnp.ndarray, capacity: int) -> jnp.ndarray:
    """jit-able balanced assignment.  scores: (N, E) -> (N,) int32."""
    n, e = scores.shape
    scores = jnp.asarray(scores, jnp.float32)
    order = jnp.argsort(-scores.max(axis=1), stable=True)

    def body(i, carry):
        out, counts = carry
        idx = order[i]
        row = scores[idx]
        masked = jnp.where(counts < capacity, row, -jnp.inf)
        ex = jnp.argmax(masked)
        return (out.at[idx].set(ex.astype(jnp.int32)),
                counts.at[ex].add(1))

    out0 = jnp.full((n,), -1, jnp.int32)
    cnt0 = jnp.zeros((e,), jnp.int32)
    out, _ = jax.lax.fori_loop(0, n, body, (out0, cnt0))
    return out


def argmax_assignment(scores: jnp.ndarray) -> jnp.ndarray:
    """Inference-time routing: no balancing (paper §2.2)."""
    return jnp.argmax(scores, axis=-1).astype(jnp.int32)


def sequential_assignment_np(scores: np.ndarray, capacity: int) -> np.ndarray:
    """The Fig.-1a strawman: assign in corpus order (for the ablation bench)."""
    scores = np.asarray(scores, np.float64)
    n, e = scores.shape
    counts = np.zeros(e, np.int64)
    out = np.full(n, -1, np.int64)
    for i in range(n):
        ranked = np.argsort(-scores[i], kind="stable")
        for ex in ranked:
            if counts[ex] < capacity:
                out[i] = ex
                counts[ex] += 1
                break
    return out
