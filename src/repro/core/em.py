"""EM training of the routers (paper Algorithm 1, lines 1-10).

Alternates:
  M-step: every router takes SGD steps on its currently-assigned segment
          (vmapped across routers — embarrassingly parallel);
  E-step: a fresh corpus chunk is scored by all routers on a short prefix
          and re-partitioned with balanced assignments.

Communication accounting (paper App. A.4) is tracked explicitly:
``comm_bytes`` counts exactly the score floats a real deployment would
all-gather (2 bytes * N sequences per router per E-step) — nothing else
crosses node boundaries.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import assignment as asg
from repro.core import router as routerlib
from repro.data import SyntheticCorpus, make_lm_batch
from repro.optim import AdamWConfig, adamw


@dataclass
class EMConfig:
    n_experts: int = 4
    prefix_len: int = 64            # M
    em_iters: int = 4               # T
    # N sequences per E-step chunk.  Must be >> steps_per_iter*batch_size/E:
    # routers must see (nearly) fresh data each step or they memorize their
    # segment instead of learning its distribution (paper: ~45M tokens/chunk)
    chunk_size: int = 2048
    steps_per_iter: int = 50        # router SGD steps per M-step
    batch_size: int = 16
    capacity_factor: float = 1.0
    lr: float = 1e-3
    warmup: int = 20


@dataclass
class EMState:
    router_params: dict
    history: list = field(default_factory=list)
    comm_bytes: int = 0
    chunks_used: int = 0


def _per_expert_batches(corpus: SyntheticCorpus, indices_by_e: list[np.ndarray],
                        batch_size: int, rng: np.random.Generator,
                        prefix_len: int) -> dict:
    """Build an (E, B, M) token batch: each router trains on its segment."""
    toks = []
    for idx in indices_by_e:
        sel = rng.choice(idx, size=batch_size, replace=len(idx) < batch_size)
        t, _ = corpus.sequences(sel)
        toks.append(t[:, :prefix_len])
    toks = np.stack(toks)                            # (E,B,M)
    labels = np.roll(toks, -1, axis=2)
    mask = np.ones_like(toks, np.float32)
    mask[..., -1] = 0.0
    return {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels),
            "loss_mask": jnp.asarray(mask)}


def domain_purity(assign: np.ndarray, domains: np.ndarray, e: int) -> float:
    """Fraction of sequences landing with their segment's plurality domain."""
    total = 0
    for ex in range(e):
        d = domains[assign == ex]
        if len(d):
            total += np.bincount(d).max()
    return total / len(assign)


def train_routers(corpus: SyntheticCorpus, rcfg, emcfg: EMConfig,
                  key) -> EMState:
    E = emcfg.n_experts
    key, k1 = jax.random.split(key)
    stacked = routerlib.init_ensemble(k1, rcfg, E)
    opt_cfg = AdamWConfig(peak_lr=emcfg.lr, warmup_steps=emcfg.warmup,
                          schedule="constant",
                          total_steps=emcfg.em_iters * emcfg.steps_per_iter)
    opt_state = jax.vmap(lambda p: adamw.init_state(p, opt_cfg))(stacked)
    rng = np.random.default_rng(0xB0B)
    state = EMState(router_params=stacked)

    # initial chunk: random assignment (Algorithm 1 line 3)
    chunk = np.arange(emcfg.chunk_size, dtype=np.int64)
    assign = rng.integers(0, E, size=emcfg.chunk_size)
    _, domains = corpus.sequences(chunk)
    state.chunks_used = 1

    train_step = jax.jit(lambda p, s, b: routerlib.ensemble_train_step(
        p, s, b, rcfg, opt_cfg))
    score_fn = jax.jit(lambda p, t: routerlib.ensemble_scores(p, rcfg, t))
    cap = asg.default_capacity(emcfg.chunk_size, E, emcfg.capacity_factor)
    assign_fn = jax.jit(lambda s: asg.balanced_assignment(s, cap))

    for it in range(emcfg.em_iters):
        # ---- M-step: SGD on own segment --------------------------------
        seg = [chunk[assign == ex] for ex in range(E)]
        seg = [s if len(s) else chunk[:1] for s in seg]
        losses = []
        for _ in range(emcfg.steps_per_iter):
            batch = _per_expert_batches(corpus, seg, emcfg.batch_size, rng,
                                        emcfg.prefix_len)
            stacked, opt_state, metrics = train_step(stacked, opt_state, batch)
            losses.append(np.asarray(metrics["ce"]))
        # ---- E-step: fresh chunk, score, balanced-assign ----------------
        chunk = state.chunks_used * emcfg.chunk_size + \
            np.arange(emcfg.chunk_size, dtype=np.int64)
        state.chunks_used += 1
        toks, domains = corpus.sequences(chunk)
        scores = score_fn(stacked, jnp.asarray(toks[:, :emcfg.prefix_len]))
        assign = np.asarray(assign_fn(scores))
        # all-gather of one f16 score per (sequence, router): App. A.4
        state.comm_bytes += 2 * emcfg.chunk_size * E
        state.history.append({
            "iter": it,
            "router_ce": float(np.mean(losses[-1])),
            "purity": domain_purity(assign, domains, E),
            "load": np.bincount(assign, minlength=E).tolist(),
        })

    state.router_params = stacked
    return state


def shard_corpus(state_or_params, rcfg, corpus: SyntheticCorpus,
                 n_sequences: int, emcfg: EMConfig,
                 batch: int = 1024) -> tuple[np.ndarray, np.ndarray, int]:
    """Stage-2 segmentation (Algorithm 1 lines 12-13).

    Scores the first ``n_sequences`` of the corpus in chunks and returns
    (assignments (N,), domains (N,), comm_bytes).
    """
    stacked = getattr(state_or_params, "router_params", state_or_params)
    E = emcfg.n_experts
    score_fn = jax.jit(lambda t: routerlib.ensemble_scores(stacked, rcfg, t))
    cap = asg.default_capacity(batch, E, emcfg.capacity_factor)
    assign_fn = jax.jit(lambda s: asg.balanced_assignment(s, cap))
    out, doms = [], []
    comm = 0
    for start in range(0, n_sequences, batch):
        idx = np.arange(start, min(start + batch, n_sequences), dtype=np.int64)
        toks, d = corpus.sequences(idx)
        scores = score_fn(jnp.asarray(toks[:, :emcfg.prefix_len]))
        out.append(np.asarray(assign_fn(scores[:len(idx)])))
        doms.append(d)
        comm += 2 * len(idx) * E
    return np.concatenate(out), np.concatenate(doms), comm
