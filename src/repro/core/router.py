"""Router ensemble: E independent tiny LMs, stacked for vmap execution.

The router posterior is Bayes over per-expert prefix likelihoods
(paper Eq. 4-7): ``score[b, e] = log p(x_{1:M} | theta^{r,e})``.  On one
host we stack the E router param trees on a leading axis and ``vmap`` the
LM; on the production mesh the same stacked tree is sharded over the
``pod`` axis so each pod scores with its own router — the only cross-pod
traffic is the (B, E) score matrix (2 bytes/sequence/router, App. A.4).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import model as modellib

Params = dict[str, Any]


def init_ensemble(key, rcfg, n_experts: int) -> Params:
    """Stacked param tree with leading axis E (independent inits)."""
    keys = jax.random.split(key, n_experts)
    return jax.vmap(lambda k: modellib.init_params(k, rcfg))(keys)


def unstack(stacked: Params, e: int) -> Params:
    return jax.tree_util.tree_map(lambda x: x[e], stacked)


def sequence_loglik(params: Params, rcfg, tokens: jnp.ndarray) -> jnp.ndarray:
    """log p(x_{1:M}) per sequence under ONE router.  tokens: (B, M) -> (B,)."""
    labels = jnp.roll(tokens, -1, axis=1)
    nll, _ = modellib.per_token_nll(params, rcfg, {"tokens": tokens,
                                                   "labels": labels})
    mask = jnp.ones_like(nll).at[:, -1].set(0.0)     # no label for last pos
    return -(nll * mask).sum(axis=1)


def ensemble_scores(stacked: Params, rcfg, prefix: jnp.ndarray) -> jnp.ndarray:
    """Score matrix (B, E): prefix log-likelihood under every router."""
    scores = jax.vmap(lambda p: sequence_loglik(p, rcfg, prefix))(stacked)
    return scores.T                                   # (B, E)


def ensemble_train_step(stacked: Params, opt_states: Params, batches: dict,
                        rcfg, opt_cfg):
    """One SGD step for every router on its own batch.

    ``batches`` leaves have leading axis E: router e trains on batches[e].
    vmap == "each node trains its own router"; zero cross-router terms.
    """
    from repro.optim import adamw

    def loss_fn(params, batch):
        return modellib.loss_and_metrics(params, rcfg, batch)

    step = adamw.make_train_step(loss_fn, opt_cfg)
    return jax.vmap(step)(stacked, opt_states, batches)
