"""SmallTalk mixture: independent expert training + routed inference.

Stage 2 of Algorithm 1: after the routers have segmented the corpus, the
E experts are plain LMs trained completely independently (here looped on
one host; on the production mesh each lives on its own pod — see
``mixture_train_step`` which vmaps a stacked expert tree over the ``pod``
axis with zero cross-pod collectives).

Inference (§2.2): score the first ``prefix_len`` tokens with every router,
``argmax`` (no balancing), run the ONE selected expert.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import assignment as asg
from repro.core import router as routerlib
from repro.data import AssignedStream, SyntheticCorpus
from repro.models import model as modellib
from repro.optim import AdamWConfig, adamw

Params = dict[str, Any]


@dataclass
class MixtureState:
    expert_cfg: Any
    router_cfg: Any
    expert_params: list          # E independent param trees
    router_params: Params        # stacked (E, ...)
    prefix_len: int
    history: list = field(default_factory=list)


# ---------------------------------------------------------------------------
# Expert training (independent)
# ---------------------------------------------------------------------------
def train_expert(cfg, params: Params, stream, steps: int, opt_cfg: AdamWConfig,
                 log_every: int = 50) -> tuple[Params, list]:
    state = adamw.init_state(params, opt_cfg)
    step_fn = jax.jit(adamw.make_train_step(
        lambda p, b: modellib.loss_and_metrics(p, cfg, b), opt_cfg))
    hist = []
    for i in range(steps):
        batch = {k: jnp.asarray(v) for k, v in stream.next().items()
                 if k != "domain"}
        params, state, metrics = step_fn(params, state, batch)
        if i % log_every == 0 or i == steps - 1:
            hist.append({"step": i, "ce": float(metrics["ce"])})
    return params, hist


def train_mixture_experts(cfg, corpus: SyntheticCorpus, assignments: np.ndarray,
                          steps_per_expert: int, batch_size: int,
                          opt_cfg: AdamWConfig, key,
                          router_state=None, prefix_len: int = 64,
                          router_cfg=None) -> MixtureState:
    E = cfg.mixture.n_experts if cfg.mixture else int(assignments.max()) + 1
    expert_params = []
    hist = []
    for e in range(E):
        k = jax.random.fold_in(key, e)
        params = modellib.init_params(k, cfg)
        idx = np.nonzero(assignments == e)[0]
        stream = AssignedStream(corpus, idx, batch_size, seed=e)
        params, h = train_expert(cfg, params, stream, steps_per_expert, opt_cfg)
        expert_params.append(params)
        hist.append(h)
    return MixtureState(expert_cfg=cfg, router_cfg=router_cfg,
                        expert_params=expert_params,
                        router_params=(router_state.router_params
                                       if router_state else None),
                        prefix_len=prefix_len, history=hist)


# ---------------------------------------------------------------------------
# Routed evaluation / serving
# ---------------------------------------------------------------------------
def route(mix: MixtureState, tokens: jnp.ndarray,
          prefix_len: int | None = None) -> jnp.ndarray:
    """Inference routing: (B,) expert ids from a short prefix, pure argmax."""
    m = prefix_len or mix.prefix_len
    scores = routerlib.ensemble_scores(mix.router_params, mix.router_cfg,
                                       tokens[:, :m])
    return asg.argmax_assignment(scores)


def eval_nll(cfg, params: Params, batch: dict) -> np.ndarray:
    nll, _ = modellib.per_token_nll(params, cfg, batch)
    mask = batch.get("loss_mask", jnp.ones_like(nll))
    return np.asarray((nll * mask).sum(1) / jnp.maximum(mask.sum(1), 1))


def mixture_eval_ppl(mix: MixtureState, batch: dict,
                     prefix_len: int | None = None,
                     return_routes: bool = False):
    """Per-sequence routed NLL -> corpus perplexity."""
    toks = jnp.asarray(batch["tokens"])
    eids = np.asarray(route(mix, toks, prefix_len))
    nll = np.zeros(toks.shape[0], np.float64)
    for e in np.unique(eids):
        sel = np.nonzero(eids == e)[0]
        sub = {k: jnp.asarray(np.asarray(v)[sel]) for k, v in batch.items()
               if k != "domain"}
        nll[sel] = eval_nll(mix.expert_cfg, mix.expert_params[int(e)], sub)
    ppl = float(np.exp(nll.mean()))
    return (ppl, eids, nll) if return_routes else ppl


def dense_eval_ppl(cfg, params: Params, batch: dict) -> float:
    sub = {k: jnp.asarray(v) for k, v in batch.items() if k != "domain"}
    return float(np.exp(eval_nll(cfg, params, sub).mean()))


# ---------------------------------------------------------------------------
# Stacked multi-pod training step (dry-run / production)
# ---------------------------------------------------------------------------
def stack_experts(expert_params: list) -> Params:
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *expert_params)


def mixture_train_step(cfg, opt_cfg: AdamWConfig):
    """Build the stacked train step: vmap over the leading expert axis.

    On the (pod, data, model) mesh the stacked axis is sharded over
    ``pod``: each pod updates its own expert.  The compiled HLO contains
    NO collectives on the pod axis (verified by launch/dryrun.py), which
    is the paper's communication claim stated in the IR.
    """
    step = adamw.make_train_step(
        lambda p, b: modellib.loss_and_metrics(p, cfg, b), opt_cfg)
    return jax.vmap(step)
