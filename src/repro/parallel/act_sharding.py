"""Activation sharding constraints.

GSPMD propagates parameter shardings well through matmuls but gives up on
the attention head reshape when ``n_heads % model_size != 0`` (qwen2-1.5b:
12 heads on a 16-way model axis) — it silently REPLICATES attention over
the model axis, a 16x FLOP explosion we caught in the dry-run roofline.

This module lets model code request activation constraints without knowing
about meshes: the launch layer enables a context (axis sizes) around
tracing; outside of it (unit tests, single-host training) every helper is
an identity.

Head-sharding policy for attention:
  * heads divide the model axis      -> shard heads ("megatron");
  * otherwise                        -> shard the query SEQUENCE over the
    model axis ("context parallel"): q_chunks live on different devices,
    k/v are replicated over model (cheap for GQA), scores stay local.
"""
from __future__ import annotations

from contextlib import contextmanager

import jax
from jax.sharding import PartitionSpec as P

_STATE = {"sizes": None, "mesh": None, "data_axes": ("data",),
          "model_axes": ("model",)}


@contextmanager
def use(mesh, *, dp_only: bool = False, data_axes: tuple | None = None):
    """Enable activation constraints for tracing under ``mesh``.

    ``dp_only``: the model axis joins data parallelism (small archs where
    16-way tensor parallelism is all-reduce-bound — §Perf hillclimb 3);
    logical axis "data" maps to the physical ("data","model") pair and
    "model" maps to nothing.

    Set REPRO_BASELINE_SHARDING=1 to no-op (pure-GSPMD baseline — used by
    the §Perf before/after measurements)."""
    import os
    if os.environ.get("REPRO_BASELINE_SHARDING"):
        yield
        return
    prev = (_STATE["sizes"], _STATE["mesh"], _STATE["data_axes"],
            _STATE["model_axes"])
    _STATE["sizes"] = dict(zip(mesh.axis_names, mesh.devices.shape))
    _STATE["mesh"] = mesh
    if data_axes is not None:
        _STATE["data_axes"] = tuple(data_axes)      # e.g. ("pod","data")
    else:
        _STATE["data_axes"] = ("data", "model") if dp_only else ("data",)
    _STATE["model_axes"] = () if dp_only else ("model",)
    try:
        yield
    finally:
        (_STATE["sizes"], _STATE["mesh"], _STATE["data_axes"],
         _STATE["model_axes"]) = prev


def current_mesh():
    """Concrete mesh for manual-SPMD (shard_map) regions, or None."""
    return _STATE["mesh"]


def data_shard_map(fn, sharded_args, example_out, batch: int,
                   repl_args=()):
    """Wrap ``fn(*sharded_args, *repl_args)`` in a data-parallel shard_map
    if a mesh is active.

    Used for recurrent cells (sLSTM/mLSTM scans): GSPMD's sharding
    propagation gives up inside transposed nested scans and replicates the
    whole recurrence; manual SPMD keeps it local by construction.  Sharded
    tensors (args and outputs) must be batch-major; ``repl_args`` (e.g.
    recurrent weights) are replicated inside the region and their
    gradients psum-reduced by the shard_map transpose.
    """
    import jax
    from jax.sharding import PartitionSpec as P
    mesh = current_mesh()
    if mesh is None or batch % _size("data") != 0:
        return fn

    daxes = _resolve("data")
    dax = daxes[0] if len(daxes) == 1 else daxes

    def bspec(x):
        return P(dax, *([None] * (x.ndim - 1)))

    def rspec(x):
        return P(*([None] * x.ndim))

    in_specs = (jax.tree_util.tree_map(bspec, sharded_args)
                + jax.tree_util.tree_map(rspec, repl_args))
    out_specs = jax.tree_util.tree_map(bspec, example_out)
    return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_vma=False)


def enabled() -> bool:
    return _STATE["sizes"] is not None


def _resolve(name: str) -> tuple[str, ...]:
    """Map a logical axis name to physical mesh axes."""
    if name == "data":
        return _STATE["data_axes"]
    if name == "model":
        return _STATE["model_axes"]
    return (name,)


def _size(name: str) -> int:
    s = _STATE["sizes"]
    if not s:
        return 1
    n = 1
    for a in _resolve(name):
        n *= s.get(a, 1)
    return n


def constrain(x, *axes):
    """with_sharding_constraint if enabled; axes longer than ndim trimmed,
    non-divisible axes dropped.  Logical axis names resolve through the
    dp_only mapping (see :func:`use`)."""
    if not enabled():
        return x
    parts = []
    for i, dim in enumerate(x.shape):
        ax = axes[i] if i < len(axes) else None
        if ax is None or _size(ax) <= 1 or dim % _size(ax) != 0:
            parts.append(None)
        else:
            phys = _resolve(ax)
            parts.append(phys[0] if len(phys) == 1 else phys)
    if all(p is None for p in parts):
        return x
    return jax.lax.with_sharding_constraint(x, P(*parts))


def attn_mode(n_heads: int) -> str:
    """'heads' | 'ctx' | 'off' — how attention activations are sharded."""
    if not enabled():
        return "off"
    return "heads" if n_heads % _size("model") == 0 else "ctx"


def shard_attn_q(q):
    """q: (B, S, Hq, hd)."""
    mode = attn_mode(q.shape[2])
    if mode == "heads":
        return constrain(q, "data", None, "model", None)
    if mode == "ctx":
        return constrain(q, "data", "model", None, None)
    return q


def shard_attn_kv(k):
    """k/v: (B, S, Hkv, hd) — replicated over model unless heads divide."""
    if attn_mode(k.shape[2]) == "heads":
        return constrain(k, "data", None, "model", None)
    return constrain(k, "data", None, None, None)


def shard_tokens(x):
    """(B, S, D) residual-stream activations."""
    return constrain(x, "data", None, None)


def shard_moe_buffer(buf):
    """(E, C, D) expert dispatch buffer."""
    if not enabled():
        return buf
    if buf.shape[0] % _size("model") == 0:
        return constrain(buf, "model", None, None)
    return constrain(buf, None, "data", None)
