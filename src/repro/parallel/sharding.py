"""Logical-axis sharding rules: param/batch/cache pytrees -> PartitionSpec.

Mesh layout (launch/mesh.py):
  single-pod: (data=16, model=16)
  multi-pod : (pod=2, data=16, model=16)

Compute specs are Megatron-style tensor parallelism over ``model``
(attention heads / FFN hidden / vocab) with batch over ``data``.  Storage
specs (master params + AdamW moments) optionally extend the compute spec
with ``data`` on the largest unsharded axis (ZeRO-3) for archs in
``FSDP_ARCHS`` — required to fit the >=27B models in 16 GB/chip.

The ``pod`` axis never appears in *intra-expert* specs: in SmallTalk mode
it shards the leading expert-stack axis (see core/mixture.py), which is
exactly the paper's claim — no collectives cross the pod boundary.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Tree = Any


def _axis(mesh_sizes: dict[str, int], name: str, dim: int) -> str | None:
    """Use mesh axis ``name`` for a dim if it divides evenly."""
    n = mesh_sizes.get(name, 1)
    return name if n > 1 and dim % n == 0 else None


def mesh_sizes(mesh: Mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


# ---------------------------------------------------------------------------
# Param specs
# ---------------------------------------------------------------------------
_COL = {"wq", "wk", "wv", "wi", "wg", "up", "in_proj", "img_proj",
        "ffn_wi", "wz", "wf_", }          # (in, out): shard out
_ROW = {"wo", "down", "out_proj", "ffn_wo"}  # (in, out): shard in
_VOCAB = {"embed", "lm_head"}


def _param_leaf_spec(path: tuple, shape: tuple[int, ...],
                     ms: dict[str, int]) -> P:
    names = [_pname(p) for p in path]
    leaf = names[-1]
    stacked = "stages" in names
    pre = (None,) if stacked else ()
    body = shape[1:] if stacked else shape

    def spec(*axes):
        return P(*pre, *axes)

    in_moe = "moe" in names and "dense" not in names
    if leaf in _VOCAB:
        return spec(_axis(ms, "model", body[0]), None)
    if leaf == "router":                                  # moe gate: replicate
        return spec(*([None] * len(body)))
    if in_moe and leaf in ("wi", "wg"):                    # (E, D, F)
        if _axis(ms, "model", body[0]):
            return spec("model", None, None)
        return spec(None, None, _axis(ms, "model", body[2]))
    if in_moe and leaf == "wo":                            # (E, F, D)
        if _axis(ms, "model", body[0]):
            return spec("model", None, None)
        return spec(None, _axis(ms, "model", body[1]), None)
    if leaf in ("wz", "wi_", "wf", "wo_") and len(body) == 2 and "slstm" in names:
        return spec(None, _axis(ms, "model", body[1]))
    if "slstm" in names and leaf.startswith("r") and len(body) == 3:
        return spec(None, None, _axis(ms, "model", body[2]))
    if "mlstm" in names and leaf in ("wi", "wf"):          # gate proj (di, NH)
        return spec(None, _axis(ms, "model", body[1]))
    if leaf in _ROW and len(body) == 2:
        return spec(_axis(ms, "model", body[0]), None)
    if leaf in _COL and len(body) == 2:
        return spec(None, _axis(ms, "model", body[1]))
    if "slstm" in names and len(body) == 2 and leaf[0] == "w":
        return spec(None, _axis(ms, "model", body[1]))
    if leaf == "conv_w":                                   # (K, ch)
        return spec(None, _axis(ms, "model", body[1]))
    if leaf in ("conv_b", "bq", "bk", "bv") and len(body) == 1:
        return spec(_axis(ms, "model", body[0]))
    # scales, small per-head vectors, biases: replicate
    return spec(*([None] * len(body)))


def _pname(p) -> str:
    return str(getattr(p, "key", getattr(p, "idx", p)))


def param_specs(params_shape: Tree, mesh: Mesh, *, fsdp: bool = False) -> Tree:
    ms = mesh_sizes(mesh)

    def one(path, leaf):
        sp = _param_leaf_spec(path, tuple(leaf.shape), ms)
        if fsdp:
            sp = storage_extend(sp, tuple(leaf.shape), ms)
        return sp

    return jax.tree_util.tree_map_with_path(one, params_shape)


def storage_extend(spec: P, shape: tuple[int, ...], ms: dict[str, int],
                   axes: tuple[str, ...] = ("data",)) -> P:
    """ZeRO: extend a compute spec with ``axes`` on the largest free axis."""
    n = 1
    for a in axes:
        n *= ms.get(a, 1)
    if n <= 1:
        return spec
    if any(set(axes) & set((a,) if isinstance(a, str) else tuple(a or ()))
           for a in spec):
        return spec                      # already ZeRO-extended
    parts = list(spec) + [None] * (len(shape) - len(spec))
    order = sorted(range(len(shape)), key=lambda i: -shape[i])
    for i in order:
        if parts[i] is None and shape[i] % n == 0 and shape[i] >= 2 * n:
            parts[i] = axes[0] if len(axes) == 1 else axes
            return P(*parts)
    return spec


def param_specs_dp(params_shape: Tree, mesh: Mesh, *, zero: bool = True) -> Tree:
    """Pure data parallelism (model axis joins data): weights replicated
    for compute; master/opt state ZeRO-sharded over (data x model)."""
    ms = mesh_sizes(mesh)

    def one(path, leaf):
        sp = P(*([None] * leaf.ndim))
        if zero:
            sp = storage_extend(sp, tuple(leaf.shape), ms,
                                axes=("data", "model"))
        return sp

    return jax.tree_util.tree_map_with_path(one, params_shape)


def opt_state_specs(pspecs: Tree, ms_mesh: Mesh, *, fsdp: bool,
                    params_shape: Tree,
                    axes: tuple[str, ...] = ("data",)) -> Tree:
    """AdamW moments follow the (possibly ZeRO-extended) param specs."""
    ms = mesh_sizes(ms_mesh)

    def one(sp, leaf):
        return storage_extend(sp, tuple(leaf.shape), ms, axes=axes) \
            if fsdp else sp

    mspec = jax.tree_util.tree_map(one, pspecs, params_shape)
    return {"m": mspec, "v": mspec, "step": P()}


# ---------------------------------------------------------------------------
# Batch / cache specs
# ---------------------------------------------------------------------------
def batch_specs(batch_shape: Tree, mesh: Mesh,
                batch_axis: str | tuple[str, ...] = "data") -> Tree:
    ms = mesh_sizes(mesh)
    n = 1
    for a in ((batch_axis,) if isinstance(batch_axis, str) else batch_axis):
        n *= ms.get(a, 1)

    def one(path, leaf):
        name = _pname(path[-1]) if path else ""
        if name == "cache_index" or leaf.ndim == 0:
            return P()
        if leaf.shape[0] % n == 0 and leaf.shape[0] >= n:
            return P(batch_axis, *([None] * (leaf.ndim - 1)))
        return P(*([None] * leaf.ndim))

    return jax.tree_util.tree_map_with_path(one, batch_shape)


def _cache_leaf_spec(name: str, shape: tuple[int, ...],
                     ms: dict[str, int]) -> P:
    """shape includes the leading per-stage stack axis (rep)."""
    rep, B = shape[0], shape[1]
    bax = _axis(ms, "data", B)
    rest = shape[2:]
    if name in ("k", "v"):                                # (rep,B,S,hkv,hd)
        sax = None if bax else _axis(ms, "data", rest[0])
        hax = _axis(ms, "model", rest[1])
        dax = None if hax else _axis(ms, "model", rest[2])
        return P(None, bax, sax, hax, dax)
    if name == "pos":                                     # (rep,B,S)
        sax = None if bax else _axis(ms, "data", rest[0])
        return P(None, bax, sax)
    if name == "conv":                                    # (rep,B,K-1,ch)
        return P(None, bax, None, _axis(ms, "model", rest[1]))
    if name == "ssm":                                     # (rep,B,H,P,N)
        return P(None, bax, _axis(ms, "model", rest[0]), None, None)
    if name == "C" and len(rest) == 3:                    # (rep,B,NH,dh,dh)
        return P(None, bax, None, None, _axis(ms, "model", rest[2]))
    if name == "n" and len(rest) == 2:                    # (rep,B,NH,dh)
        return P(None, bax, None, _axis(ms, "model", rest[1]))
    if len(rest) == 1 and name in ("c", "n", "m", "h"):   # slstm (rep,B,D) / (rep,B,NH)
        return P(None, bax, _axis(ms, "model", rest[0]))
    return P(None, bax, *([None] * len(rest)))


def cache_tree_specs(cache_shape: Tree, mesh: Mesh) -> Tree:
    ms = mesh_sizes(mesh)

    def one(path, leaf):
        return _cache_leaf_spec(_pname(path[-1]), tuple(leaf.shape), ms)

    return jax.tree_util.tree_map_with_path(one, cache_shape)


def named(tree_specs: Tree, mesh: Mesh) -> Tree:
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s),
                                  tree_specs,
                                  is_leaf=lambda x: isinstance(x, P))
