from repro.optim.adamw import (AdamWConfig, apply_updates, init_state,
                               lr_at, make_train_step)

__all__ = ["AdamWConfig", "apply_updates", "init_state", "lr_at",
           "make_train_step"]
