"""AdamW + LR schedules, pure JAX (no optax dependency).

Paper settings (§3.1): AdamW β1=0.9 β2=0.99, weight decay 0.1, gradient
clipping at global-norm 0.1.  Experts: linear warmup → cosine decay.
Routers: linear warmup → constant (App. A.1 — only *relative* router
quality matters, so constant LR removes a tuning knob).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

Params = Any


@dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 5e-4
    warmup_steps: int = 3000
    total_steps: int = 256_000
    schedule: str = "cosine"        # cosine|constant
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.99
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 0.1
    opt_dtype: str = "float32"      # dtype of m/v moments


def lr_at(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / jnp.maximum(cfg.warmup_steps, 1)
    if cfg.schedule == "constant":
        post = jnp.float32(cfg.peak_lr)
    else:
        t = (step - cfg.warmup_steps) / jnp.maximum(
            cfg.total_steps - cfg.warmup_steps, 1)
        t = jnp.clip(t, 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        post = cfg.peak_lr * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)
    return jnp.where(step < cfg.warmup_steps, warm, post)


def init_state(params: Params, cfg: AdamWConfig) -> dict:
    odt = jnp.dtype(cfg.opt_dtype)
    zeros = lambda p: jnp.zeros(p.shape, odt)  # noqa: E731
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def clip_by_global_norm(grads: Params, max_norm: float) -> tuple[Params, jnp.ndarray]:
    leaves = jax.tree_util.tree_leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-12))
    return jax.tree_util.tree_map(lambda g: g * scale.astype(g.dtype), grads), gnorm


def apply_updates(params: Params, grads: Params, state: dict,
                  cfg: AdamWConfig) -> tuple[Params, dict, dict]:
    """One AdamW step.  Returns (new_params, new_state, info)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state["step"] + 1
    lr = lr_at(cfg, step)
    sf = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** sf
    bc2 = 1.0 - cfg.b2 ** sf
    odt = jnp.dtype(cfg.opt_dtype)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m32 = m.astype(jnp.float32) * cfg.b1 + gf * (1 - cfg.b1)
        v32 = v.astype(jnp.float32) * cfg.b2 + jnp.square(gf) * (1 - cfg.b2)
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return newp.astype(p.dtype), m32.astype(odt), v32.astype(odt)

    flat, treedef = jax.tree_util.tree_flatten(params)
    gflat = treedef.flatten_up_to(grads)
    mflat = treedef.flatten_up_to(state["m"])
    vflat = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat, gflat, mflat, vflat)]
    newp = treedef.unflatten([o[0] for o in out])
    newm = treedef.unflatten([o[1] for o in out])
    newv = treedef.unflatten([o[2] for o in out])
    return newp, {"m": newm, "v": newv, "step": step}, {"lr": lr, "gnorm": gnorm}


def make_train_step(loss_fn: Callable, cfg: AdamWConfig):
    """loss_fn(params, batch) -> (loss, metrics).  Returns jit-able step."""
    def train_step(params, state, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch)
        params, state, info = apply_updates(params, grads, state, cfg)
        metrics = dict(metrics, loss=loss, **info)
        return params, state, metrics
    return train_step
