"""Serving bench: continuous-batching engine vs the old serial path.

Workload: a mixed-length batch (equal prompt lengths — the old path cannot
mix them — but per-request completion budgets spread over [min,max]) routed
across >= 2 experts.  The baseline serves each expert group serially and
decodes every request to the group maximum; the engine keeps a fixed
number of decode lanes per expert full, admitting queued requests in
batched prefills as lanes free up, with full-attention KV in the paged
block pool.  Both paths are greedy and must produce byte-identical
tokens — the bench asserts that, then compares useful-token throughput
and reports the paged-cache memory footprint (HBM bytes per lane vs the
dense ``lanes * max_len`` slab) and the admission prefill-call count.

Both paths are warmed first (same shapes as the timed run) so jit compile
time is excluded.  The model is sized so per-step compute, not dispatch
overhead, dominates — wasted lane-tokens then cost real wall time, which
is exactly what continuous batching reclaims.

  PYTHONPATH=src python benchmarks/serve_bench.py
  PYTHONPATH=src python benchmarks/serve_bench.py --smoke   # CI gate

``--smoke`` shrinks the models/workload so the token-identity gate (plus
pool-pressure coverage) runs in CI on every push; the speedup exit check
is skipped there because tiny models are dispatch-bound.
"""
from __future__ import annotations

import argparse
import json
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import router as routerlib
from repro.data import DataConfig, SyntheticCorpus
from repro.models import model as modellib
from repro.serving import EngineConfig, MixtureServeEngine, baseline
from repro.serving import cache as cachelib

EXPERT = ModelConfig(name="bench-expert", n_layers=4, d_model=256, n_heads=8,
                     n_kv_heads=8, d_ff=1024, vocab_size=512,
                     ffn_type="gelu", loss_chunk=128,
                     compute_dtype="float32", param_dtype="float32")
ROUTER = ModelConfig(name="bench-router", n_layers=1, d_model=64, n_heads=4,
                     n_kv_heads=4, d_ff=256, vocab_size=512,
                     ffn_type="gelu", loss_chunk=128,
                     compute_dtype="float32", param_dtype="float32")
SMOKE_EXPERT = EXPERT.replace(name="smoke-expert", n_layers=2, d_model=64,
                              n_heads=4, n_kv_heads=4, d_ff=128,
                              vocab_size=128, loss_chunk=32)
SMOKE_ROUTER = ROUTER.replace(name="smoke-router", d_model=32, n_heads=2,
                              n_kv_heads=2, d_ff=64, vocab_size=128,
                              loss_chunk=32)


def build(ecfg, rcfg, n_experts: int, seed: int):
    key = jax.random.PRNGKey(seed)
    router_params = routerlib.init_ensemble(key, rcfg, n_experts)
    expert_params = [modellib.init_params(jax.random.fold_in(key, e), ecfg)
                     for e in range(n_experts)]
    return expert_params, router_params


def dense_slab_bytes(ecfg, lanes: int, max_len: int) -> int:
    """Bytes the replaced dense (lanes, max_len) per-lane layout would hold."""
    return cachelib.kv_cache_bytes(modellib.cache_specs(ecfg, lanes, max_len))


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--experts", type=int, default=2)
    ap.add_argument("--lanes", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--min-new", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--block-size", type=int, default=16,
                    help="tokens per paged KV block")
    ap.add_argument("--blocks-per-expert", type=int, default=0,
                    help="KV pool blocks per expert "
                         "(0 = lanes*max_len/block_size, i.e. no pressure)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default=None, help="write results to this file")
    ap.add_argument("--smoke", action="store_true",
                    help="small CI workload: identity gate incl. pool "
                         "pressure, no speedup exit check")
    ap.add_argument("--no-check", action="store_true",
                    help="skip the engine-beats-baseline exit check")
    args = ap.parse_args()
    if args.smoke:
        ecfg, rcfg = SMOKE_EXPERT, SMOKE_ROUTER
        args.requests = min(args.requests, 10)
        args.lanes = min(args.lanes, 2)
        args.max_new = min(args.max_new, 16)
        if args.blocks_per_expert == 0:   # force block reuse under pressure
            total = args.prompt_len + args.max_new
            args.blocks_per_expert = -(-total // args.block_size) + 1
    else:
        ecfg, rcfg = EXPERT, ROUTER
    assert args.requests >= 8 and args.experts >= 2, "workload too small"

    expert_params, router_params = build(ecfg, rcfg, args.experts, args.seed)
    corpus = SyntheticCorpus(DataConfig(vocab_size=ecfg.vocab_size,
                                        seq_len=args.prompt_len,
                                        n_domains=args.experts))
    prompts, _ = corpus.sequences(np.arange(args.requests) + 555_000)
    rng = np.random.default_rng(args.seed)
    n_new = rng.integers(args.min_new, args.max_new + 1, size=args.requests)
    max_len = -(-(args.prompt_len + args.max_new) // args.block_size) \
        * args.block_size                 # round lane budget up to blocks
    prefix_len = args.prompt_len

    # ---- baseline: old serial per-group path -----------------------------
    # warm every shape the timed run will hit (per-group prefill + decode)
    eids = baseline.route(rcfg, router_params, prompts, prefix_len)
    for e in np.unique(eids):
        n_group = int((eids == e).sum())
        baseline.generate(ecfg, expert_params[int(e)],
                          jnp.asarray(prompts[:n_group]), 2,
                          cache_len=max_len)
    serial = baseline.serve_serial(ecfg, rcfg, expert_params,
                                   router_params, prompts, n_new,
                                   prefix_len=prefix_len, cache_len=max_len)

    # ---- engine: continuous batching over the paged pool ------------------
    eng = MixtureServeEngine(
        ecfg, rcfg, expert_params, router_params,
        EngineConfig(lanes_per_expert=args.lanes, max_len=max_len,
                     prefix_len=prefix_len,
                     min_prefill_bucket=args.prompt_len,
                     block_size=args.block_size,
                     pool_blocks=args.blocks_per_expert))
    # warmup: compile every admission batch width the timed run can hit
    # (routing-independent — see MixtureServeEngine.warmup)
    eng.warmup(args.prompt_len)
    timed = [eng.submit(prompts[i], int(n_new[i]), arrival_tick=eng.tick)
             for i in range(args.requests)]  # timed: all arrive at once
    uid0 = timed[0].uid
    res = eng.run()

    # ---- identity + report ------------------------------------------------
    mismatches = []
    for r in res["requests"]:
        i = r.uid - uid0
        if r.expert != serial["routes"][i] or \
                not np.array_equal(np.asarray(r.tokens), serial["tokens"][i]):
            mismatches.append(i)
    speedup = res["tokens_per_s"] / serial["tokens_per_s"]
    dense = dense_slab_bytes(ecfg, args.lanes, max_len)
    report = {
        "workload": {"requests": args.requests, "experts": args.experts,
                     "lanes": args.lanes, "prompt_len": args.prompt_len,
                     "max_len": max_len,
                     "new_tokens": [int(x) for x in n_new]},
        "serial": {"wall_s": round(serial["wall_s"], 3),
                   "tokens_per_s": round(serial["tokens_per_s"], 1),
                   "useful_tokens": serial["useful_tokens"],
                   "wasted_tokens": serial["wasted_tokens"]},
        "engine": {"wall_s": round(res["wall_s"], 3),
                   "tokens_per_s": round(res["tokens_per_s"], 1),
                   "useful_tokens": res["useful_tokens"],
                   "occupancy": round(res["occupancy"], 3),
                   "ticks": res["ticks"],
                   "prefill_calls": res["prefill_calls"]},
        "paged_kv": {"block_size": args.block_size,
                     "pool_blocks_per_expert": eng.pool_blocks,
                     "peak_blocks": {e: s["peak_blocks"] for e, s in
                                     res["per_expert"].items()},
                     "hbm_bytes_per_lane": res["kv_bytes_per_lane"],
                     "dense_slab_bytes_per_lane": dense // args.lanes},
        "speedup": round(speedup, 2),
        "tokens_identical": not mismatches,
    }
    print(json.dumps(report, indent=1))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=1)
    if mismatches:
        print(f"FAIL: token mismatch on requests {mismatches[:8]}")
        return 1
    print(f"engine {res['tokens_per_s']:.1f} tok/s vs serial "
          f"{serial['tokens_per_s']:.1f} tok/s -> {speedup:.2f}x "
          f"({serial['wasted_tokens']} wasted baseline tokens reclaimed); "
          f"KV {res['kv_bytes_per_lane']} B/lane vs dense "
          f"{dense // args.lanes} B/lane, "
          f"{res['prefill_calls']} prefill calls for {args.requests} requests")
    if args.smoke:
        # the pressured pool above serializes admission, so the batching
        # bound needs a second, full-pool engine: k_e simultaneous
        # arrivals per expert must cost <= ceil(k_e / lanes) prefills
        eng2 = MixtureServeEngine(
            ecfg, rcfg, expert_params, router_params,
            EngineConfig(lanes_per_expert=args.lanes, max_len=max_len,
                         prefix_len=prefix_len,
                         min_prefill_bucket=args.prompt_len,
                         block_size=args.block_size))
        eng2.warmup(args.prompt_len)
        # uniform budget: lanes then free together, so admission drains
        # `lanes` requests per prefill and the ceil bound is tight
        uniform = args.min_new
        reqs = [eng2.submit(prompts[i], uniform, arrival_tick=eng2.tick)
                for i in range(args.requests)]
        res2 = eng2.run()
        for e, st in enumerate(eng2._experts):
            k_e = sum(1 for r in reqs if r.expert == e)
            if st.prefill_calls > -(-k_e // args.lanes):
                print(f"FAIL: expert {e} took {st.prefill_calls} prefill "
                      f"calls for {k_e} simultaneous arrivals "
                      f"(bound ceil(k/lanes) = {-(-k_e // args.lanes)})")
                return 1
        if any(not np.array_equal(np.asarray(r.tokens),
                                  serial["tokens"][i][:uniform])
               for i, r in enumerate(reqs)):
            print("FAIL: full-pool token mismatch")
            return 1
        print("smoke OK: token identity under pool pressure, batched "
              f"admission within budget ({res2['prefill_calls']} prefills "
              f"for {args.requests} requests)")
        return 0
    if not args.no_check and speedup <= 1.0:
        print("FAIL: engine did not beat the serial baseline")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
