"""Serving bench: continuous-batching engine vs the old serial path.

Workload: a mixed-length batch (equal prompt lengths — the old path cannot
mix them — but per-request completion budgets spread over [min,max]) routed
across >= 2 experts.  The baseline serves each expert group serially and
decodes every request to the group maximum; the engine keeps a fixed
number of decode lanes per expert full, admitting queued requests in
batched prefills as lanes free up, with full-attention KV in the paged
block pool.  Both paths must produce byte-identical tokens — greedy by
default, or ``--mode sampled`` for a temperature/top-k/top-p workload
with a shared stop-token set (early stops free engine lanes mid-flight,
while the serial path still decodes each group to its maximum and throws
the surplus away — exactly the waste continuous batching reclaims).  The
bench asserts identity, then compares useful-token throughput and
reports the paged-cache memory footprint (HBM bytes per lane vs the
dense ``lanes * max_len`` slab), the admission prefill-call count, and
the decode read traffic: bytes/tick the paged-attention kernel reads
(live blocks only; ``--decode-impl pallas`` selects the Pallas kernel,
interpret-mode on CPU) vs the gathered ``(lanes, max_len)`` view the
old decode materialized — the former must be strictly smaller or the
bench fails.

v7 adds the admission-side mirror of that read gate: the fused paged
prefill (``--prefill-impl``; attention + direct pool block writes, no
dense KV slab and no ``insert_requests`` re-read) is priced against the
slab+scatter path it replaced, and fused write bytes must be strictly
below slab write bytes or the bench fails.  The decode epilogue's
``(lanes, vocab)`` logits HBM traffic is reported alongside — it drops
to zero when ``--decode-impl pallas`` fuses unembed+softcap+sampling
into the decode kernel.  ``--trajectory FILE`` appends a one-line JSONL
perf record (tokens/sec, decode read bytes, prefill write bytes) so CI
can accumulate ``benchmarks/TRAJECTORY.jsonl`` across PRs.

Both paths are warmed first (same shapes as the timed run) so jit compile
time is excluded.  The model is sized so per-step compute, not dispatch
overhead, dominates — wasted lane-tokens then cost real wall time.

  PYTHONPATH=src python benchmarks/serve_bench.py
  PYTHONPATH=src python benchmarks/serve_bench.py --mode sampled
  PYTHONPATH=src python benchmarks/serve_bench.py --smoke \
      --json BENCH_serve.json                             # CI gate

``--transport process`` runs every expert in its own spawned OS process
(the multi-host story proven on one machine: pickled request/token
messages over pipes are the only cross-expert traffic) — the identity
gates must hold there exactly as on the in-process loopback default.
``--transport tcp`` goes one further: expert workers are discovered
through a ``repro.serving.net`` registry and reached over raw TCP, and
the bench self-starts a local fleet (registry + one worker process per
expert, via the real module CLIs) when ``--registry`` is omitted.  The
same identity gates apply bitwise, and a **two-frontend** section
connects two stateless frontends to the one fleet concurrently — each
leases its own uid namespace from the registry, they split the workload
and decode interleaved, and the bench hard-fails on any uid collision
or token deviation from the serial reference (zero cross-frontend
stream corruption).

Every prompt shares its leading ``--shared-prefix-len`` tokens (default
half the prompt) — the prefix-sharing workload: each expert's radix
cache maps those block-aligned leading tokens to pool blocks, so once
one request has prefilled them, later admissions reserve only the novel
suffix and replay it through the decode path (copy-on-write: shared
blocks are read-only, refcounted, evicted LRU under pool pressure).
The report's ``prefix_sharing`` section counts hit blocks and prefill
tokens saved; in ``--smoke`` mode saved tokens must be > 0 with tokens
still bitwise identical, or the bench fails.  ``--no-prefix-cache``
turns sharing off; ``--prefill-chunk-tokens`` caps suffix replay per
tick (the chunked-admission state machine).

``--smoke`` shrinks the models/workload so the token-identity gates
(greedy under pool pressure, batched-admission prefill budget, AND a
sampled + early-stop gate) run in CI on every push; the speedup exit
check is skipped there because tiny models are dispatch-bound.  The
``--json`` report follows the ``BENCH_serve/v5`` schema (v4 + the
``two_frontend`` section and ``"tcp"`` as a transport value), persisted
as a CI artifact so the perf trajectory accumulates.

``--open-loop`` adds the production-facing workload the closed-loop
sections cannot measure: **Poisson arrivals** (``--arrival-rate``
requests per engine tick) with a **Zipf expert mix** (``--zipf-a``
over experts ranked by routed traffic), reporting per-expert p50/p99
time-to-first-token and inter-token latency in wall milliseconds —
arrivals keep coming whether or not the engine keeps up, so queueing
delay shows up in TTFT instead of hiding behind aggregate tokens/sec.
With ``--hot-replicas R`` (R > 1) the workload runs twice — one server
per expert, then R replicas of the hottest expert with least-loaded
admission — and the bench hard-fails unless the hot expert's p99 TTFT
strictly improves while both runs stay token-identical to the serial
oracle (replica placement cannot change tokens: the sampler is
counter-based per ``(seed, uid, step)``).

``--autoscale`` adds the live-scaling gate on the same Zipf workload:
the engine, handed a ``ScalePolicy`` instead of a replica map, must
spawn + warm + adopt a hot-expert replica **mid-serve** under pressure,
quiesce and release an idle cold-expert replica (recalling its queued
requests without losing a token), beat the static single-replica run's
hot p99 TTFT, and stay bitwise identical to the serial oracle
throughout.  Works on all three transports; on tcp a dedicated
``LocalFleet`` doubles as the scale executor, so scale-up boots a real
worker process and scale-down kills one.  The v6 schema carries the
events and both latency profiles in an ``autoscale`` section.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import router as routerlib
from repro.data import DataConfig, SyntheticCorpus
from repro.models import model as modellib
from repro.serving import SamplingParams, ServeFrontend, baseline
from repro.serving import cache as cachelib
from repro.serving import cli as servecli

EXPERT = ModelConfig(name="bench-expert", n_layers=4, d_model=256, n_heads=8,
                     n_kv_heads=8, d_ff=1024, vocab_size=512,
                     ffn_type="gelu", loss_chunk=128,
                     compute_dtype="float32", param_dtype="float32")
ROUTER = ModelConfig(name="bench-router", n_layers=1, d_model=64, n_heads=4,
                     n_kv_heads=4, d_ff=256, vocab_size=512,
                     ffn_type="gelu", loss_chunk=128,
                     compute_dtype="float32", param_dtype="float32")
SMOKE_EXPERT = EXPERT.replace(name="smoke-expert", n_layers=2, d_model=64,
                              n_heads=4, n_kv_heads=4, d_ff=128,
                              vocab_size=128, loss_chunk=32)
SMOKE_ROUTER = ROUTER.replace(name="smoke-router", d_model=32, n_heads=2,
                              n_kv_heads=2, d_ff=64, vocab_size=128,
                              loss_chunk=32)


def build(ecfg, rcfg, n_experts: int, seed: int):
    key = jax.random.PRNGKey(seed)
    router_params = routerlib.init_ensemble(key, rcfg, n_experts)
    expert_params = [modellib.init_params(jax.random.fold_in(key, e), ecfg)
                     for e in range(n_experts)]
    return expert_params, router_params


def dense_slab_bytes(ecfg, lanes: int, max_len: int) -> int:
    """Bytes the replaced dense (lanes, max_len) per-lane layout would hold."""
    return cachelib.kv_cache_bytes(modellib.cache_specs(ecfg, lanes, max_len))


def _pctl(xs, q: float) -> float:
    return float(np.percentile(np.asarray(xs, float), q)) if len(xs) else 0.0


def open_loop_workload(rcfg, router_params, corpus, args, rng):
    """Skewed open-loop workload: (prompts, n_new, arrival_ticks, hot_expert).

    A candidate prompt pool is routed once with the real router to learn
    which prompts land on which expert; experts are ranked by that pool
    traffic and each request draws its expert rank from a Zipf(--zipf-a)
    law, then takes the next pooled prompt routed there — so the engine's
    own router reproduces the intended skew at serve time.  Arrival ticks
    are Poisson: floored cumsum of Exponential(1/--arrival-rate) gaps.
    """
    pool_n = max(4 * args.ol_requests, 8 * args.experts)
    pool, _ = corpus.sequences(np.arange(pool_n) + 777_555)
    eids = np.asarray(baseline.route(rcfg, router_params, pool,
                                     args.prompt_len))
    by_expert = [np.flatnonzero(eids == e) for e in range(args.experts)]
    ranked = [e for e in sorted(range(args.experts),
                                key=lambda e: (-len(by_expert[e]), e))
              if len(by_expert[e])]
    ranks = np.minimum(rng.zipf(args.zipf_a, size=args.ol_requests),
                       len(ranked)) - 1
    cursors = [0] * args.experts
    picks = []
    for k in ranks:
        e = ranked[int(k)]
        picks.append(int(by_expert[e][cursors[e] % len(by_expert[e])]))
        cursors[e] += 1
    picks = np.asarray(picks)
    hot = int(np.bincount(eids[picks], minlength=args.experts).argmax())
    gaps = rng.exponential(1.0 / args.arrival_rate, size=args.ol_requests)
    arrivals = np.floor(np.cumsum(gaps)).astype(int)
    n_new = rng.integers(args.min_new, args.max_new + 1,
                         size=args.ol_requests)
    return pool[picks], n_new, arrivals, hot


def _drive_workload(eng, reqs):
    """Drive an already-submitted open-loop workload to drain,
    wall-stamping each tracked request's arrival and every one of its
    token deltas; returns ``(arrive_wall, token_walls)`` keyed by uid.
    Untracked traffic (e.g. a warm-the-scaler pressure phase's
    stragglers) streams through without polluting the stamps."""
    arrive_wall: dict[int, float] = {}
    token_walls: dict[int, list[float]] = {r.uid: [] for r in reqs}
    while eng.busy:
        eng._skip_idle_gap()          # jump empty gaps to the next arrival
        now = time.perf_counter()
        for r in reqs:
            if r.uid not in arrive_wall and r.arrival_tick <= eng.tick:
                arrive_wall[r.uid] = now
        eng.step()
        now = time.perf_counter()
        for d in eng.last_deltas:
            if d.request.uid in token_walls:
                token_walls[d.request.uid].append(now)
    return arrive_wall, token_walls


def _lat(sub, arrive_wall, token_walls):
    """p50/p99 TTFT + inter-token latency (ms) over ``sub`` requests."""
    ttft = [token_walls[r.uid][0] - arrive_wall[r.uid] for r in sub]
    itl = [b - a for r in sub
           for a, b in zip(token_walls[r.uid], token_walls[r.uid][1:])]
    return {"ttft_p50_ms": round(_pctl(ttft, 50) * 1e3, 2),
            "ttft_p99_ms": round(_pctl(ttft, 99) * 1e3, 2),
            "itl_p50_ms": round(_pctl(itl, 50) * 1e3, 2),
            "itl_p99_ms": round(_pctl(itl, 99) * 1e3, 2)}


def _ol_mismatches(reqs, serial) -> list[int]:
    """Workload indices whose engine route or tokens deviate from the
    serial oracle."""
    return [i for i, r in enumerate(reqs)
            if r.expert != serial["routes"][i]
            or not np.array_equal(np.asarray(r.tokens),
                                  serial["tokens"][i])]


def open_loop_run(ecfg, rcfg, expert_params, router_params, args, max_len,
                  prompts, n_new, arrivals, sampling, serial, replicas):
    """One open-loop pass: drive the engine tick by tick, wall-stamping
    each request's arrival and every token delta.  Returns (run report
    with p50/p99 TTFT + inter-token latency overall and per expert,
    list of token-mismatch indices vs the serial oracle).

    The engine gets a full KV pool (``pool_blocks=0``) so lane queueing
    — the thing replication relieves — is what TTFT measures, not block
    pressure.
    """
    eng_cfg = dataclasses.replace(
        servecli.engine_config_from_args(args, max_len=max_len,
                                         prefix_len=args.prompt_len,
                                         min_prefill_bucket=args.prompt_len),
        pool_blocks=0)
    with ServeFrontend(ecfg, rcfg, expert_params, router_params, eng_cfg,
                       replicas=replicas) as eng:
        eng.warmup(args.prompt_len, sampled=sampling.temperature > 0)
        reqs = [eng.submit(prompts[i], int(n_new[i]), sampling=sampling,
                           arrival_tick=int(arrivals[i]))
                for i in range(len(prompts))]
        arrive_wall, token_walls = _drive_workload(eng, reqs)
    bad = _ol_mismatches(reqs, serial)
    per_expert = {
        e: {"served": sum(r.expert == e for r in reqs),
            **_lat([r for r in reqs if r.expert == e],
                   arrive_wall, token_walls)}
        for e in sorted({r.expert for r in reqs})}
    return {"replicas": {int(e): int(c)
                         for e, c in dict(replicas or {}).items()},
            **_lat(reqs, arrive_wall, token_walls), "per_expert": per_expert,
            "tokens_identical": not bad}, bad


def run_autoscale(args, ecfg, rcfg, expert_params, router_params, corpus,
                  max_len):
    """The live-autoscaling gate: prove the control plane grows AND
    shrinks the replica map mid-serve, improves the hot expert's tail
    latency, and never touches a token.  Returns ``(section, fail)``
    where ``fail`` is None on success.

    Two runs over the same open-loop Zipf workload:

    1. **static** — one replica per expert (on tcp, whatever the
       dedicated fleet registered: the hot expert always has exactly
       one), the existing open-loop driver.  This is the p99 baseline.
    2. **autoscaled** — the cold expert starts with a spare replica (so
       scale-down has a victim) and a ``ScalePolicy`` is installed.  A
       **pressure phase** first streams short greedy requests at the
       hot expert, wall-paced (``--as-pace-ms``) so spawned workers have
       real time to warm off-path, until the scaler has adopted a new
       hot replica mid-serve and idle-retired a cold one; then the
       measured workload runs against the scaled placement.

    Hard gates: an ``up`` event for the hot expert, a ``down`` event
    for the cold expert, hot-expert p99 TTFT strictly below the static
    run, and every request (pressure phase included) bitwise identical
    to the serial oracle.  All traffic here is greedy, so tokens are
    uid-independent and the oracle holds regardless of uid namespace
    (tcp frontends lease namespaces).
    """
    scale = servecli.scale_policy_from_args(args)
    ol_rng = np.random.default_rng(args.seed + 2)
    prompts, n_new, arrivals, hot = open_loop_workload(
        rcfg, router_params, corpus, args, ol_rng)
    serial_ol = baseline.serve_serial(
        ecfg, rcfg, expert_params, router_params, prompts, n_new,
        prefix_len=args.prompt_len, cache_len=max_len)
    counts = np.bincount(np.asarray(serial_ol["routes"]),
                         minlength=args.experts).astype(float)
    counts[hot] = np.inf                   # the hot expert is never cold
    cold = int(counts.argmin())
    # the pressure ring: pool prompts that route to the hot expert,
    # cycled for as long as the scaler needs — each short and greedy so
    # one serial pass is the oracle for every lap of the ring
    pool, _ = corpus.sequences(np.arange(max(64, 8 * args.experts)) + 991_000)
    ring_eids = np.asarray(baseline.route(rcfg, router_params, pool,
                                          args.prompt_len))
    ring = pool[ring_eids == hot][:16]
    if not len(ring):
        return {}, "no pool prompt routes to the hot expert"
    ring_new = 4
    ring_ref = baseline.serve_serial(
        ecfg, rcfg, expert_params, router_params, ring,
        np.full(len(ring), ring_new), prefix_len=args.prompt_len,
        cache_len=max_len)
    eng_cfg = dataclasses.replace(
        servecli.engine_config_from_args(args, max_len=max_len,
                                         prefix_len=args.prompt_len,
                                         min_prefill_bucket=args.prompt_len),
        pool_blocks=0)
    section = {
        "policy": {"up_pressure": scale.up_pressure,
                   "up_ticks": scale.up_ticks,
                   "down_idle_ticks": scale.down_idle_ticks,
                   "cooldown_ticks": scale.cooldown_ticks,
                   "min_replicas": scale.min_replicas,
                   "max_replicas": scale.max_replicas,
                   "every": scale.every},
        "hot_expert": hot, "cold_expert": cold,
        "requests": int(args.ol_requests),
    }
    fleet = None
    try:
        if args.transport == "tcp":
            # a dedicated full-pool fleet (the main bench fleet may run a
            # pressured pool): the cold expert gets its scale-down victim
            # at boot, and the fleet doubles as the scale executor —
            # scale-up boots a real worker process, scale-down kills it
            from repro.serving.net.fleet import LocalFleet
            spec_cfg = dataclasses.replace(eng_cfg, transport="loopback",
                                           registry="")
            fleet = LocalFleet(ecfg, spec_cfg, args.experts, seed=args.seed,
                               replicas={cold: 2},
                               warmup_len=args.prompt_len)
            eng_cfg = dataclasses.replace(eng_cfg,
                                          registry=fleet.registry_addr)

        # ---- static run: hot expert on one replica --------------------
        with ServeFrontend(ecfg, rcfg, expert_params, router_params,
                           eng_cfg) as eng:
            eng.warmup(args.prompt_len, sampled=False)
            base = eng.tick
            reqs = [eng.submit(prompts[i], int(n_new[i]),
                               arrival_tick=base + int(arrivals[i]))
                    for i in range(len(prompts))]
            aw, tw = _drive_workload(eng, reqs)
        section["static"] = {
            **_lat(reqs, aw, tw),
            "hot": _lat([r for r in reqs if r.expert == hot], aw, tw)}
        bad = _ol_mismatches(reqs, serial_ol)
        if bad:
            return section, f"static-run token mismatch on {bad[:8]}"

        # ---- autoscaled run -------------------------------------------
        with ServeFrontend(ecfg, rcfg, expert_params, router_params,
                           eng_cfg,
                           replicas=None if args.transport == "tcp"
                           else {cold: 2},
                           scale=scale, scale_executor=fleet) as eng:
            eng.warmup(args.prompt_len, sampled=False)
            # pressure phase: keep the hot expert backlogged past lane
            # capacity until the scaler spawns, warms, and ADOPTS a new
            # replica mid-serve, then drain and wait for the idle cold
            # replica to quiesce and release; wall pacing gives process/
            # tcp workers real seconds to come up off-path
            pace = args.as_pace_ms / 1e3
            deadline = time.monotonic() + args.as_timeout
            ring_reqs, outstanding = [], 0
            # enough in flight to hold positive pressure on the hot
            # expert's single replica (capacity `lanes`), yet a small
            # enough residue that the stragglers left at the break
            # don't crowd the measured phase's hot lanes
            target = 2 * args.lanes + 2
            while time.monotonic() < deadline:
                up = any(ev.action == "up" and ev.expert == hot
                         for ev in eng.scale_events)
                down = any(ev.action == "down" and ev.expert == cold
                           for ev in eng.scale_events)
                if up and down:
                    # straight into the measured phase: the ring
                    # stragglers drain alongside it (their deltas stay
                    # untracked) and the immediate load keeps the idle
                    # policy off the just-adopted replica
                    break
                while outstanding < target:
                    k = len(ring_reqs) % len(ring)
                    ring_reqs.append(eng.submit(ring[k], ring_new,
                                                arrival_tick=eng.tick))
                    outstanding += 1
                outstanding -= len(eng.step())
                if pace:
                    time.sleep(pace)
            evs = list(eng.scale_events)
            scaled_up = any(ev.action == "up" and ev.expert == hot
                            for ev in evs)
            retired = any(ev.action == "down" and ev.expert == cold
                          for ev in evs)
            # measured phase: the same workload as the static run, now
            # against the scaled placement (ring stragglers drain
            # alongside — checked below, once they have finished)
            base = eng.tick
            reqs = [eng.submit(prompts[i], int(n_new[i]),
                               arrival_tick=base + int(arrivals[i]))
                    for i in range(len(prompts))]
            aw, tw = _drive_workload(eng, reqs)
            section["autoscaled"] = {
                **_lat(reqs, aw, tw),
                "hot": _lat([r for r in reqs if r.expert == hot], aw, tw),
                "pressure_requests": len(ring_reqs),
                "scale_ups": sum(ev.action == "up" for ev in
                                 eng.scale_events),
                "scale_downs": sum(ev.action == "down" for ev in
                                   eng.scale_events),
                "events": [ev.to_dict() for ev in eng.scale_events],
                "final_replicas": {e: n for e, n
                                   in enumerate(eng.replicas)}}
        bad = _ol_mismatches(reqs, serial_ol)
        bad_ring = [k for k, r in enumerate(ring_reqs)
                    if not np.array_equal(np.asarray(r.tokens),
                                          ring_ref["tokens"][k % len(ring)])]
        p99_s = section["static"]["hot"]["ttft_p99_ms"]
        p99_a = section["autoscaled"]["hot"]["ttft_p99_ms"]
        section["scaled_up_hot"] = scaled_up
        section["retired_cold"] = retired
        section["p99_ttft_improved"] = p99_a < p99_s
        section["tokens_identical"] = not bad and not bad_ring
        print(f"autoscale ({args.transport}): hot expert {hot} "
              f"{'gained' if scaled_up else 'DID NOT GAIN'} a replica "
              f"mid-serve, cold expert {cold} "
              f"{'retired' if retired else 'DID NOT RETIRE'} one; hot "
              f"p99 TTFT {p99_s}ms static -> {p99_a}ms autoscaled")
        if not scaled_up:
            return section, (f"hot expert {hot} never gained a replica "
                             f"(no 'up' event within {args.as_timeout}s)")
        if not retired:
            return section, (f"cold expert {cold} never retired its idle "
                             f"replica (no 'down' event within "
                             f"{args.as_timeout}s)")
        if bad_ring:
            return section, (f"pressure-phase token mismatch on "
                             f"{bad_ring[:8]}")
        if bad:
            return section, f"autoscaled-run token mismatch on {bad[:8]}"
        if p99_a >= p99_s:
            return section, (f"autoscaling did not improve hot-expert "
                             f"p99 TTFT ({p99_a}ms >= {p99_s}ms)")
        return section, None
    finally:
        if fleet is not None:
            fleet.close()


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--experts", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--shared-prefix-len", type=int, default=-1,
                    help="leading tokens every prompt shares (the prefix-"
                         "sharing workload; -1 = prompt_len // 2, 0 = "
                         "fully distinct prompts)")
    ap.add_argument("--min-new", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=32)
    servecli.add_engine_args(ap)
    servecli.add_autoscale_args(ap)
    servecli.add_sampling_args(ap, temperature=0.8, top_k=32, top_p=0.95)
    ap.add_argument("--as-pace-ms", type=float, default=10.0,
                    help="autoscale pressure phase: wall milliseconds per "
                         "engine tick, so spawned replicas get real time "
                         "to warm off-path")
    ap.add_argument("--as-timeout", type=float, default=300.0,
                    help="autoscale pressure phase: seconds to wait for "
                         "the scale-up + scale-down events before failing")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mode", choices=["greedy", "sampled"], default="greedy",
                    help="sampled: temperature/top-k/top-p decoding plus a "
                         "random stop-token set (early-stop workload)")
    ap.add_argument("--open-loop", action="store_true",
                    help="also run the skewed open-loop latency workload "
                         "(Poisson arrivals, Zipf expert mix, p50/p99 TTFT "
                         "and inter-token latency per expert)")
    ap.add_argument("--arrival-rate", type=float, default=0.5,
                    help="open-loop Poisson arrival rate, requests per "
                         "engine tick")
    ap.add_argument("--zipf-a", type=float, default=1.5,
                    help="Zipf exponent of the open-loop expert mix "
                         "(higher = more skew onto the hot expert)")
    ap.add_argument("--ol-requests", type=int, default=32,
                    help="open-loop workload size (smoke clamps to 16)")
    ap.add_argument("--hot-replicas", type=int, default=1,
                    help="> 1: re-run the open-loop workload with this many "
                         "replicas of the hot expert and hard-fail unless "
                         "its p99 TTFT strictly improves")
    ap.add_argument("--n-stops", type=int, default=-1,
                    help="random stop-token ids shared by all requests "
                         "(-1: vocab/16 in sampled mode, 0 in greedy)")
    ap.add_argument("--json", default=None, help="write results to this file")
    ap.add_argument("--trajectory", default=None,
                    help="append a one-line JSONL perf record (tokens/sec, "
                         "decode read bytes, prefill write bytes) to this "
                         "file on success — CI points it at "
                         "benchmarks/TRAJECTORY.jsonl so the perf "
                         "trajectory accumulates across PRs")
    ap.add_argument("--smoke", action="store_true",
                    help="small CI workload: identity gates (greedy pool "
                         "pressure, admission budget, sampled early-stop), "
                         "no speedup exit check")
    ap.add_argument("--no-check", action="store_true",
                    help="skip the engine-beats-baseline exit check")
    return ap


def main() -> int:
    args = build_parser().parse_args()
    if args.smoke:
        ecfg, rcfg = SMOKE_EXPERT, SMOKE_ROUTER
        args.requests = min(args.requests, 10)
        args.lanes = min(args.lanes, 2)
        args.max_new = min(args.max_new, 16)
        args.ol_requests = min(args.ol_requests, 16)
        if args.blocks_per_expert == 0:   # force block reuse under pressure
            total = args.prompt_len + args.max_new
            args.blocks_per_expert = -(-total // args.block_size) + 1
    else:
        ecfg, rcfg = EXPERT, ROUTER
    assert args.requests >= 8 and args.experts >= 2, "workload too small"
    max_len = -(-(args.prompt_len + args.max_new) // args.block_size) \
        * args.block_size                 # round lane budget up to blocks

    fleet = None
    if args.transport == "tcp" and not args.registry:
        # no --registry given: boot a local fleet through the real module
        # CLIs (one registry + one expert_worker process per expert); the
        # workers re-derive their params from --seed exactly like build().
        # The spec config carries the engine *shape*; its transport field
        # is neutralized because workers are servers, not tcp clients.
        from repro.serving.net.fleet import LocalFleet
        spec_cfg = dataclasses.replace(
            servecli.engine_config_from_args(
                args, max_len=max_len, prefix_len=args.prompt_len,
                min_prefill_bucket=args.prompt_len),
            transport="loopback", registry="")
        fleet = LocalFleet(ecfg, spec_cfg, args.experts, seed=args.seed,
                           warmup_len=args.prompt_len)
        args.registry = fleet.registry_addr
        print(f"local worker fleet up: registry {fleet.registry_addr}, "
              f"{args.experts} expert workers")
    try:
        return run_bench(args, ecfg, rcfg, max_len)
    finally:
        if fleet is not None:
            fleet.close()


def run_bench(args, ecfg, rcfg, max_len: int) -> int:
    expert_params, router_params = build(ecfg, rcfg, args.experts, args.seed)
    corpus = SyntheticCorpus(DataConfig(vocab_size=ecfg.vocab_size,
                                        seq_len=args.prompt_len,
                                        n_domains=args.experts))
    prompts, _ = corpus.sequences(np.arange(args.requests) + 555_000)
    # shared-prefix workload: every prompt opens with the same "system
    # prompt" tokens, so once one request per expert has prefilled them
    # the radix cache serves the leading blocks to every later admission
    shared_len = (args.prompt_len // 2 if args.shared_prefix_len < 0
                  else args.shared_prefix_len)
    if shared_len:
        prompts = prompts.copy()
        prompts[:, :shared_len] = prompts[0, :shared_len]
    rng = np.random.default_rng(args.seed)
    n_new = rng.integers(args.min_new, args.max_new + 1, size=args.requests)
    prefix_len = args.prompt_len

    # ---- generation recipe (shared by both paths) -------------------------
    sampling = SamplingParams(
        temperature=args.temperature, top_k=args.top_k, top_p=args.top_p,
        seed=args.sample_seed) if args.mode == "sampled" else SamplingParams()
    n_stops = args.n_stops if args.n_stops >= 0 else (
        ecfg.vocab_size // 16 if args.mode == "sampled" else 0)
    stop_tokens = frozenset(
        int(t) for t in rng.choice(ecfg.vocab_size, size=n_stops,
                                   replace=False)) if n_stops else frozenset()

    # ---- baseline: old serial per-group path -----------------------------
    # warm every shape the timed run will hit (per-group prefill + decode
    # + the per-group-width sampler when sampling)
    eids = baseline.route(rcfg, router_params, prompts, prefix_len)
    for e in np.unique(eids):
        n_group = int((eids == e).sum())
        baseline.generate(ecfg, expert_params[int(e)],
                          jnp.asarray(prompts[:n_group]), 2,
                          cache_len=max_len, sampling=sampling,
                          uids=np.arange(n_group))
    serial = baseline.serve_serial(ecfg, rcfg, expert_params,
                                   router_params, prompts, n_new,
                                   prefix_len=prefix_len, cache_len=max_len,
                                   sampling=sampling, stop_tokens=stop_tokens)

    # ---- engine: continuous batching over the paged pool ------------------
    # context managers cover every early-failure return below: worker
    # processes (process transport) are released on all exit paths.
    # uid_namespace=0 pins engine uids to 0..N-1 — the serial oracle's —
    # so sampled tokens (a pure function of (seed, uid, step)) stay
    # bitwise comparable even on tcp, where a frontend would otherwise
    # lease a namespace from the registry.
    eng_cfg = servecli.engine_config_from_args(
        args, max_len=max_len, prefix_len=prefix_len,
        min_prefill_bucket=args.prompt_len)
    with ServeFrontend(ecfg, rcfg, expert_params, router_params, eng_cfg,
                       replicas=args.replicas, uid_namespace=0) as eng:
        # warmup: compile every admission batch width the timed run can
        # hit (routing-independent — see ServeFrontend.warmup);
        # greedy mode skips the sampled warmup pass it would never use
        eng.warmup(args.prompt_len, sampled=args.mode == "sampled")
        timed = [eng.submit(prompts[i], int(n_new[i]), sampling=sampling,
                            stop_tokens=stop_tokens, arrival_tick=eng.tick)
                 for i in range(args.requests)]  # timed: all arrive at once
        uid0 = timed[0].uid
        res = eng.run()
        pool_blocks = eng.pool_blocks

    # ---- identity + report ------------------------------------------------
    mismatches = []
    for r in res["requests"]:
        i = r.uid - uid0
        if r.expert != serial["routes"][i] or \
                not np.array_equal(np.asarray(r.tokens), serial["tokens"][i]):
            mismatches.append(i)
    speedup = res["tokens_per_s"] / serial["tokens_per_s"]
    dense = dense_slab_bytes(ecfg, args.lanes, max_len)
    report = {
        # v7 (PR 10): prefill_impl + prefill_write_bytes (fused paged
        # prefill vs the dense slab+scatter it replaced — fused must be
        # strictly below slab) and epilogue_logits_bytes (the decode
        # epilogue's HBM logits traffic; 0 on the fused Pallas
        # epilogue); v6 (PR 9): the autoscale section — live replica scaling under
        # the open-loop Zipf workload, gated on a mid-serve hot-expert
        # scale-up, an idle cold-expert scale-down, hot p99 TTFT
        # strictly improving vs static, and bitwise token identity; v5
        # (PR 8): "transport" may now be "tcp" (registry-discovered
        # network worker fleet) and the two_frontend section gates two
        # replicated stateless frontends on one fleet; v4 (PR 7) added
        # the prefix_sharing section (hit blocks, prefill tokens saved,
        # cached blocks), n_unadmitted, and the shared-prefix workload
        # knobs; v3 (PR 6) added open_loop + per-replica breakdowns; v2
        # (PR 5) added "transport" + per-expert queue_wait_ticks /
        # occupancy; compare_bench.py accepts a newer fresh report
        # against an older baseline (added keys only)
        "schema": "BENCH_serve/v7",
        "mode": args.mode,
        "transport": args.transport,
        "workload": {"requests": args.requests, "experts": args.experts,
                     "lanes": args.lanes, "prompt_len": args.prompt_len,
                     "shared_prefix_len": shared_len,
                     "prefill_chunk_tokens": args.prefill_chunk_tokens,
                     "max_len": max_len,
                     "new_tokens": [int(x) for x in n_new],
                     "sampling": {"temperature": sampling.temperature,
                                  "top_k": sampling.top_k,
                                  "top_p": sampling.top_p,
                                  "seed": sampling.seed},
                     "n_stop_tokens": len(stop_tokens)},
        "serial": {"wall_s": round(serial["wall_s"], 3),
                   "tokens_per_s": round(serial["tokens_per_s"], 1),
                   "useful_tokens": serial["useful_tokens"],
                   "wasted_tokens": serial["wasted_tokens"]},
        "engine": {"wall_s": round(res["wall_s"], 3),
                   "tokens_per_s": round(res["tokens_per_s"], 1),
                   "useful_tokens": res["useful_tokens"],
                   "early_stops": res["early_stops"],
                   "occupancy": round(res["occupancy"], 3),
                   "ticks": res["ticks"],
                   "prefill_calls": res["prefill_calls"],
                   "per_expert": {
                       e: {"served": s["served"],
                           "prefills": s["prefills"],
                           "queue_wait_ticks": s["queue_wait_ticks"],
                           "occupancy": round(s["occupancy"], 3),
                           "replicas": s["replicas"],
                           "per_replica": {
                               rr: {"served": pr["served"],
                                    "queue_wait_ticks":
                                        pr["queue_wait_ticks"],
                                    "occupancy": round(pr["occupancy"], 3)}
                               for rr, pr in s["per_replica"].items()}}
                       for e, s in res["per_expert"].items()}},
        "paged_kv": {"block_size": args.block_size,
                     "pool_blocks_per_expert": pool_blocks,
                     "peak_blocks": {e: s["peak_blocks"] for e, s in
                                     res["per_expert"].items()},
                     "hbm_bytes_per_lane": res["kv_bytes_per_lane"],
                     "dense_slab_bytes_per_lane": dense // args.lanes},
        "prefix_sharing": res["prefix_sharing"],
        "n_unadmitted": res["n_unadmitted"],
        "decode_impl": res["decode_impl"],
        "decode_read_bytes_per_tick": {
            # what the paged kernel reads (live blocks only) vs the
            # gathered (lanes, max_len) view the old decode materialized
            "paged": res["decode_read_bytes"]["paged_per_tick"],
            "gathered": res["decode_read_bytes"]["gathered_per_tick"],
        },
        "prefill_impl": res["prefill_impl"],
        "prefill_write_bytes": {
            # what the fused paged prefill writes (bucketed K/V straight
            # into pool blocks + the block-span pos rewrite) vs the dense
            # slab+scatter path (slab K/V out of prefill, then read back
            # and scattered by insert_requests) — both priced on every
            # admission regardless of which path ran
            "fused": res["prefill_write_bytes"]["fused"],
            "slab": res["prefill_write_bytes"]["slab"],
            "fused_per_prefill": res["prefill_write_bytes"]
                                    ["fused_per_prefill"],
            "slab_per_prefill": res["prefill_write_bytes"]
                                   ["slab_per_prefill"],
        },
        # (lanes, vocab) logits buffers the decode epilogue materialized
        # in HBM; 0 when the Pallas epilogue samples in-kernel
        "epilogue_logits_bytes": res["epilogue_logits_bytes"],
        "speedup": round(speedup, 2),
        "tokens_identical": not mismatches,
    }
    def emit(code: int) -> int:
        """Print/persist the report (CI keeps it as BENCH_serve.json)."""
        print(json.dumps(report, indent=1))
        if args.json:
            with open(args.json, "w") as f:
                json.dump(report, f, indent=1)
        if args.trajectory and code == 0:
            # one compact perf row per green run: the numbers the repo
            # tracks across PRs, appended so history accumulates
            row = {"ts": round(time.time(), 1),
                   "schema": report["schema"],
                   "mode": args.mode,
                   "transport": args.transport,
                   "smoke": bool(args.smoke),
                   "decode_impl": report["decode_impl"],
                   "prefill_impl": report["prefill_impl"],
                   "tokens_per_s": report["engine"]["tokens_per_s"],
                   "speedup": report["speedup"],
                   "decode_read_bytes_per_tick":
                       report["decode_read_bytes_per_tick"]["paged"],
                   "prefill_write_bytes_per_prefill":
                       report["prefill_write_bytes"]["fused_per_prefill"]
                       if report["prefill_impl"] != "slab"
                       else report["prefill_write_bytes"]
                                  ["slab_per_prefill"],
                   "epilogue_logits_bytes":
                       report["epilogue_logits_bytes"]}
            with open(args.trajectory, "a") as f:
                f.write(json.dumps(row) + "\n")
        return code

    if mismatches:
        print(f"FAIL: token mismatch on requests {mismatches[:8]}")
        return emit(1)
    print(f"engine {res['tokens_per_s']:.1f} tok/s vs serial "
          f"{serial['tokens_per_s']:.1f} tok/s -> {speedup:.2f}x "
          f"({serial['wasted_tokens']} wasted baseline tokens reclaimed, "
          f"{res['early_stops']} early stops); "
          f"KV {res['kv_bytes_per_lane']} B/lane vs dense "
          f"{dense // args.lanes} B/lane, "
          f"{res['prefill_calls']} prefill calls for {args.requests} requests")
    rb = res["decode_read_bytes"]
    print(f"decode KV reads ({res['decode_impl']}): paged "
          f"{rb['paged_per_tick']} B/tick vs gathered "
          f"{rb['gathered_per_tick']} B/tick "
          f"({rb['paged'] / max(rb['gathered'], 1):.2f}x)")
    if rb["paged"] >= rb["gathered"]:
        print("FAIL: paged decode reads did not beat the gathered "
              "(lanes, max_len) view")
        return emit(1)
    wb = res["prefill_write_bytes"]
    print(f"admission KV writes ({res['prefill_impl']}): fused "
          f"{wb['fused_per_prefill']} B/prefill vs slab+scatter "
          f"{wb['slab_per_prefill']} B/prefill "
          f"({wb['fused'] / max(wb['slab'], 1):.2f}x); decode epilogue "
          f"logits traffic {res['epilogue_logits_bytes']} B "
          f"({res['decode_impl']} epilogue)")
    if wb["slab"] and wb["fused"] >= wb["slab"]:
        print("FAIL: fused paged prefill writes did not beat the dense "
              "slab+scatter path")
        return emit(1)
    ps = report["prefix_sharing"]
    print(f"prefix sharing: {'on' if ps['enabled'] else 'off'}, "
          f"{shared_len}-token shared prompt head, {ps['hit_blocks']} hit "
          f"blocks, {ps['prefill_tokens_saved']} prefill tokens saved, "
          f"{report['n_unadmitted']} never admitted")
    if ps["enabled"] and shared_len >= args.block_size and \
            ps["prefill_tokens_saved"] <= 0:
        # staggered admissions over a shared prompt head MUST hit the
        # radix cache; zero savings means sharing silently broke
        print("FAIL: shared-prefix workload saved no prefill tokens")
        return emit(1)

    # ---- two stateless frontends sharing one tcp worker fleet -------------
    if args.transport == "tcp":
        # each frontend leases its own uid namespace from the registry,
        # the workload splits even/odd across them, and they decode
        # interleaved against the same workers: any uid collision or
        # token deviation is cross-frontend stream corruption.  Greedy
        # submissions, so tokens are uid-independent and the serial
        # reference covers both halves regardless of namespace.
        ref = serial if args.mode == "greedy" else baseline.serve_serial(
            ecfg, rcfg, expert_params, router_params, prompts, n_new,
            prefix_len=prefix_len, cache_len=max_len)
        with ServeFrontend(ecfg, rcfg, expert_params, router_params,
                           eng_cfg) as fa, \
                ServeFrontend(ecfg, rcfg, expert_params, router_params,
                              eng_cfg) as fb:
            fa.warmup(args.prompt_len, sampled=False)
            ra = [(i, fa.submit(prompts[i], int(n_new[i]),
                                arrival_tick=fa.tick))
                  for i in range(0, args.requests, 2)]
            rb = [(i, fb.submit(prompts[i], int(n_new[i]),
                                arrival_tick=fb.tick))
                  for i in range(1, args.requests, 2)]
            while fa.busy or fb.busy:
                if fa.busy:
                    fa.step()
                if fb.busy:
                    fb.step()
            spaces = [fa.uid_namespace, fb.uid_namespace]
        uids_a = {r.uid for _, r in ra}
        uids_b = {r.uid for _, r in rb}
        bad2f = [i for i, r in ra + rb
                 if r.expert != ref["routes"][i]
                 or not np.array_equal(np.asarray(r.tokens),
                                       ref["tokens"][i])]
        report["two_frontend"] = {
            "namespaces": spaces,
            "uids_disjoint": not (uids_a & uids_b),
            "tokens_identical": not bad2f,
        }
        print(f"two frontends, one fleet: namespaces {spaces}, "
              f"{len(ra)}+{len(rb)} requests interleaved, uids disjoint: "
              f"{not (uids_a & uids_b)}, tokens identical: {not bad2f}")
        if uids_a & uids_b:
            print(f"FAIL: cross-frontend uid collision on "
                  f"{sorted(uids_a & uids_b)[:8]}")
            return emit(1)
        if bad2f:
            print(f"FAIL: two-frontend token mismatch on requests "
                  f"{bad2f[:8]}")
            return emit(1)

    # ---- open-loop skewed latency workload --------------------------------
    if args.open_loop and args.transport == "tcp":
        # the open-loop runs re-shape the KV pool (full pool) and the
        # replica set per run, but a tcp fleet is booted once with fixed
        # workers — replication latency is measured on the in-process
        # transports
        print("note: open-loop latency workload skipped on --transport "
              "tcp (pool shape and replica set are fixed at worker boot)")
    elif args.open_loop:
        ol_rng = np.random.default_rng(args.seed + 1)
        ol_prompts, ol_new, ol_arrivals, hot = open_loop_workload(
            rcfg, router_params, corpus, args, ol_rng)
        # one serial oracle for both runs: tokens are replica-placement-
        # invariant, so single and replicated must both match it bitwise
        serial_ol = baseline.serve_serial(
            ecfg, rcfg, expert_params, router_params, ol_prompts, ol_new,
            prefix_len=prefix_len, cache_len=max_len, sampling=sampling)
        single, bad_ol = open_loop_run(
            ecfg, rcfg, expert_params, router_params, args, max_len,
            ol_prompts, ol_new, ol_arrivals, sampling, serial_ol,
            replicas=None)
        report["open_loop"] = {
            "arrival_rate": args.arrival_rate, "zipf_a": args.zipf_a,
            "requests": int(args.ol_requests), "hot_expert": hot,
            "hot_replicas": args.hot_replicas, "single": single}
        if bad_ol:
            print(f"FAIL: open-loop token mismatch (1 server/expert) on "
                  f"requests {bad_ol[:8]}")
            return emit(1)
        print(f"open-loop ({args.ol_requests} reqs, rate "
              f"{args.arrival_rate}/tick, zipf {args.zipf_a}): hot expert "
              f"{hot} served {single['per_expert'][hot]['served']}, "
              f"p99 TTFT {single['per_expert'][hot]['ttft_p99_ms']}ms, "
              f"p99 ITL {single['per_expert'][hot]['itl_p99_ms']}ms")
        if args.hot_replicas > 1:
            repl, bad_ol = open_loop_run(
                ecfg, rcfg, expert_params, router_params, args, max_len,
                ol_prompts, ol_new, ol_arrivals, sampling, serial_ol,
                replicas={hot: args.hot_replicas})
            report["open_loop"]["replicated"] = repl
            if bad_ol:
                print(f"FAIL: open-loop token mismatch "
                      f"({args.hot_replicas} replicas of expert {hot}) on "
                      f"requests {bad_ol[:8]}")
                return emit(1)
            p99_1 = single["per_expert"][hot]["ttft_p99_ms"]
            p99_r = repl["per_expert"][hot]["ttft_p99_ms"]
            improved = p99_r < p99_1
            report["open_loop"]["p99_ttft_improved"] = improved
            print(f"open-loop hot expert {hot} p99 TTFT: {p99_1}ms (1 "
                  f"server) -> {p99_r}ms ({args.hot_replicas} replicas, "
                  f"least-loaded admission), tokens identical both runs")
            if not improved:
                print(f"FAIL: {args.hot_replicas} replicas did not improve "
                      f"hot-expert p99 TTFT ({p99_r}ms >= {p99_1}ms)")
                return emit(1)
    # ---- live autoscaling: grow/shrink the replica map mid-serve ----------
    if args.autoscale:
        section, fail = run_autoscale(args, ecfg, rcfg, expert_params,
                                      router_params, corpus, max_len)
        report["autoscale"] = section
        if fail:
            print(f"FAIL: {fail}")
            return emit(1)

    if args.smoke:
        if args.transport == "tcp":
            # the full-pool admission-budget engine needs pool_blocks=0,
            # but a tcp fleet's pool shape is fixed at worker boot — the
            # bound is pool-shape-dependent, not transport-dependent, and
            # CI pins it on the in-process transports
            print("note: full-pool admission-budget gate skipped on "
                  "--transport tcp (pool shape is fixed at worker boot)")
            budget = "budget gate pinned on in-process transports"
        else:
            # the pressured pool above serializes admission, so the
            # batching bound needs a second, full-pool engine: k_e
            # simultaneous arrivals per expert must cost <=
            # ceil(k_e / lanes) prefills
            with ServeFrontend(ecfg, rcfg, expert_params, router_params,
                               dataclasses.replace(eng_cfg, pool_blocks=0),
                               uid_namespace=0) as eng2:
                eng2.warmup(args.prompt_len, sampled=False)
                # uniform budget: lanes then free together, so admission
                # drains `lanes` requests per prefill and the ceil bound
                # is tight (greedy, no stops: the budget must stay tight,
                # so the reference is its own greedy serial run,
                # independent of --mode)
                uniform = args.min_new
                ref2 = baseline.serve_serial(
                    ecfg, rcfg, expert_params, router_params, prompts,
                    np.full(args.requests, uniform), prefix_len=prefix_len,
                    cache_len=max_len)
                reqs = [eng2.submit(prompts[i], uniform,
                                    arrival_tick=eng2.tick)
                        for i in range(args.requests)]
                res2 = eng2.run()
            # per-expert stats come from the run report (StatsMsg across
            # the transport), so this gate holds for process-backed
            # experts too
            for e, st in res2["per_expert"].items():
                k_e = sum(1 for r in reqs if r.expert == e)
                if st["prefills"] > -(-k_e // args.lanes):
                    print(f"FAIL: expert {e} took {st['prefills']} prefill "
                          f"calls for {k_e} simultaneous arrivals "
                          f"(bound ceil(k/lanes) = {-(-k_e // args.lanes)})")
                    return emit(1)
            if any(not np.array_equal(np.asarray(r.tokens),
                                      ref2["tokens"][i])
                   for i, r in enumerate(reqs)):
                print("FAIL: full-pool token mismatch")
                return emit(1)
            budget = (f"{res2['prefill_calls']} prefills for "
                      f"{args.requests} requests")

        # sampled + early-stop gate: same pressured pool, random stop set;
        # engine must stay token-identical to the serial sampler AND
        # reclaim lanes/blocks at stop tokens
        sp = SamplingParams(temperature=args.temperature, top_k=args.top_k,
                            top_p=args.top_p, seed=args.sample_seed)
        stops3 = frozenset(int(t) for t in rng.choice(
            ecfg.vocab_size, size=max(ecfg.vocab_size // 16, 4),
            replace=False))
        serial3 = baseline.serve_serial(
            ecfg, rcfg, expert_params, router_params, prompts, n_new,
            prefix_len=prefix_len, cache_len=max_len, sampling=sp,
            stop_tokens=stops3)
        with ServeFrontend(ecfg, rcfg, expert_params, router_params,
                           eng_cfg, uid_namespace=0) as eng3:
            eng3.warmup(args.prompt_len)
            reqs3 = [eng3.submit(prompts[i], int(n_new[i]), sampling=sp,
                                 stop_tokens=stops3, arrival_tick=eng3.tick)
                     for i in range(args.requests)]
            res3 = eng3.run()
        bad3 = [i for i, r in enumerate(reqs3)
                if not np.array_equal(np.asarray(r.tokens),
                                      serial3["tokens"][i])]
        report["smoke_sampled"] = {
            "sampling": {"temperature": sp.temperature, "top_k": sp.top_k,
                         "top_p": sp.top_p, "seed": sp.seed},
            "n_stop_tokens": len(stops3),
            "early_stops": res3["early_stops"],
            "useful_tokens": res3["useful_tokens"],
            "tokens_identical": not bad3,
        }
        if bad3:
            print(f"FAIL: sampled-mode token mismatch on requests {bad3[:8]}")
            return emit(1)
        print("smoke OK: token identity under pool pressure, batched "
              f"admission ({budget}), sampled+early-stop identity "
              f"({res3['early_stops']} early stops)")
        return emit(0)
    if not args.no_check and speedup <= 1.0:
        print("FAIL: engine did not beat the serial baseline")
        return emit(1)
    return emit(0)


if __name__ == "__main__":
    sys.exit(main())
