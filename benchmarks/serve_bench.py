"""Serving bench: continuous-batching engine vs the old serial path.

Workload: a mixed-length batch (equal prompt lengths — the old path cannot
mix them — but per-request completion budgets spread over [min,max]) routed
across >= 2 experts.  The baseline serves each expert group serially and
decodes every request to the group maximum; the engine keeps a fixed
number of decode lanes per expert full, admitting queued requests in
batched prefills as lanes free up, with full-attention KV in the paged
block pool.  Both paths must produce byte-identical tokens — greedy by
default, or ``--mode sampled`` for a temperature/top-k/top-p workload
with a shared stop-token set (early stops free engine lanes mid-flight,
while the serial path still decodes each group to its maximum and throws
the surplus away — exactly the waste continuous batching reclaims).  The
bench asserts identity, then compares useful-token throughput and
reports the paged-cache memory footprint (HBM bytes per lane vs the
dense ``lanes * max_len`` slab), the admission prefill-call count, and
the decode read traffic: bytes/tick the paged-attention kernel reads
(live blocks only; ``--decode-impl pallas`` selects the Pallas kernel,
interpret-mode on CPU) vs the gathered ``(lanes, max_len)`` view the
old decode materialized — the former must be strictly smaller or the
bench fails.

Both paths are warmed first (same shapes as the timed run) so jit compile
time is excluded.  The model is sized so per-step compute, not dispatch
overhead, dominates — wasted lane-tokens then cost real wall time.

  PYTHONPATH=src python benchmarks/serve_bench.py
  PYTHONPATH=src python benchmarks/serve_bench.py --mode sampled
  PYTHONPATH=src python benchmarks/serve_bench.py --smoke \
      --json BENCH_serve.json                             # CI gate

``--transport process`` runs every expert in its own spawned OS process
(the multi-host story proven on one machine: pickled request/token
messages over pipes are the only cross-expert traffic) — the identity
gates must hold there exactly as on the in-process loopback default.

``--smoke`` shrinks the models/workload so the token-identity gates
(greedy under pool pressure, batched-admission prefill budget, AND a
sampled + early-stop gate) run in CI on every push; the speedup exit
check is skipped there because tiny models are dispatch-bound.  The
``--json`` report follows the ``BENCH_serve/v2`` schema (v1 + transport
and per-expert queue-wait/occupancy stats), persisted as a CI artifact
so the perf trajectory accumulates.
"""
from __future__ import annotations

import argparse
import json
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import router as routerlib
from repro.data import DataConfig, SyntheticCorpus
from repro.models import model as modellib
from repro.serving import (EngineConfig, MixtureServeEngine, SamplingParams,
                           baseline)
from repro.serving import cache as cachelib

EXPERT = ModelConfig(name="bench-expert", n_layers=4, d_model=256, n_heads=8,
                     n_kv_heads=8, d_ff=1024, vocab_size=512,
                     ffn_type="gelu", loss_chunk=128,
                     compute_dtype="float32", param_dtype="float32")
ROUTER = ModelConfig(name="bench-router", n_layers=1, d_model=64, n_heads=4,
                     n_kv_heads=4, d_ff=256, vocab_size=512,
                     ffn_type="gelu", loss_chunk=128,
                     compute_dtype="float32", param_dtype="float32")
SMOKE_EXPERT = EXPERT.replace(name="smoke-expert", n_layers=2, d_model=64,
                              n_heads=4, n_kv_heads=4, d_ff=128,
                              vocab_size=128, loss_chunk=32)
SMOKE_ROUTER = ROUTER.replace(name="smoke-router", d_model=32, n_heads=2,
                              n_kv_heads=2, d_ff=64, vocab_size=128,
                              loss_chunk=32)


def build(ecfg, rcfg, n_experts: int, seed: int):
    key = jax.random.PRNGKey(seed)
    router_params = routerlib.init_ensemble(key, rcfg, n_experts)
    expert_params = [modellib.init_params(jax.random.fold_in(key, e), ecfg)
                     for e in range(n_experts)]
    return expert_params, router_params


def dense_slab_bytes(ecfg, lanes: int, max_len: int) -> int:
    """Bytes the replaced dense (lanes, max_len) per-lane layout would hold."""
    return cachelib.kv_cache_bytes(modellib.cache_specs(ecfg, lanes, max_len))


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--experts", type=int, default=2)
    ap.add_argument("--lanes", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--min-new", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--block-size", type=int, default=16,
                    help="tokens per paged KV block")
    ap.add_argument("--blocks-per-expert", type=int, default=0,
                    help="KV pool blocks per expert "
                         "(0 = lanes*max_len/block_size, i.e. no pressure)")
    ap.add_argument("--decode-impl", choices=["auto", "jnp", "pallas"],
                    default="auto",
                    help="paged decode attention: jnp gather reference or "
                         "the Pallas block-table kernel (interpret-mode on "
                         "CPU; auto follows the expert config)")
    ap.add_argument("--transport", choices=["loopback", "process"],
                    default="loopback",
                    help="expert backend: in-process loopback or one "
                         "spawned OS process per expert (router scores the "
                         "only cross-process traffic)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mode", choices=["greedy", "sampled"], default="greedy",
                    help="sampled: temperature/top-k/top-p decoding plus a "
                         "random stop-token set (early-stop workload)")
    ap.add_argument("--temperature", type=float, default=0.8,
                    help="sampled-mode temperature")
    ap.add_argument("--top-k", type=int, default=32)
    ap.add_argument("--top-p", type=float, default=0.95)
    ap.add_argument("--sample-seed", type=int, default=0)
    ap.add_argument("--n-stops", type=int, default=-1,
                    help="random stop-token ids shared by all requests "
                         "(-1: vocab/16 in sampled mode, 0 in greedy)")
    ap.add_argument("--json", default=None, help="write results to this file")
    ap.add_argument("--smoke", action="store_true",
                    help="small CI workload: identity gates (greedy pool "
                         "pressure, admission budget, sampled early-stop), "
                         "no speedup exit check")
    ap.add_argument("--no-check", action="store_true",
                    help="skip the engine-beats-baseline exit check")
    args = ap.parse_args()
    if args.smoke:
        ecfg, rcfg = SMOKE_EXPERT, SMOKE_ROUTER
        args.requests = min(args.requests, 10)
        args.lanes = min(args.lanes, 2)
        args.max_new = min(args.max_new, 16)
        if args.blocks_per_expert == 0:   # force block reuse under pressure
            total = args.prompt_len + args.max_new
            args.blocks_per_expert = -(-total // args.block_size) + 1
    else:
        ecfg, rcfg = EXPERT, ROUTER
    assert args.requests >= 8 and args.experts >= 2, "workload too small"

    expert_params, router_params = build(ecfg, rcfg, args.experts, args.seed)
    corpus = SyntheticCorpus(DataConfig(vocab_size=ecfg.vocab_size,
                                        seq_len=args.prompt_len,
                                        n_domains=args.experts))
    prompts, _ = corpus.sequences(np.arange(args.requests) + 555_000)
    rng = np.random.default_rng(args.seed)
    n_new = rng.integers(args.min_new, args.max_new + 1, size=args.requests)
    max_len = -(-(args.prompt_len + args.max_new) // args.block_size) \
        * args.block_size                 # round lane budget up to blocks
    prefix_len = args.prompt_len

    # ---- generation recipe (shared by both paths) -------------------------
    sampling = SamplingParams(
        temperature=args.temperature, top_k=args.top_k, top_p=args.top_p,
        seed=args.sample_seed) if args.mode == "sampled" else SamplingParams()
    n_stops = args.n_stops if args.n_stops >= 0 else (
        ecfg.vocab_size // 16 if args.mode == "sampled" else 0)
    stop_tokens = frozenset(
        int(t) for t in rng.choice(ecfg.vocab_size, size=n_stops,
                                   replace=False)) if n_stops else frozenset()

    # ---- baseline: old serial per-group path -----------------------------
    # warm every shape the timed run will hit (per-group prefill + decode
    # + the per-group-width sampler when sampling)
    eids = baseline.route(rcfg, router_params, prompts, prefix_len)
    for e in np.unique(eids):
        n_group = int((eids == e).sum())
        baseline.generate(ecfg, expert_params[int(e)],
                          jnp.asarray(prompts[:n_group]), 2,
                          cache_len=max_len, sampling=sampling,
                          uids=np.arange(n_group))
    serial = baseline.serve_serial(ecfg, rcfg, expert_params,
                                   router_params, prompts, n_new,
                                   prefix_len=prefix_len, cache_len=max_len,
                                   sampling=sampling, stop_tokens=stop_tokens)

    # ---- engine: continuous batching over the paged pool ------------------
    # context managers cover every early-failure return below: worker
    # processes (process transport) are released on all exit paths
    with MixtureServeEngine(
            ecfg, rcfg, expert_params, router_params,
            EngineConfig(lanes_per_expert=args.lanes, max_len=max_len,
                         prefix_len=prefix_len,
                         min_prefill_bucket=args.prompt_len,
                         block_size=args.block_size,
                         pool_blocks=args.blocks_per_expert,
                         decode_impl=args.decode_impl,
                         transport=args.transport)) as eng:
        # warmup: compile every admission batch width the timed run can
        # hit (routing-independent — see MixtureServeEngine.warmup);
        # greedy mode skips the sampled warmup pass it would never use
        eng.warmup(args.prompt_len, sampled=args.mode == "sampled")
        timed = [eng.submit(prompts[i], int(n_new[i]), sampling=sampling,
                            stop_tokens=stop_tokens, arrival_tick=eng.tick)
                 for i in range(args.requests)]  # timed: all arrive at once
        uid0 = timed[0].uid
        res = eng.run()
        pool_blocks = eng.pool_blocks

    # ---- identity + report ------------------------------------------------
    mismatches = []
    for r in res["requests"]:
        i = r.uid - uid0
        if r.expert != serial["routes"][i] or \
                not np.array_equal(np.asarray(r.tokens), serial["tokens"][i]):
            mismatches.append(i)
    speedup = res["tokens_per_s"] / serial["tokens_per_s"]
    dense = dense_slab_bytes(ecfg, args.lanes, max_len)
    report = {
        # v2 (PR 5): adds "transport" + per-expert queue_wait_ticks /
        # occupancy under engine.per_expert; compare_bench.py accepts a
        # newer fresh report against an older baseline (added keys only)
        "schema": "BENCH_serve/v2",
        "mode": args.mode,
        "transport": args.transport,
        "workload": {"requests": args.requests, "experts": args.experts,
                     "lanes": args.lanes, "prompt_len": args.prompt_len,
                     "max_len": max_len,
                     "new_tokens": [int(x) for x in n_new],
                     "sampling": {"temperature": sampling.temperature,
                                  "top_k": sampling.top_k,
                                  "top_p": sampling.top_p,
                                  "seed": sampling.seed},
                     "n_stop_tokens": len(stop_tokens)},
        "serial": {"wall_s": round(serial["wall_s"], 3),
                   "tokens_per_s": round(serial["tokens_per_s"], 1),
                   "useful_tokens": serial["useful_tokens"],
                   "wasted_tokens": serial["wasted_tokens"]},
        "engine": {"wall_s": round(res["wall_s"], 3),
                   "tokens_per_s": round(res["tokens_per_s"], 1),
                   "useful_tokens": res["useful_tokens"],
                   "early_stops": res["early_stops"],
                   "occupancy": round(res["occupancy"], 3),
                   "ticks": res["ticks"],
                   "prefill_calls": res["prefill_calls"],
                   "per_expert": {
                       e: {"served": s["served"],
                           "prefills": s["prefills"],
                           "queue_wait_ticks": s["queue_wait_ticks"],
                           "occupancy": round(s["occupancy"], 3)}
                       for e, s in res["per_expert"].items()}},
        "paged_kv": {"block_size": args.block_size,
                     "pool_blocks_per_expert": pool_blocks,
                     "peak_blocks": {e: s["peak_blocks"] for e, s in
                                     res["per_expert"].items()},
                     "hbm_bytes_per_lane": res["kv_bytes_per_lane"],
                     "dense_slab_bytes_per_lane": dense // args.lanes},
        "decode_impl": res["decode_impl"],
        "decode_read_bytes_per_tick": {
            # what the paged kernel reads (live blocks only) vs the
            # gathered (lanes, max_len) view the old decode materialized
            "paged": res["decode_read_bytes"]["paged_per_tick"],
            "gathered": res["decode_read_bytes"]["gathered_per_tick"],
        },
        "speedup": round(speedup, 2),
        "tokens_identical": not mismatches,
    }
    def emit(code: int) -> int:
        """Print/persist the report (CI keeps it as BENCH_serve.json)."""
        print(json.dumps(report, indent=1))
        if args.json:
            with open(args.json, "w") as f:
                json.dump(report, f, indent=1)
        return code

    if mismatches:
        print(f"FAIL: token mismatch on requests {mismatches[:8]}")
        return emit(1)
    print(f"engine {res['tokens_per_s']:.1f} tok/s vs serial "
          f"{serial['tokens_per_s']:.1f} tok/s -> {speedup:.2f}x "
          f"({serial['wasted_tokens']} wasted baseline tokens reclaimed, "
          f"{res['early_stops']} early stops); "
          f"KV {res['kv_bytes_per_lane']} B/lane vs dense "
          f"{dense // args.lanes} B/lane, "
          f"{res['prefill_calls']} prefill calls for {args.requests} requests")
    rb = res["decode_read_bytes"]
    print(f"decode KV reads ({res['decode_impl']}): paged "
          f"{rb['paged_per_tick']} B/tick vs gathered "
          f"{rb['gathered_per_tick']} B/tick "
          f"({rb['paged'] / max(rb['gathered'], 1):.2f}x)")
    if rb["paged"] >= rb["gathered"]:
        print("FAIL: paged decode reads did not beat the gathered "
              "(lanes, max_len) view")
        return emit(1)
    if args.smoke:
        # the pressured pool above serializes admission, so the batching
        # bound needs a second, full-pool engine: k_e simultaneous
        # arrivals per expert must cost <= ceil(k_e / lanes) prefills
        with MixtureServeEngine(
                ecfg, rcfg, expert_params, router_params,
                EngineConfig(lanes_per_expert=args.lanes, max_len=max_len,
                             prefix_len=prefix_len,
                             min_prefill_bucket=args.prompt_len,
                             block_size=args.block_size,
                             decode_impl=args.decode_impl,
                             transport=args.transport)) as eng2:
            eng2.warmup(args.prompt_len, sampled=False)
            # uniform budget: lanes then free together, so admission
            # drains `lanes` requests per prefill and the ceil bound is
            # tight (greedy, no stops: the budget must stay tight, so the
            # reference is its own greedy serial run, independent of --mode)
            uniform = args.min_new
            ref2 = baseline.serve_serial(
                ecfg, rcfg, expert_params, router_params, prompts,
                np.full(args.requests, uniform), prefix_len=prefix_len,
                cache_len=max_len)
            reqs = [eng2.submit(prompts[i], uniform, arrival_tick=eng2.tick)
                    for i in range(args.requests)]
            res2 = eng2.run()
        # per-expert stats come from the run report (StatsMsg across the
        # transport), so this gate holds for process-backed experts too
        for e, st in res2["per_expert"].items():
            k_e = sum(1 for r in reqs if r.expert == e)
            if st["prefills"] > -(-k_e // args.lanes):
                print(f"FAIL: expert {e} took {st['prefills']} prefill "
                      f"calls for {k_e} simultaneous arrivals "
                      f"(bound ceil(k/lanes) = {-(-k_e // args.lanes)})")
                return emit(1)
        if any(not np.array_equal(np.asarray(r.tokens), ref2["tokens"][i])
               for i, r in enumerate(reqs)):
            print("FAIL: full-pool token mismatch")
            return emit(1)

        # sampled + early-stop gate: same pressured pool, random stop set;
        # engine must stay token-identical to the serial sampler AND
        # reclaim lanes/blocks at stop tokens
        sp = SamplingParams(temperature=args.temperature, top_k=args.top_k,
                            top_p=args.top_p, seed=args.sample_seed)
        stops3 = frozenset(int(t) for t in rng.choice(
            ecfg.vocab_size, size=max(ecfg.vocab_size // 16, 4),
            replace=False))
        serial3 = baseline.serve_serial(
            ecfg, rcfg, expert_params, router_params, prompts, n_new,
            prefix_len=prefix_len, cache_len=max_len, sampling=sp,
            stop_tokens=stops3)
        with MixtureServeEngine(
                ecfg, rcfg, expert_params, router_params,
                EngineConfig(lanes_per_expert=args.lanes, max_len=max_len,
                             prefix_len=prefix_len,
                             min_prefill_bucket=args.prompt_len,
                             block_size=args.block_size,
                             pool_blocks=args.blocks_per_expert,
                             decode_impl=args.decode_impl,
                             transport=args.transport)) as eng3:
            eng3.warmup(args.prompt_len)
            reqs3 = [eng3.submit(prompts[i], int(n_new[i]), sampling=sp,
                                 stop_tokens=stops3, arrival_tick=eng3.tick)
                     for i in range(args.requests)]
            res3 = eng3.run()
        bad3 = [i for i, r in enumerate(reqs3)
                if not np.array_equal(np.asarray(r.tokens),
                                      serial3["tokens"][i])]
        report["smoke_sampled"] = {
            "sampling": {"temperature": sp.temperature, "top_k": sp.top_k,
                         "top_p": sp.top_p, "seed": sp.seed},
            "n_stop_tokens": len(stops3),
            "early_stops": res3["early_stops"],
            "useful_tokens": res3["useful_tokens"],
            "tokens_identical": not bad3,
        }
        if bad3:
            print(f"FAIL: sampled-mode token mismatch on requests {bad3[:8]}")
            return emit(1)
        print("smoke OK: token identity under pool pressure, batched "
              f"admission within budget ({res2['prefill_calls']} prefills "
              f"for {args.requests} requests), sampled+early-stop identity "
              f"({res3['early_stops']} early stops)")
        return emit(0)
    if not args.no_check and speedup <= 1.0:
        print("FAIL: engine did not beat the serial baseline")
        return emit(1)
    return emit(0)


if __name__ == "__main__":
    sys.exit(main())
