"""Serving bench: continuous-batching engine vs the old serial path.

Workload: a mixed-length batch (equal prompt lengths — the old path cannot
mix them — but per-request completion budgets spread over [min,max]) routed
across >= 2 experts.  The baseline serves each expert group serially and
decodes every request to the group maximum; the engine keeps a fixed
number of decode lanes per expert full, admitting queued requests as
lanes free up.  Both paths are greedy and must produce byte-identical
tokens — the bench asserts that, then compares useful-token throughput.

Both paths are warmed first (same shapes as the timed run) so jit compile
time is excluded.  The model is sized so per-step compute, not dispatch
overhead, dominates — wasted lane-tokens then cost real wall time, which
is exactly what continuous batching reclaims.

  PYTHONPATH=src python benchmarks/serve_bench.py
"""
from __future__ import annotations

import argparse
import json
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import router as routerlib
from repro.data import DataConfig, SyntheticCorpus
from repro.models import model as modellib
from repro.serving import EngineConfig, MixtureServeEngine, baseline

EXPERT = ModelConfig(name="bench-expert", n_layers=4, d_model=256, n_heads=8,
                     n_kv_heads=8, d_ff=1024, vocab_size=512,
                     ffn_type="gelu", loss_chunk=128,
                     compute_dtype="float32", param_dtype="float32")
ROUTER = ModelConfig(name="bench-router", n_layers=1, d_model=64, n_heads=4,
                     n_kv_heads=4, d_ff=256, vocab_size=512,
                     ffn_type="gelu", loss_chunk=128,
                     compute_dtype="float32", param_dtype="float32")


def build(n_experts: int, seed: int):
    key = jax.random.PRNGKey(seed)
    router_params = routerlib.init_ensemble(key, ROUTER, n_experts)
    expert_params = [modellib.init_params(jax.random.fold_in(key, e), EXPERT)
                     for e in range(n_experts)]
    return expert_params, router_params


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--experts", type=int, default=2)
    ap.add_argument("--lanes", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--min-new", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default=None, help="write results to this file")
    ap.add_argument("--no-check", action="store_true",
                    help="skip the engine-beats-baseline exit check")
    args = ap.parse_args()
    assert args.requests >= 8 and args.experts >= 2, "workload too small"

    expert_params, router_params = build(args.experts, args.seed)
    corpus = SyntheticCorpus(DataConfig(vocab_size=EXPERT.vocab_size,
                                        seq_len=args.prompt_len,
                                        n_domains=args.experts))
    prompts, _ = corpus.sequences(np.arange(args.requests) + 555_000)
    rng = np.random.default_rng(args.seed)
    n_new = rng.integers(args.min_new, args.max_new + 1, size=args.requests)
    max_len = args.prompt_len + args.max_new
    prefix_len = args.prompt_len

    # ---- baseline: old serial per-group path -----------------------------
    # warm every shape the timed run will hit (per-group prefill + decode)
    eids = baseline.route(ROUTER, router_params, prompts, prefix_len)
    for e in np.unique(eids):
        n_group = int((eids == e).sum())
        baseline.generate(EXPERT, expert_params[int(e)],
                          jnp.asarray(prompts[:n_group]), 2,
                          cache_len=max_len)
    serial = baseline.serve_serial(EXPERT, ROUTER, expert_params,
                                   router_params, prompts, n_new,
                                   prefix_len=prefix_len, cache_len=max_len)

    # ---- engine: continuous batching -------------------------------------
    eng = MixtureServeEngine(
        EXPERT, ROUTER, expert_params, router_params,
        EngineConfig(lanes_per_expert=args.lanes, max_len=max_len,
                     prefix_len=prefix_len, min_prefill_bucket=args.prompt_len))
    for i in range(3):                       # warmup: compile all shapes
        eng.submit(prompts[i], 2, arrival_tick=0)
    eng.run()
    timed = [eng.submit(prompts[i], int(n_new[i]), arrival_tick=eng.tick)
             for i in range(args.requests)]  # timed: all arrive at once
    uid0 = timed[0].uid
    res = eng.run()

    # ---- identity + report ------------------------------------------------
    mismatches = []
    for r in res["requests"]:
        i = r.uid - uid0
        if r.expert != serial["routes"][i] or \
                not np.array_equal(np.asarray(r.tokens), serial["tokens"][i]):
            mismatches.append(i)
    speedup = res["tokens_per_s"] / serial["tokens_per_s"]
    report = {
        "workload": {"requests": args.requests, "experts": args.experts,
                     "lanes": args.lanes, "prompt_len": args.prompt_len,
                     "new_tokens": [int(x) for x in n_new]},
        "serial": {"wall_s": round(serial["wall_s"], 3),
                   "tokens_per_s": round(serial["tokens_per_s"], 1),
                   "useful_tokens": serial["useful_tokens"],
                   "wasted_tokens": serial["wasted_tokens"]},
        "engine": {"wall_s": round(res["wall_s"], 3),
                   "tokens_per_s": round(res["tokens_per_s"], 1),
                   "useful_tokens": res["useful_tokens"],
                   "occupancy": round(res["occupancy"], 3),
                   "ticks": res["ticks"]},
        "speedup": round(speedup, 2),
        "tokens_identical": not mismatches,
    }
    print(json.dumps(report, indent=1))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=1)
    if mismatches:
        print(f"FAIL: token mismatch on requests {mismatches[:8]}")
        return 1
    print(f"engine {res['tokens_per_s']:.1f} tok/s vs serial "
          f"{serial['tokens_per_s']:.1f} tok/s -> {speedup:.2f}x "
          f"({serial['wasted_tokens']} wasted baseline tokens reclaimed)")
    if not args.no_check and speedup <= 1.0:
        print("FAIL: engine did not beat the serial baseline")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
