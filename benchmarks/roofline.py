"""Roofline report generator (deliverable g).

Reads the dry-run JSON records (results/dryrun/*.json), computes the
three roofline terms per (arch x shape), the MODEL_FLOPS/HLO_FLOPs
usefulness ratio, the dominant bottleneck, and a what-would-move-it note.

    PYTHONPATH=src python -m benchmarks.roofline results/dryrun
"""
from __future__ import annotations

import glob
import json
import os
import sys

sys.path.insert(0, "src")

import jax

from repro.configs import INPUT_SHAPES, get_config
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16


def model_params(cfg) -> tuple[float, float]:
    """(total params, active params) — analytic, via eval_shape."""
    from repro.launch.specs import param_struct
    struct = param_struct(cfg)
    flat = jax.tree_util.tree_flatten_with_path(struct)[0]
    total = active = 0.0
    for path, leaf in flat:
        names = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        n = float(leaf.size)
        total += n
        if cfg.moe is not None and "moe" in names and "dense" not in names \
                and names[-1] in ("wi", "wg", "wo"):
            n = n * cfg.moe.top_k / cfg.moe.n_experts
        active += n
    return total, active


def model_flops(cfg, shape, kind: str) -> float:
    """MODEL_FLOPS = 6*N_active*D (train) / 2*N_active*D (inference)."""
    _, active = model_params(cfg)
    if kind == "train":
        return 6.0 * active * shape.global_batch * shape.seq_len
    if kind == "prefill":
        return 2.0 * active * shape.global_batch * shape.seq_len
    return 2.0 * active * shape.global_batch           # decode: 1 token


def hint(dom: str, rec: dict, cfg) -> str:
    if dom == "collective_s":
        kinds = rec["hlo_cost"].get("by_kind", {})
        top = max(kinds, key=kinds.get) if kinds else "?"
        return (f"cut {top} traffic (layout/sharding: e.g. reduce "
                f"tensor-parallel all-reduces or overlap with compute)")
    if dom == "memory_s":
        return ("raise arithmetic intensity: fuse (Pallas), larger "
                "per-device batch, fewer remat recomputes, bf16 residuals")
    return "compute-bound: near roofline; only kernel-level wins remain"


def load(dirpath: str, *, mesh: str = "sp", mode: str = "dense") -> list[dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(dirpath, f"*-{mesh}-{mode}.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def table(recs: list[dict]) -> str:
    lines = [
        "| arch | shape | step | compute_s | memory_s | collective_s | "
        "dominant | MODEL_FLOPS/HLO | note |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    order = {n: i for i, n in enumerate(INPUT_SHAPES)}
    recs = sorted(recs, key=lambda r: (r["arch"], order.get(r["shape"], 9)))
    for r in recs:
        if r["status"] == "SKIP":
            lines.append(f"| {r['arch']} | {r['shape']} | SKIP | - | - | - | "
                         f"- | - | {r['why']} |")
            continue
        if r["status"] != "OK":
            lines.append(f"| {r['arch']} | {r['shape']} | FAIL | - | - | - | "
                         f"- | - | see json |")
            continue
        cfg = get_config(r["arch"])
        shape = INPUT_SHAPES[r["shape"]]
        rl = r["roofline"]
        mf = model_flops(cfg, shape, r["kind"])
        hlo_global = r["hlo_cost"]["flops"] * r["chips"]
        ratio = mf / hlo_global if hlo_global else float("nan")
        dom = rl["dominant"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} "
            f"| {rl['compute_s']*1e3:.2f}ms | {rl['memory_s']*1e3:.2f}ms "
            f"| {rl['collective_s']*1e3:.2f}ms | {dom.replace('_s','')} "
            f"| {ratio:.2f} | {hint(dom, r, cfg)} |")
    return "\n".join(lines)


def main() -> None:
    d = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"
    recs = load(d)
    print(f"## Roofline — single-pod 16x16 (256 chips), "
          f"{PEAK_FLOPS_BF16/1e12:.0f} TF/s bf16, {HBM_BW/1e9:.0f} GB/s HBM, "
          f"{ICI_BW/1e9:.0f} GB/s ICI\n")
    print(table(recs))
    ok = sum(r["status"] == "OK" for r in recs)
    sk = sum(r["status"] == "SKIP" for r in recs)
    print(f"\n{ok} OK, {sk} SKIP (per assignment rules), "
          f"{len(recs) - ok - sk} FAIL")


if __name__ == "__main__":
    main()
