"""Multi-pod summary table: dense (pod = extra DP) vs smalltalk (pod =
expert-parallel) — the paper's communication claim per architecture.

    PYTHONPATH=src python -m benchmarks.multipod_table results/dryrun
"""
from __future__ import annotations

import glob
import json
import os
import sys


def main() -> None:
    d = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"
    recs = {}
    for f in glob.glob(os.path.join(d, "*-mp-*.json")):
        r = json.load(open(f))
        recs[(r["arch"], r["shape"], r["mode"])] = r
    archs = sorted({k[0] for k in recs})
    shapes = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    print("| arch | shape | dense(mp) | pod-crossing bytes/step (dense) | "
          "smalltalk(mp) | pod-crossing bytes (smalltalk) |")
    print("|---|---|---|---|---|---|")
    for a in archs:
        for s in shapes:
            de = recs.get((a, s, "dense"))
            st = recs.get((a, s, "smalltalk"))
            if de is None and st is None:
                continue

            def fmt(r, col):
                if r is None:
                    return "-", "-"
                if r["status"] != "OK":
                    return r["status"], "-"
                pc = r["hlo_cost"]["pod_crossing_bytes"]
                return "OK", f"{pc/1e9:.2f} GB" if pc else "**0**"

            d1, d2 = fmt(de, True)
            s1, s2 = fmt(st, True)
            print(f"| {a} | {s} | {d1} | {d2} | {s1} | {s2} |")
    n_ok = sum(r["status"] == "OK" for r in recs.values())
    n_skip = sum(r["status"] == "SKIP" for r in recs.values())
    n_fail = len(recs) - n_ok - n_skip
    print(f"\n{n_ok} OK / {n_skip} SKIP / {n_fail} FAIL")
    # the paper's claim, asserted:
    bad = [(k, r["hlo_cost"]["pod_crossing_bytes"]) for k, r in recs.items()
           if k[2] == "smalltalk" and r["status"] == "OK"
           and r["hlo_cost"]["pod_crossing_bytes"] > 0]
    print("smalltalk pod-crossing violations:", bad if bad else "none ✅")


if __name__ == "__main__":
    main()
