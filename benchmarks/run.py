"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (us_per_call = wall time of
the measured computation; derived = the table/figure's headline quantity).

  table3_flops       App. A.3 / Table 3 cost accounting (analytic, exact)
  tableA4_comm       App. A.4 communication overhead vs dense DDP
  fig2_ppl_vs_flops  mixture vs dense ppl at equal total tokens (measured)
  fig4a_router_size  router-size invariance (routing purity, measured)
  fig4b_prefix_len   routed ppl vs inference prefix length (measured)
  fig4c_tfidf        LM routing vs TF-IDF+k-means (purity, measured)
  fig5_specialize    per-segment expert-vs-dense ppl (measured)
  assignment_perf    balanced-assignment throughput
  kernels_perf       pallas(interpret) vs jnp-chunked loss / attention

Scale note: measured rows run a CPU-sized replica (tiny experts, synthetic
multi-domain corpus) of each experiment; the analytic rows evaluate the
paper's exact formulas at paper scale.
"""
from __future__ import annotations

import sys
import time
from functools import lru_cache

import numpy as np

sys.path.insert(0, "src")

ROWS: list[tuple[str, float, str]] = []


def row(name: str, us: float, derived: str) -> None:
    ROWS.append((name, us, derived))
    print(f"{name},{us:.1f},{derived}", flush=True)


def timed(fn):
    t0 = time.time()
    out = fn()
    return out, (time.time() - t0) * 1e6


# ---------------------------------------------------------------------------
# Analytic tables
# ---------------------------------------------------------------------------
def bench_table3_flops():
    from benchmarks.flops_accounting import comm_table, table3
    (rows, us) = timed(table3)
    for r in rows:
        row(f"table3_{r['model']}x{r['experts']}e_train_overhead_pct",
            us / len(rows),
            f"{r['mix_overhead_train_pct']:.2f}")
        row(f"table3_{r['model']}x{r['experts']}e_inf_overhead_pct",
            us / len(rows),
            f"{r['mix_overhead_inf_pct']:.2f}")


def bench_tableA4_comm():
    from benchmarks.flops_accounting import comm_table
    (c, us) = timed(lambda: comm_table(E=32))
    row("tableA4_router_total_comm_MB", us,
        f"{c['router_total_bytes'] / 1e6:.2f}")
    row("tableA4_ddp_bytes_per_step_GB", us,
        f"{c['ddp_bytes_per_step'] / 1e9:.2f}")
    row("tableA4_one_ddp_step_vs_router_total", us,
        f"{c['ratio_one_ddp_step_vs_entire_router_training']:.1f}")


# ---------------------------------------------------------------------------
# Measured mini-replica (shared artifacts)
# ---------------------------------------------------------------------------
@lru_cache(maxsize=1)
def _mini():
    """Train the shared mini replica: routers (EM), mixture, dense."""
    import jax
    from repro.configs.base import ModelConfig
    from repro.core import em, mixture as mixlib
    from repro.data import DataConfig, Stream, SyntheticCorpus, make_lm_batch
    from repro.models import model as modellib
    from repro.optim import AdamWConfig

    rcfg = ModelConfig(name="bench-router", n_layers=2, d_model=64, n_heads=4,
                       n_kv_heads=4, d_ff=256, vocab_size=256,
                       ffn_type="gelu", loss_chunk=64)
    ecfg = ModelConfig(name="bench-expert", n_layers=2, d_model=128,
                       n_heads=4, n_kv_heads=4, d_ff=512, vocab_size=256,
                       ffn_type="gelu", loss_chunk=64)
    corpus = SyntheticCorpus(DataConfig(vocab_size=256, seq_len=64,
                                        n_domains=4))
    emcfg = em.EMConfig(n_experts=4, prefix_len=32, em_iters=3,
                        chunk_size=2048, steps_per_iter=40, batch_size=32,
                        lr=3e-3)
    key = jax.random.PRNGKey(0)
    t0 = time.time()
    state = em.train_routers(corpus, rcfg, emcfg, key)
    t_router = time.time() - t0
    assign, doms, comm = em.shard_corpus(state, rcfg, corpus, 4096, emcfg)
    opt = AdamWConfig(peak_lr=1e-3, warmup_steps=20, total_steps=200,
                      clip_norm=1.0)
    E, steps, bs = 4, 200, 16
    t0 = time.time()
    mix = mixlib.train_mixture_experts(ecfg, corpus, assign, steps, bs, opt,
                                       key, router_state=state, prefix_len=32,
                                       router_cfg=rcfg)
    t_mix = time.time() - t0
    t0 = time.time()
    dense = modellib.init_params(key, ecfg)
    optd = AdamWConfig(peak_lr=1e-3, warmup_steps=20, total_steps=E * steps,
                       clip_norm=1.0)
    dense, _ = mixlib.train_expert(ecfg, dense, Stream(corpus, bs), E * steps,
                                   optd)
    t_dense = time.time() - t0
    held = corpus.sequences(np.arange(10_000_000, 10_000_000 + 512))
    batch = make_lm_batch(*held)
    return dict(rcfg=rcfg, ecfg=ecfg, corpus=corpus, emcfg=emcfg, state=state,
                assign=assign, doms=doms, mix=mix, dense=dense, batch=batch,
                t_router=t_router, t_mix=t_mix, t_dense=t_dense,
                held_domains=held[1])


def bench_fig2_ppl_vs_flops():
    from repro.core import mixture as mixlib
    m = _mini()
    ppl_mix, eids, nll = mixlib.mixture_eval_ppl(m["mix"], m["batch"],
                                                 return_routes=True)
    ppl_dense = mixlib.dense_eval_ppl(m["ecfg"], m["dense"], m["batch"])
    m["eids"], m["nll_mix"] = eids, nll
    row("fig2_ppl_mixture_4e", m["t_mix"] * 1e6, f"{ppl_mix:.4f}")
    row("fig2_ppl_dense_equal_tokens", m["t_dense"] * 1e6, f"{ppl_dense:.4f}")
    row("fig2_ppl_gain_pct", 0.0, f"{100 * (1 - ppl_mix / ppl_dense):.2f}")


def bench_fig4a_router_size():
    """Router size does not matter: EM purity for 2 router sizes."""
    import jax
    from repro.configs.base import ModelConfig
    from repro.core import em
    m = _mini()
    small = ModelConfig(name="bench-router-xs", n_layers=1, d_model=32,
                        n_heads=2, n_kv_heads=2, d_ff=128, vocab_size=256,
                        ffn_type="gelu", loss_chunk=64)
    (state_xs, us) = timed(lambda: em.train_routers(
        m["corpus"], small, m["emcfg"], jax.random.PRNGKey(0)))
    p_big = m["state"].history[-1]["purity"]
    p_xs = state_xs.history[-1]["purity"]
    row("fig4a_purity_router_84k_params", m["t_router"] * 1e6, f"{p_big:.3f}")
    row("fig4a_purity_router_13k_params", us, f"{p_xs:.3f}")


def bench_fig4b_prefix_len():
    from repro.core import mixture as mixlib
    m = _mini()
    for M in (8, 16, 32):
        (ppl, us) = timed(lambda M=M: mixlib.mixture_eval_ppl(
            m["mix"], m["batch"], prefix_len=M))
        row(f"fig4b_ppl_prefix_{M}", us, f"{ppl:.4f}")


def bench_fig4c_tfidf():
    from benchmarks.tfidf_router import TfidfSvd, balanced_kmeans, route_nearest
    from repro.core.em import domain_purity
    m = _mini()
    corpus, emcfg = m["corpus"], m["emcfg"]
    train_toks, train_doms = corpus.sequences(np.arange(1024))

    def run():
        enc = TfidfSvd(vocab=256, dim=16)
        feats = enc.fit(train_toks)
        assign, centers = balanced_kmeans(feats, 4, iters=10)
        # route HELD-OUT prefixes (the paper's point: short prefix hurts tfidf)
        held, doms = corpus.sequences(np.arange(20_000, 20_000 + 512))
        pf = enc.transform(held[:, :emcfg.prefix_len])
        return route_nearest(pf, centers), doms

    ((route, doms), us) = timed(run)
    p_tfidf = domain_purity(route, doms, 4)
    p_lm = domain_purity(m["assign"][:4096], m["doms"][:4096], 4)
    row("fig4c_purity_tfidf_kmeans", us, f"{p_tfidf:.3f}")
    row("fig4c_purity_lm_router", 0.0, f"{p_lm:.3f}")


def bench_fig5_specialize():
    from repro.core import mixture as mixlib
    m = _mini()
    if "eids" not in m:
        bench_fig2_ppl_vs_flops()
    eids, nll = m["eids"], m["nll_mix"]
    dense_nll = mixlib.eval_nll(m["ecfg"], m["dense"],
                                {k: np.asarray(v) for k, v in m["batch"].items()
                                 if k != "domain"})
    wins, shares = [], []
    for e in range(4):
        sel = eids == e
        if sel.sum() == 0:
            continue
        wins.append(float(np.exp(nll[sel].mean()))
                    < float(np.exp(dense_nll[sel].mean())))
        shares.append(float(sel.mean()))
        row(f"fig5_segment{e}_ppl_mix_vs_dense", 0.0,
            f"{np.exp(nll[sel].mean()):.3f}_vs_{np.exp(dense_nll[sel].mean()):.3f}")
    row("fig5_experts_beating_dense", 0.0, f"{sum(wins)}/{len(wins)}")
    row("fig5_min_segment_share", 0.0, f"{min(shares):.3f}")


# ---------------------------------------------------------------------------
# Systems micro-benches
# ---------------------------------------------------------------------------
def bench_assignment_perf():
    import jax
    from repro.core.assignment import balanced_assignment
    scores = np.random.default_rng(0).normal(size=(4096, 32)).astype(np.float32)
    fn = jax.jit(lambda s: balanced_assignment(s, 129))
    fn(scores).block_until_ready()
    t0 = time.time()
    for _ in range(5):
        fn(scores).block_until_ready()
    us = (time.time() - t0) / 5 * 1e6
    row("assignment_balanced_4096x32", us, "capacity=129")


def bench_kernels_perf():
    import jax
    import jax.numpy as jnp
    from repro.kernels.lm_loss import ops as lm_ops
    from repro.kernels.flash_attention import ops as fa_ops
    h = jax.random.normal(jax.random.PRNGKey(0), (4, 256, 128))
    emb = jax.random.normal(jax.random.PRNGKey(1), (2048, 128)) * 0.1
    lab = jax.random.randint(jax.random.PRNGKey(2), (4, 256), 0, 2048)
    for impl in ("jnp", "pallas"):
        fn = jax.jit(lambda h, e, l, impl=impl: lm_ops.lm_loss(
            h, e, l, impl=impl))
        ref = fn(h, emb, lab).block_until_ready()
        t0 = time.time()
        for _ in range(3):
            fn(h, emb, lab).block_until_ready()
        us = (time.time() - t0) / 3 * 1e6
        row(f"lm_loss_{impl}_4x256xV2048", us,
            f"mean_nll={float(ref.mean()):.3f}")
    q = jax.random.normal(jax.random.PRNGKey(0), (2, 512, 8, 64))
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 512, 2, 64))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 512, 2, 64))
    for impl in ("jnp", "pallas"):
        fn = jax.jit(lambda q, k, v, impl=impl: fa_ops.flash_attention(
            q, k, v, impl=impl))
        out = fn(q, k, v).block_until_ready()
        t0 = time.time()
        for _ in range(3):
            fn(q, k, v).block_until_ready()
        us = (time.time() - t0) / 3 * 1e6
        row(f"flash_attn_{impl}_2x512_gqa4", us,
            f"out_norm={float(jnp.abs(out).mean()):.4f}")


ALL = [bench_table3_flops, bench_tableA4_comm, bench_fig2_ppl_vs_flops,
       bench_fig4a_router_size, bench_fig4b_prefix_len, bench_fig4c_tfidf,
       bench_fig5_specialize, bench_assignment_perf, bench_kernels_perf]


def main() -> None:
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    for fn in ALL:
        if only and only not in fn.__name__:
            continue
        try:
            fn()
        except Exception as ex:  # keep the harness going; surface the row
            row(fn.__name__ + "_ERROR", 0.0, repr(ex)[:80])


if __name__ == "__main__":
    main()
