"""TF-IDF + SVD + balanced k-means routing baseline (Gururangan et al.
2023), the comparison in paper Fig. 4c — numpy implementation."""
from __future__ import annotations

import numpy as np


class TfidfSvd:
    """TF-IDF fitted on the training corpus, SVD projection reused for
    routing prefixes (the honest version of the Fig. 4c baseline)."""

    def __init__(self, vocab: int, dim: int = 32):
        self.vocab = vocab
        self.dim = dim
        self.idf: np.ndarray | None = None
        self.proj: np.ndarray | None = None

    def _counts(self, tokens: np.ndarray) -> np.ndarray:
        N = tokens.shape[0]
        counts = np.zeros((N, self.vocab), np.float32)
        for i, row in enumerate(tokens):
            np.add.at(counts[i], row, 1.0)
        return counts

    def _tfidf(self, tokens: np.ndarray) -> np.ndarray:
        tf = self._counts(tokens)
        tf /= np.maximum(tf.sum(1, keepdims=True), 1)
        x = tf * self.idf[None]
        return x / np.maximum(np.linalg.norm(x, axis=1, keepdims=True), 1e-9)

    def fit(self, tokens: np.ndarray) -> np.ndarray:
        counts = self._counts(tokens)
        df = (counts > 0).sum(0)
        self.idf = (np.log((1 + tokens.shape[0]) / (1 + df)) + 1.0
                    ).astype(np.float32)
        x = self._tfidf(tokens)
        _, s, vt = np.linalg.svd(x, full_matrices=False)
        d = min(self.dim, vt.shape[0])
        self.proj = vt[:d].T                    # (vocab, d)
        return x @ self.proj

    def transform(self, tokens: np.ndarray) -> np.ndarray:
        return self._tfidf(tokens) @ self.proj


def balanced_kmeans(x: np.ndarray, k: int, iters: int = 20,
                    seed: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """Balanced k-means (capacity-constrained greedy assignment)."""
    rng = np.random.default_rng(seed)
    n = x.shape[0]
    cap = int(np.ceil(n / k))
    centers = x[rng.choice(n, k, replace=False)]
    assign = np.zeros(n, np.int64)
    for _ in range(iters):
        d2 = ((x[:, None] - centers[None]) ** 2).sum(-1)    # (n, k)
        order = np.argsort(d2.min(1))
        counts = np.zeros(k, np.int64)
        for i in order:
            for c in np.argsort(d2[i]):
                if counts[c] < cap:
                    assign[i] = c
                    counts[c] += 1
                    break
        for c in range(k):
            sel = assign == c
            if sel.any():
                centers[c] = x[sel].mean(0)
    return assign, centers


def route_nearest(feats: np.ndarray, centers: np.ndarray) -> np.ndarray:
    d2 = ((feats[:, None] - centers[None]) ** 2).sum(-1)
    return d2.argmin(1)
