"""Compare a fresh ``BENCH_serve/v*`` report against the checked-in baseline.

CI runs ``serve_bench.py --smoke --json BENCH_serve.json`` on every push
and then this script against ``benchmarks/BENCH_baseline.json``, so the
BENCH trajectory is *gated*, not just uploaded:

  * token-identity gates (greedy workload + the sampled/early-stop smoke
    gate) hard-fail — these are correctness, no tolerance;
  * the paged decode read traffic must stay strictly below the gathered
    ``(lanes, max_len)`` view it replaced — also a hard gate;
  * a v3 ``open_loop`` section (when present) must carry the full
    per-expert latency quartet, be token-identical in every run, and —
    when a replicated run exists — have improved the hot expert's p99
    TTFT (hard gates; the latency values themselves are informational
    rows in the delta table);
  * a v4 ``prefix_sharing`` section (when present and enabled on a
    shared-prefix workload) must report ``prefill_tokens_saved > 0``
    while the token-identity gates above stay green — the cache must
    actually shortcut prefill work AND must not change a single token;
  * a v5 ``two_frontend`` section (present on ``--transport tcp`` runs:
    two stateless frontends sharing one worker fleet) must report
    distinct leased uid namespaces, ``uids_disjoint`` and
    ``tokens_identical`` — any cross-frontend stream corruption is a
    hard failure;
  * a v6 ``autoscale`` section (present on ``--autoscale`` runs) must
    report ``scaled_up_hot`` (the hot expert gained a replica
    mid-serve), ``retired_cold`` (an idle cold replica was quiesced and
    released), ``p99_ttft_improved`` vs the static single-replica run,
    and ``tokens_identical`` across both runs — all hard gates;
  * a v7 ``prefill_write_bytes`` section (when present) must show the
    fused paged prefill's pool writes strictly below the slab+scatter
    path it replaced — the admission-side mirror of the decode read
    gate, also hard;
  * engine tokens/sec must stay within ``--min-ratio`` of the baseline —
    generous by default because shared CI runners are noisy; the full
    delta table lands in ``$GITHUB_STEP_SUMMARY`` either way.

Schema evolution: reports carry ``BENCH_serve/v<N>``.  A *newer* fresh
report against an *older* baseline is fine — schema bumps add keys (the
metric paths above are looked up tolerantly and missing rows are simply
skipped), so the trajectory never breaks just because the bench learned
to measure something new.  A fresh report OLDER than the baseline fails:
that means a regression in the bench itself.

Refresh the baseline by re-running the smoke bench and checking in the
report:  PYTHONPATH=src python benchmarks/serve_bench.py --smoke \
             --json benchmarks/BENCH_baseline.json
"""
from __future__ import annotations

import argparse
import json
import sys


def _fmt(x):
    if isinstance(x, float):
        return f"{x:,.2f}"
    if isinstance(x, int):
        return f"{x:,}"
    return str(x)


def _schema_version(schema) -> int | None:
    """``"BENCH_serve/v<N>"`` -> N, else None."""
    prefix = "BENCH_serve/v"
    if not isinstance(schema, str) or not schema.startswith(prefix):
        return None
    try:
        return int(schema[len(prefix):])
    except ValueError:
        return None


def _get(report: dict, path: str):
    cur = report
    for part in path.split("."):
        if not isinstance(cur, dict) or part not in cur:
            return None
        cur = cur[part]
    return cur


ROWS = [
    ("engine tok/s", "engine.tokens_per_s"),
    ("serial tok/s", "serial.tokens_per_s"),
    ("speedup", "speedup"),
    ("occupancy", "engine.occupancy"),
    ("prefill calls", "engine.prefill_calls"),
    ("early stops", "engine.early_stops"),
    ("paged read B/tick", "decode_read_bytes_per_tick.paged"),
    ("gathered read B/tick", "decode_read_bytes_per_tick.gathered"),
    # v7 admission-write rows: absent in older reports, tolerantly skipped
    ("fused prefill write B/prefill", "prefill_write_bytes.fused_per_prefill"),
    ("slab prefill write B/prefill", "prefill_write_bytes.slab_per_prefill"),
    ("epilogue logits B", "epilogue_logits_bytes"),
    # v3 open-loop latency rows: absent in v1/v2 reports, tolerantly
    # skipped (latency is informational here; the gates below check the
    # structural invariants, serve_bench gates the improvement itself)
    ("open-loop p50 TTFT ms (1/expert)", "open_loop.single.ttft_p50_ms"),
    ("open-loop p99 TTFT ms (1/expert)", "open_loop.single.ttft_p99_ms"),
    ("open-loop p99 ITL ms (1/expert)", "open_loop.single.itl_p99_ms"),
    ("open-loop p99 TTFT ms (replicated)", "open_loop.replicated.ttft_p99_ms"),
    ("open-loop p99 ITL ms (replicated)", "open_loop.replicated.itl_p99_ms"),
    # v4 prefix-sharing rows: absent in older reports, tolerantly skipped
    ("prefix hit blocks", "prefix_sharing.hit_blocks"),
    ("prefill tokens saved", "prefix_sharing.prefill_tokens_saved"),
    ("cached blocks", "prefix_sharing.cached_blocks"),
    ("unadmitted requests", "n_unadmitted"),
    # v6 autoscale rows: absent in older reports, tolerantly skipped
    ("autoscale hot p99 TTFT ms (static)", "autoscale.static.hot.ttft_p99_ms"),
    ("autoscale hot p99 TTFT ms (scaled)",
     "autoscale.autoscaled.hot.ttft_p99_ms"),
    ("autoscale ups", "autoscale.autoscaled.scale_ups"),
    ("autoscale downs", "autoscale.autoscaled.scale_downs"),
]


def check_two_frontend(fresh: dict) -> list[str]:
    """Structural gates on the v5 ``two_frontend`` section (present on
    tcp runs): the two stateless frontends must have leased distinct uid
    namespaces, allocated disjoint uid ranges, and produced tokens
    identical to the serial reference."""
    tf = fresh.get("two_frontend")
    if tf is None:
        return []
    failures = []
    spaces = tf.get("namespaces") or []
    if len(spaces) != len(set(spaces)):
        failures.append(f"two-frontend run leased colliding uid "
                        f"namespaces {spaces}")
    if tf.get("uids_disjoint") is not True:
        failures.append("two-frontend run allocated overlapping uids")
    if tf.get("tokens_identical") is not True:
        failures.append("token-identity gate failed (two-frontend run)")
    return failures

def check_autoscale(fresh: dict) -> list[str]:
    """Hard gates on the v6 ``autoscale`` section (present on
    ``--autoscale`` runs): the control plane must have grown the hot
    expert and shrunk the cold one mid-serve, improved the hot tail
    latency over the static run, and changed no tokens."""
    a = fresh.get("autoscale")
    if a is None:
        return []
    failures = []
    if a.get("scaled_up_hot") is not True:
        failures.append("autoscale run never scaled the hot expert up")
    if a.get("retired_cold") is not True:
        failures.append("autoscale run never retired the idle cold replica")
    if a.get("p99_ttft_improved") is not True:
        failures.append("autoscaling did not improve the hot expert's "
                        "p99 TTFT over the static run")
    if a.get("tokens_identical") is not True:
        failures.append("token-identity gate failed (autoscale run)")
    return failures


# every per-expert entry of an open_loop run must carry the full latency
# quartet — a v3 report that dropped one silently would still "compare"
_LATENCY_KEYS = ("ttft_p50_ms", "ttft_p99_ms", "itl_p50_ms", "itl_p99_ms")


def check_open_loop(fresh: dict) -> list[str]:
    """Structural gates on the v3 ``open_loop`` section (when present):
    per-expert latency fields complete, every run token-identical, and a
    replicated run must have improved the hot expert's p99 TTFT."""
    ol = fresh.get("open_loop")
    if ol is None:
        return []
    failures = []
    for run_name in ("single", "replicated"):
        run = ol.get(run_name)
        if run is None:
            continue
        if run.get("tokens_identical") is not True:
            failures.append(f"token-identity gate failed (open-loop "
                            f"{run_name} run)")
        for e, st in (run.get("per_expert") or {}).items():
            missing = [k for k in _LATENCY_KEYS if k not in st]
            if missing:
                failures.append(f"open-loop {run_name} run: expert {e} "
                                f"report is missing {missing}")
    if "replicated" in ol and ol.get("p99_ttft_improved") is not True:
        failures.append("open-loop replicated run did not improve the hot "
                        "expert's p99 TTFT")
    return failures


def delta_table(fresh: dict, base: dict) -> str:
    lines = ["| metric | baseline | current | delta |",
             "|---|---:|---:|---:|"]
    for label, path in ROWS:
        b, f = _get(base, path), _get(fresh, path)
        if b is None and f is None:
            continue
        if isinstance(b, (int, float)) and isinstance(f, (int, float)) and b:
            delta = f"{100.0 * (f - b) / b:+.1f}%"
        else:
            delta = "—"
        lines.append(f"| {label} | {_fmt(b)} | {_fmt(f)} | {delta} |")
    gates = [("tokens_identical", _get(fresh, "tokens_identical")),
             ("smoke_sampled.tokens_identical",
              _get(fresh, "smoke_sampled.tokens_identical")),
             ("two_frontend.tokens_identical",
              _get(fresh, "two_frontend.tokens_identical")),
             ("two_frontend.uids_disjoint",
              _get(fresh, "two_frontend.uids_disjoint")),
             ("autoscale.scaled_up_hot",
              _get(fresh, "autoscale.scaled_up_hot")),
             ("autoscale.retired_cold",
              _get(fresh, "autoscale.retired_cold")),
             ("autoscale.p99_ttft_improved",
              _get(fresh, "autoscale.p99_ttft_improved")),
             ("autoscale.tokens_identical",
              _get(fresh, "autoscale.tokens_identical"))]
    lines.append("")
    lines.append("gates: " + ", ".join(
        f"`{name}` = {val}" for name, val in gates if val is not None))
    return "\n".join(lines)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("fresh", help="BENCH_serve.json from this run")
    ap.add_argument("baseline", help="checked-in benchmarks/BENCH_baseline.json")
    ap.add_argument("--min-ratio", type=float, default=0.25,
                    help="fail if engine tokens/sec drops below this "
                         "fraction of the baseline report's")
    ap.add_argument("--summary", default=None,
                    help="append the markdown delta table to this file "
                         "(pass \"$GITHUB_STEP_SUMMARY\" in CI)")
    args = ap.parse_args()
    with open(args.fresh) as f:
        fresh = json.load(f)
    with open(args.baseline) as f:
        base = json.load(f)
    vers = []
    for r, name in ((fresh, args.fresh), (base, args.baseline)):
        v = _schema_version(r.get("schema"))
        if v is None:
            print(f"FAIL: {name} is not a BENCH_serve/v* report "
                  f"(schema={r.get('schema')!r})")
            return 2
        vers.append(v)
    if vers[0] < vers[1]:
        print(f"FAIL: fresh report schema v{vers[0]} is older than the "
              f"baseline's v{vers[1]}")
        return 2
    if vers[0] > vers[1]:
        print(f"note: fresh schema v{vers[0]} vs baseline v{vers[1]} — "
              f"comparing the shared keys (schema bumps add keys)")

    table = f"### Serving bench vs baseline\n\n{delta_table(fresh, base)}\n"
    print(table)
    if args.summary:
        with open(args.summary, "a") as f:
            f.write(table)

    failures = []
    if fresh.get("tokens_identical") is not True:
        failures.append("token-identity gate failed (greedy workload)")
    smoke = fresh.get("smoke_sampled")
    if smoke is not None and smoke.get("tokens_identical") is not True:
        failures.append("token-identity gate failed (sampled + early-stop)")
    rb = fresh.get("decode_read_bytes_per_tick")
    if rb and rb["paged"] >= rb["gathered"]:
        failures.append(f"paged decode reads ({rb['paged']} B/tick) not "
                        f"below gathered ({rb['gathered']} B/tick)")
    wb = fresh.get("prefill_write_bytes")
    if wb and wb.get("slab") and wb["fused"] >= wb["slab"]:
        failures.append(f"fused prefill writes ({wb['fused']} B) not "
                        f"below slab+scatter ({wb['slab']} B)")
    failures.extend(check_open_loop(fresh))
    failures.extend(check_two_frontend(fresh))
    failures.extend(check_autoscale(fresh))
    ps = fresh.get("prefix_sharing")
    if ps is not None and ps.get("enabled") and \
            _get(fresh, "workload.shared_prefix_len"):
        # on a shared-prefix workload an enabled cache must save work;
        # zero savings means sharing silently stopped engaging (the
        # identity gates above already guarantee it changed no tokens)
        if not ps.get("prefill_tokens_saved", 0) > 0:
            failures.append("prefix sharing enabled on a shared-prefix "
                            "workload but prefill_tokens_saved is not > 0")
    f_tps = _get(fresh, "engine.tokens_per_s") or 0.0
    b_tps = _get(base, "engine.tokens_per_s") or 0.0
    if b_tps and f_tps < args.min_ratio * b_tps:
        failures.append(f"engine {f_tps:.1f} tok/s fell below "
                        f"{args.min_ratio:.2f}x baseline ({b_tps:.1f} tok/s)")
    for msg in failures:
        print(f"FAIL: {msg}")
    if not failures:
        print(f"OK: identity gates green, engine {f_tps:.1f} tok/s vs "
              f"baseline {b_tps:.1f} tok/s")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
