"""Analytic FLOPs accounting — paper Appendix A.3 (Eq. 10-16), exact
formulas, evaluated at the paper's configurations to reproduce Table 3's
cost columns."""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class T:
    """Transformer shape (paper notation)."""
    L: int; H: int; A: int; D_ff: int; V: int  # noqa: E702


# paper Table 1
EXPERT_335M = T(L=24, H=1024, A=16, D_ff=4096, V=32000)
EXPERT_1P3B = T(L=24, H=2048, A=16, D_ff=8192, V=32000)
ROUTER_4M = T(L=12, H=96, A=12, D_ff=384, V=32000)


def forward_flops(m: T, B: int, S: int) -> float:
    """Eq. 10 inner bracket: embedding + L*(MHA + FFN) + output."""
    emb = B * S * m.H
    mha = 8 * B * S * m.H ** 2 + 4 * B * S ** 2 * m.H
    ffn = 4 * B * S * m.H * m.D_ff
    out = 2 * B * S * m.H * m.V + 3 * B * S * m.V
    return emb + m.L * (mha + ffn) + out


def train_flops(m: T, B: int, S: int, steps: int) -> float:
    """Eq. 10: 3x forward per step (backward ~ 2x forward)."""
    return 3.0 * steps * forward_flops(m, B, S)


def inference_flops(m: T, S: int) -> float:
    """Eq. 11 (B=1)."""
    return forward_flops(m, 1, S)


def mixture_train_flops(expert: T, router: T, *, E: int, B: int, S: int,
                        M: int, steps_expert: int, steps_router: int,
                        B_router: int) -> dict:
    """Eq. 12-16."""
    routers = E * train_flops(router, B_router, S, steps_router)        # Eq.13
    shard_r = (steps_router * B_router * E) * inference_flops(router, M) * E  # Eq.14
    experts = E * train_flops(expert, B, S, steps_expert)               # Eq.15
    shard_e = (steps_expert * B * E) * inference_flops(router, M) * E   # Eq.16
    return {"experts": experts, "routers": routers,
            "shard_routers": shard_r, "shard_experts": shard_e,
            "total": experts + routers + shard_r + shard_e,
            "overhead": routers + shard_r + shard_e}


def mixture_inference_flops(expert: T, router: T, *, E: int, S: int,
                            M: int) -> dict:
    ex = inference_flops(expert, S)
    rt = E * inference_flops(router, M)
    return {"expert": ex, "routers": rt, "total": ex + rt,
            "overhead_frac": rt / ex}


# paper Table 2 rows: (expert cfg, E, steps_expert, dense steps, batch)
TABLE3_ROWS = [
    ("335M", EXPERT_335M, 4, 256_000, 256_000, 512, 128),
    ("335M", EXPERT_335M, 8, 256_000, 512_000, 512, 128),
    ("335M", EXPERT_335M, 16, 256_000, 1_024_000, 512, 128),
    ("335M", EXPERT_335M, 32, 256_000, 2_048_000, 512, 128),
    ("1.3B", EXPERT_1P3B, 4, 512_000, 512_000, 512, 128),
    ("1.3B", EXPERT_1P3B, 16, 512_000, 1_024_000, 1024, 128),
    ("1.3B", EXPERT_1P3B, 32, 512_000, 1_024_000, 2048, 128),
]

S_PAPER, M_PAPER = 1024, 256
ROUTER_STEPS, ROUTER_BATCH = 128_000, 32


def table3() -> list[dict]:
    rows = []
    for name, expert, E, e_steps, d_steps, d_batch, e_batch in TABLE3_ROWS:
        dense = train_flops(expert, d_batch, S_PAPER, d_steps)
        mix = mixture_train_flops(expert, ROUTER_4M, E=E, B=e_batch,
                                  S=S_PAPER, M=M_PAPER,
                                  steps_expert=e_steps,
                                  steps_router=ROUTER_STEPS,
                                  B_router=ROUTER_BATCH)
        d_inf = inference_flops(expert, S_PAPER)
        m_inf = mixture_inference_flops(expert, ROUTER_4M, E=E, S=S_PAPER,
                                        M=M_PAPER)
        rows.append({
            "model": name, "experts": E,
            "dense_train_1e19": dense / 1e19,
            "mix_overhead_train_pct": 100 * mix["overhead"] / (E * train_flops(
                expert, e_batch, S_PAPER, e_steps)),
            "dense_inf_1e12": d_inf / 1e12,
            "mix_overhead_inf_pct": 100 * m_inf["overhead_frac"],
        })
    return rows


def comm_table(E: int = 32, W: float = 1.3e9, T_tokens: float = 45e6,
               S: int = 1024) -> dict:
    """App. A.4: router all-gather bytes vs dense DDP per-step bytes."""
    data_per_router = 2 * 2 * T_tokens * E / S          # f16 scores, 2x ring
    n_comm = ROUTER_STEPS * S * ROUTER_BATCH / T_tokens
    ddp_per_step = 2 * W * 4                            # f32 grads, 2x ring
    return {"router_bytes_per_comm": data_per_router,
            "router_n_comms": n_comm,
            "router_total_bytes": data_per_router * n_comm,
            "ddp_bytes_per_step": ddp_per_step,
            "ratio_one_ddp_step_vs_entire_router_training":
                ddp_per_step / (data_per_router * n_comm)}
