"""End-to-end training example (deliverable b): full SmallTalk pipeline —
EM routers -> corpus sharding -> independent experts -> mixture-vs-dense
evaluation, with checkpoints.

Thin wrapper over the production driver:

    PYTHONPATH=src python examples/train_smalltalk.py                  # tiny
    PYTHONPATH=src python examples/train_smalltalk.py --preset small   # ~100M-class
    PYTHONPATH=src python examples/train_smalltalk.py --preset paper   # TPU scale
"""
import sys

sys.path.insert(0, "src")

from repro.launch.train import main

if __name__ == "__main__":
    if "--preset" not in sys.argv:
        sys.argv += ["--preset", "tiny"]
    if "--dense-baseline" not in sys.argv:
        sys.argv += ["--dense-baseline"]
    main()
