"""Routing analysis example (paper §3.4 in miniature):

  (a) router size invariance — two router sizes give the same partition;
  (b) prefix-length sensitivity — routing quality vs prefix tokens;
  (c) LM routing vs TF-IDF + balanced k-means (Fig. 4c).

    PYTHONPATH=src python examples/routing_analysis.py
"""
import sys

sys.path.insert(0, "src")

import jax
import numpy as np

from benchmarks.tfidf_router import TfidfSvd, balanced_kmeans, route_nearest
from repro.configs.base import ModelConfig
from repro.core import em, router as routerlib
from repro.core.assignment import argmax_assignment
from repro.data import DataConfig, SyntheticCorpus

corpus = SyntheticCorpus(DataConfig(vocab_size=256, seq_len=64, n_domains=4))
emcfg = em.EMConfig(n_experts=4, prefix_len=32, em_iters=3, chunk_size=2048,
                    steps_per_iter=40, batch_size=32, lr=3e-3)


def router_cfg(d, L):
    return ModelConfig(name=f"ra-router-{d}", n_layers=L, d_model=d,
                       n_heads=4, n_kv_heads=4, d_ff=4 * d, vocab_size=256,
                       ffn_type="gelu", loss_chunk=64)


# (a) router size invariance -------------------------------------------------
print("== (a) router size ==")
states = {}
for d, L in ((64, 2), (32, 1)):
    rcfg = router_cfg(d, L)
    st = em.train_routers(corpus, rcfg, emcfg, jax.random.PRNGKey(0))
    states[d] = (rcfg, st)
    print(f"  router d_model={d}: final purity = "
          f"{st.history[-1]['purity']:.3f}")

# (b) prefix length ------------------------------------------------------------
print("== (b) prefix length at inference ==")
rcfg, st = states[64]
held, doms = corpus.sequences(np.arange(40_000, 40_000 + 512))
for M in (4, 8, 16, 32):
    scores = routerlib.ensemble_scores(st.router_params, rcfg,
                                       jax.numpy.asarray(held[:, :M]))
    purity = em.domain_purity(np.asarray(argmax_assignment(scores)), doms, 4)
    print(f"  prefix {M:3d} tokens: routing purity = {purity:.3f}")

# (c) TF-IDF baseline ---------------------------------------------------------
print("== (c) TF-IDF + balanced k-means (Gururangan et al. 2023) ==")
train_toks, _ = corpus.sequences(np.arange(1024))
enc = TfidfSvd(vocab=256, dim=16)
feats = enc.fit(train_toks)
_, centers = balanced_kmeans(feats, 4, iters=10)
for M in (8, 32, 64):
    pf = enc.transform(held[:, :M])
    purity = em.domain_purity(route_nearest(pf, centers), doms, 4)
    print(f"  tf-idf prefix {M:3d}: purity = {purity:.3f}")
print("  (compare with the LM-router purities above: the paper's point is "
      "that likelihood routing dominates on short prefixes)")
