"""Quickstart: continuous-batching mixture serving.

Builds a tiny 2-expert SmallTalk mixture (random weights — swap in a
``launch/train.py`` checkpoint via repro.launch.serve for trained ones),
submits a staggered stream of mixed-length requests, and drives the
engine: the router ensemble scores each prompt prefix, argmax picks ONE
expert, and requests join that expert's fixed-lane decode batch as soon
as a lane frees up — no recompiles, no waiting for the batch to drain.

    PYTHONPATH=src python examples/serve_mixture.py

For the full CLI (presets, checkpoints, the old serial baseline):

    PYTHONPATH=src python -m repro.launch.serve --help
"""
import sys

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import router as routerlib
from repro.data import DataConfig, SyntheticCorpus
from repro.models import model as modellib
from repro.serving import EngineConfig, MixtureServeEngine


def main() -> None:
    # 1. a tiny mixture: E experts + E prefix routers (stacked for vmap)
    n_experts = 2
    ecfg = ModelConfig(name="qs-expert", n_layers=2, d_model=128, n_heads=4,
                       n_kv_heads=4, d_ff=512, vocab_size=256,
                       ffn_type="gelu", loss_chunk=64)
    rcfg = ModelConfig(name="qs-router", n_layers=1, d_model=64, n_heads=4,
                       n_kv_heads=4, d_ff=256, vocab_size=256,
                       ffn_type="gelu", loss_chunk=64)
    key = jax.random.PRNGKey(0)
    router_params = routerlib.init_ensemble(key, rcfg, n_experts)
    expert_params = [modellib.init_params(jax.random.fold_in(key, e), ecfg)
                     for e in range(n_experts)]

    # 2. the engine: 4 decode lanes per expert, 96-token KV budget per lane
    engine = MixtureServeEngine(
        ecfg, rcfg, expert_params, router_params,
        EngineConfig(lanes_per_expert=4, max_len=96, prefix_len=16))

    # 3. a staggered stream of requests with mixed prompt/completion lengths
    corpus = SyntheticCorpus(DataConfig(vocab_size=256, seq_len=64,
                                        n_domains=n_experts))
    prompts, _ = corpus.sequences(np.arange(12))
    rng = np.random.default_rng(0)
    for i in range(12):
        engine.submit(prompts[i, :int(rng.integers(16, 48))],
                      max_new_tokens=int(rng.integers(4, 32)),
                      arrival_tick=i // 3)        # 3 arrivals per tick

    # 4. drive it (engine.step() works too, for one tick at a time)
    res = engine.run()
    print(f"served {len(res['requests'])} requests in {res['ticks']} ticks: "
          f"{res['useful_tokens']} tokens at {res['tokens_per_s']:.1f} tok/s, "
          f"lane occupancy {res['occupancy']:.2f}")
    for r in res["requests"]:
        print(f"  req{r.uid}: expert {r.expert}, prompt {len(r.prompt)} tok, "
              f"+{len(r.tokens)} new, queued {r.queue_ticks} ticks")


if __name__ == "__main__":
    main()
