"""Quickstart: continuous-batching mixture serving with sampling + streaming.

Builds a tiny 2-expert SmallTalk mixture (random weights — swap in a
``launch/train.py`` checkpoint via repro.launch.serve for trained ones)
and drives the engine's generation API end to end:

* every request carries a frozen ``SamplingParams`` recipe —
  ``temperature`` / ``top_k`` / ``top_p`` / ``seed``, with
  ``temperature=0.0`` meaning exact greedy argmax — plus per-request
  stop conditions (a ``stop_tokens`` set and ``max_new_tokens``);
* the router ensemble scores each prompt prefix, argmax picks ONE
  expert (§2.2), and the request joins that expert's fixed-lane decode
  batch as soon as a lane and KV pool blocks free up — sampling runs
  inside the per-expert jitted decode step with counter-based RNG
  (``fold_in(seed, uid, step)``), so a request's tokens don't depend on
  lane placement and mixed greedy/sampled batches never recompile;
* ``engine.stream()`` yields a ``TokenDelta`` per decoded token (request,
  token, index, done), so callers consume output as it decodes; a stop
  token ends the request immediately and recycles its KV blocks the same
  tick (``engine.run()`` is the drain-everything batch alternative).

    PYTHONPATH=src python examples/serve_mixture.py

The engine knobs come from the shared flag surface in
:mod:`repro.serving.cli` (same names as the other front-ends — try
``--transport process``, ``--replicas 0:2`` or ``--no-prefix-cache``).
For the full CLI (presets, checkpoints, sampling flags, the old serial
baseline):

    PYTHONPATH=src python -m repro.launch.serve --help
"""
import argparse
import sys

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import router as routerlib
from repro.data import DataConfig, SyntheticCorpus
from repro.models import model as modellib
from repro.serving import SamplingParams, ServeFrontend
from repro.serving import cli as servecli


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    return servecli.add_engine_args(ap)


def main() -> None:
    args = build_parser().parse_args()
    # 1. a tiny mixture: E experts + E prefix routers (stacked for vmap)
    n_experts = 2
    ecfg = ModelConfig(name="qs-expert", n_layers=2, d_model=128, n_heads=4,
                       n_kv_heads=4, d_ff=512, vocab_size=256,
                       ffn_type="gelu", loss_chunk=64)
    rcfg = ModelConfig(name="qs-router", n_layers=1, d_model=64, n_heads=4,
                       n_kv_heads=4, d_ff=256, vocab_size=256,
                       ffn_type="gelu", loss_chunk=64)
    key = jax.random.PRNGKey(0)
    router_params = routerlib.init_ensemble(key, rcfg, n_experts)
    expert_params = [modellib.init_params(jax.random.fold_in(key, e), ecfg)
                     for e in range(n_experts)]

    # 2. the engine: 4 decode lanes per expert, 96-token KV budget per lane
    #    (a hot expert could be cloned with replicas={0: 2} — tokens are
    #    replica-placement-invariant, so output would be unchanged)
    engine = ServeFrontend(
        ecfg, rcfg, expert_params, router_params,
        servecli.engine_config_from_args(args, max_len=96, prefix_len=16),
        replicas=args.replicas)

    # 3. a staggered stream of requests: mixed prompt/completion lengths,
    #    mixed recipes (greedy + sampled), and per-request stop tokens
    corpus = SyntheticCorpus(DataConfig(vocab_size=256, seq_len=64,
                                        n_domains=n_experts))
    prompts, _ = corpus.sequences(np.arange(12))
    rng = np.random.default_rng(0)
    recipes = [
        SamplingParams(),                                       # greedy
        SamplingParams(temperature=0.7, top_k=40, seed=1),
        SamplingParams(temperature=1.0, top_p=0.9, seed=2),
    ]
    for i in range(12):
        engine.submit(prompts[i, :int(rng.integers(16, 48))],
                      max_new_tokens=int(rng.integers(4, 32)),
                      sampling=recipes[i % len(recipes)],
                      stop_tokens={0, 1},          # ids that end a sequence
                      arrival_tick=i // 3)         # 3 arrivals per tick
    # 4. stream tokens as they decode (engine.run() drains in batch mode)
    n_tokens = 0
    with engine:                   # releases process-transport workers
        for delta in engine.stream():
            n_tokens += 1
            if delta.done:
                r = delta.request
                print(f"req{r.uid}: expert {r.expert}, "
                      f"T={r.sampling.temperature}, "
                      f"prompt {len(r.prompt)} tok, "
                      f"+{len(r.tokens)}/{r.max_new_tokens} new "
                      f"({r.finish_reason}, queued {r.queue_ticks} ticks): "
                      f"{r.tokens[:8]}{'...' if len(r.tokens) > 8 else ''}")
    print(f"streamed {n_tokens} tokens over {engine.tick} ticks")


if __name__ == "__main__":
    main()
