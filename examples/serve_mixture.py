"""Serving example (deliverable b): batched requests through the routed
mixture — prefix scoring by E tiny routers, argmax routing, per-expert
batched prefill + multi-token decode.

    PYTHONPATH=src python examples/serve_mixture.py
    PYTHONPATH=src python examples/serve_mixture.py --ckpt results/train
"""
import sys

sys.path.insert(0, "src")

from repro.launch.serve import main

if __name__ == "__main__":
    main()
