"""Quickstart: the whole SmallTalk LM pipeline in ~60 lines.

Trains 2 tiny routers by EM on a 2-domain synthetic corpus, shards the
corpus, trains 2 tiny experts independently, then routes held-out
sequences and compares routed vs. mis-routed perplexity.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import em, mixture as mixlib
from repro.data import DataConfig, SyntheticCorpus, make_lm_batch
from repro.optim import AdamWConfig

# 1. tiny configs ----------------------------------------------------------
router_cfg = ModelConfig(name="qs-router", n_layers=2, d_model=48, n_heads=4,
                         n_kv_heads=4, d_ff=192, vocab_size=128,
                         ffn_type="gelu", loss_chunk=32)
expert_cfg = ModelConfig(name="qs-expert", n_layers=2, d_model=96, n_heads=4,
                         n_kv_heads=4, d_ff=384, vocab_size=128,
                         ffn_type="gelu", loss_chunk=32)
corpus = SyntheticCorpus(DataConfig(vocab_size=128, seq_len=48, n_domains=2))

# 2. EM-train the routers (paper Algorithm 1, stage 1) -----------------------
emcfg = em.EMConfig(n_experts=2, prefix_len=24, em_iters=2, chunk_size=1024,
                    steps_per_iter=30, batch_size=32, lr=3e-3)
state = em.train_routers(corpus, router_cfg, emcfg, jax.random.PRNGKey(0))
print("EM history:", *state.history, sep="\n  ")

# 3. shard the corpus and train experts independently ------------------------
assign, doms, comm = em.shard_corpus(state, router_cfg, corpus, 2048, emcfg)
print(f"purity={em.domain_purity(assign, doms, 2):.3f}  "
      f"total communication={1e-3 * (state.comm_bytes + comm):.1f} KB")

opt = AdamWConfig(peak_lr=2e-3, warmup_steps=10, total_steps=120,
                  clip_norm=1.0)
mix = mixlib.train_mixture_experts(expert_cfg, corpus, assign, 120, 16, opt,
                                   jax.random.PRNGKey(1), router_state=state,
                                   prefix_len=24, router_cfg=router_cfg)

# 4. routed inference ---------------------------------------------------------
held = corpus.sequences(np.arange(50_000, 50_000 + 128))
batch = make_lm_batch(*held)
ppl, eids, nll = mixlib.mixture_eval_ppl(mix, batch, return_routes=True)
print(f"routed mixture ppl = {ppl:.3f}")

# what if we routed everything to expert 0? (counterfactual)
bad = mixlib.dense_eval_ppl(expert_cfg, mix.expert_params[0], batch)
print(f"single-expert (unrouted) ppl = {bad:.3f}  "
      f"-> routing gain {100 * (1 - ppl / bad):.1f}%")
